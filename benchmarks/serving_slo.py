"""Mixed-tenant SLO benchmark for the async serving pipeline.

Replays deterministic seeded traffic traces (``repro.serve.traffic``,
DESIGN.md §15) — per-tenant mixes of range-τ, top-k, and deadline
queries, in open- and closed-loop arrival models — against an
``AsyncGraphQueryEngine`` and records p50/p99 latency, goodput under
each tenant's deadline SLO, and partial-result rates.

    PYTHONPATH=src python -m benchmarks.serving_slo [--n 2000] [--smoke]

``--record --commit <sha> --date <YYYY-MM-DD>`` appends one row per run
to the repo-root ``BENCH_serving_slo.json`` trajectory (same convention
as ``BENCH_query_throughput.json``): this is the serving harness every
later PR gets judged by.  ``--smoke`` runs a tiny trace and asserts the
report schema (non-empty percentiles, goodput, partial-rate, per-stage
breakdown columns) — wired into ``make bench-smoke``.  ``--trace`` turns
span recording on and writes one Chrome trace-event artifact per
mix/mode to ``artifacts/bench/`` (DESIGN.md §17); with ``--smoke`` the
artifact is schema-validated too.  ``--faults`` additionally replays
each mix under the deterministic ``fault_plan()`` chaos schedule
(poisoned filter batches, latency spikes, a verifier worker kill,
admission shedding) and asserts bounded errors and zero stuck queries —
``make chaos-smoke`` runs ``--faults --smoke`` in CI (DESIGN.md §18).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List

from benchmarks.common import Csv, art_path, dataset, save_json
from repro.serve.traffic import (TenantSpec, generate_trace, replay,
                                 tenant_weights)

BENCH_LOG = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serving_slo.json"))

# the two standing tenant mixes every serving PR is judged on: an
# interactive/bulk split and a deadline-heavy top-k explorer mix
MIXES: Dict[str, List[TenantSpec]] = {
    "interactive_bulk": [
        TenantSpec("interactive", weight=1.0, rate_qps=60.0, clients=3,
                   queries_per_client=6, topk_frac=0.7, k_range=(1, 4),
                   cap=4, tau_range=(1, 2), deadline_s=0.25,
                   edits_range=(1, 2)),
        TenantSpec("bulk", weight=1.0, rate_qps=25.0, clients=2,
                   queries_per_client=5, topk_frac=0.0, tau_range=(1, 3),
                   deadline_s=None, edits_range=(1, 2)),
    ],
    "topk_explorer": [
        TenantSpec("explorer", weight=1.0, rate_qps=45.0, clients=3,
                   queries_per_client=6, topk_frac=1.0, k_range=(2, 6),
                   cap=5, deadline_s=0.35, edits_range=(1, 3)),
        TenantSpec("analytics", weight=1.0, rate_qps=20.0, clients=2,
                   queries_per_client=4, topk_frac=0.3, k_range=(1, 3),
                   cap=4, tau_range=(2, 3), deadline_s=0.8,
                   edits_range=(1, 2)),
    ],
}


MAX_BATCH = 8


def make_pipe(db, *, backend: str = "numpy", workers: int = 2,
              max_batch: int = MAX_BATCH, obs=None, faults=None,
              verify_executor: str = "thread", inbox_limit=None,
              shed_policy: str = "reject", tenant_weights=None):
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQueryEngine
    from repro.serve.pipeline import AsyncGraphQueryEngine
    eng = GraphQueryEngine(FlatMSQIndex(db), backend=backend,
                           result_cache_size=0, obs=obs, faults=faults)
    return AsyncGraphQueryEngine(eng, max_batch=max_batch,
                                 max_delay_s=0.002, num_workers=workers,
                                 verify_executor=verify_executor,
                                 faults=faults, inbox_limit=inbox_limit,
                                 shed_policy=shed_policy,
                                 tenant_weights=tenant_weights)


def fault_plan():
    """The standing chaos schedule for ``--faults`` runs (DESIGN.md §18)
    and the error budget it can legitimately cost: only the two
    filter-batch raises fail queries (one poisoned batch each); slice
    faults degrade to partials, kills/delays cost latency only."""
    from repro.serve.faults import FaultSpec
    specs = [
        FaultSpec("filter.batch", on_calls=(3, 9)),
        FaultSpec("filter.batch", kind="delay", every=5, delay_s=0.01,
                  times=4),
        FaultSpec("device.filter", every=7),
        FaultSpec("verify.pool", kind="kill_worker", on_calls=(5,)),
        FaultSpec("verify.slice", on_calls=(11,)),
    ]
    return specs, 2 * MAX_BATCH


def check_report(rep: dict, *, faulted: bool = False,
                 n_expected=None) -> None:
    """Schema gate (the bench-smoke assertion): percentiles present and
    finite, goodput/partial-rate/SLO fields populated, per-stage
    breakdown columns present (DESIGN.md §17).  Fault-free runs must be
    error-free; ``--faults`` runs get the ``fault_plan`` error budget
    plus the zero-stuck check — every issued query resolved to a typed
    outcome (DESIGN.md §18)."""
    _, err_budget = fault_plan()
    for scope, b in [("overall", rep["overall"]),
                     *rep["per_tenant"].items()]:
        assert b["n"] > 0, f"{scope}: empty bucket"
        for fld in ("p50_ms", "p99_ms"):
            assert math.isfinite(b[fld]) and b[fld] > 0, \
                f"{scope}.{fld} not a positive finite latency: {b[fld]}"
        for fld in ("goodput_qps", "partial_rate", "slo_miss_rate"):
            assert fld in b and b[fld] >= 0, f"{scope}.{fld} missing"
        for fld in ("filter_ms", "lb_ms", "verify_ms", "queue_ms"):
            assert fld in b and math.isfinite(b[fld]) and b[fld] >= 0, \
                f"{scope}.{fld} breakdown missing/invalid: {b.get(fld)}"
        if faulted:
            assert b["errors"] <= err_budget, \
                f"{scope}: {b['errors']} errors > fault budget {err_budget}"
        else:
            assert b["errors"] == 0, f"{scope}: {b['errors']} query errors"
    if n_expected is not None:
        got = rep["overall"]["n"]
        assert got == n_expected, \
            f"stuck queries: only {got}/{n_expected} resolved"


def run_mix(csv: Csv, db, mix: str, mode: str, *, backend: str,
            workers: int, duration_s: float, seed: int,
            speed: float, span_trace: bool = False,
            validate: bool = False, faulted: bool = False) -> Dict:
    trace = generate_trace(MIXES[mix], len(db), mode=mode,
                           duration_s=duration_s, seed=seed)
    obs = None
    if span_trace:
        from repro.obs import Observability
        obs = Observability(spans=True)
    faults = None
    pipe_kw: Dict = {}
    if faulted:
        # the deterministic chaos schedule + admission control: process
        # verifiers (so worker kills are real), a bounded inbox with
        # tenant-weighted shed-oldest (DESIGN.md §18)
        from repro.serve.faults import FaultInjector
        specs, _ = fault_plan()
        faults = FaultInjector(specs, seed=seed)
        pipe_kw = dict(faults=faults, verify_executor="process",
                       inbox_limit=16, shed_policy="shed_oldest",
                       tenant_weights=tenant_weights(MIXES[mix]))
    pipe = make_pipe(db, backend=backend, workers=workers, obs=obs,
                     **pipe_kw)
    try:
        # warm the slab + caches so the first arrivals don't pay build
        # cost — the bench measures steady-state serving
        from repro.serve.graph_engine import GraphQuery
        pipe.submit(GraphQuery(db[0], 1, verify=False)).result(60)
        report = replay(trace, pipe, db, speed=speed)
    finally:
        pipe.close()
    rep = report.to_json()
    check_report(rep, faulted=faulted,
                 n_expected=len(trace.queries) if faulted else None)
    trace_path = None
    if span_trace:
        trace_path = art_path(f"serving_slo_{mix}_{mode}.trace.json")
        obs.export_trace(trace_path)
        print(f"[{mix}/{mode}] trace -> {trace_path} "
              f"({len(obs.spans)} spans, {obs.spans.dropped} dropped)")
        if validate:
            from repro.obs.export import load_trace, validate_trace
            validate_trace(load_trace(trace_path))
    o = rep["overall"]
    key = f"{mix}/{mode}" + ("/faulted" if faulted else "")
    csv.add(f"slo_{key.replace('/', '_')}_p99", o["p99_ms"] / 1e3,
            f"{o['goodput_qps']:.1f} good q/s, "
            f"{o['partial_rate'] * 100:.1f}% partial")
    print(f"[{key}] n={o['n']} (topk {o['n_topk']}) "
          f"p50={o['p50_ms']:.1f}ms p99={o['p99_ms']:.1f}ms "
          f"goodput={o['goodput_qps']:.1f} q/s "
          f"partial={o['partial_rate']:.3f} "
          f"slo_miss={o['slo_miss_rate']:.3f}"
          + (f" rejected={o['rejected']} errors={o['errors']} "
             f"faults_fired={faults.summary()['n_fired']}"
             if faulted else ""))
    rec = {"mix": mix, "mode": mode, "seed": seed,
           "n_db": len(db), "backend": backend, "workers": workers,
           "trace_digest": trace.digest(), "span_trace": trace_path,
           **rep}
    if faulted:
        rec["faulted"] = True
        rec["faults"] = faults.summary()
    return rec


def record_trajectory(recs: List[Dict], commit: str, date: str,
                      path: str = BENCH_LOG) -> Dict:
    """Append one per-PR row (per mix x loop SLO metrics) to the
    repo-root trajectory log and return it."""
    row = {
        "commit": commit, "date": date, "n_db": recs[0]["n_db"],
        "mixes": {
            f"{r['mix']}/{r['mode']}"
            + ("/faulted" if r.get("faulted") else ""): {
                "n": r["overall"]["n"],
                "p50_ms": r["overall"]["p50_ms"],
                "p99_ms": r["overall"]["p99_ms"],
                "goodput_qps": r["overall"]["goodput_qps"],
                "partial_rate": r["overall"]["partial_rate"],
                "slo_miss_rate": r["overall"]["slo_miss_rate"],
                # per-tenant stage breakdowns (DESIGN.md §17)
                "per_tenant": {name: {
                    "filter_ms": b["filter_ms"], "lb_ms": b["lb_ms"],
                    "verify_ms": b["verify_ms"], "queue_ms": b["queue_ms"],
                } for name, b in r["per_tenant"].items()},
                # fault-mode extras: the chaos row every later PR's
                # availability story is judged by (DESIGN.md §18)
                **({"faulted": True,
                    "rejected": r["overall"]["rejected"],
                    "errors": r["overall"]["errors"],
                    "faults_fired": r["faults"]["n_fired"]}
                   if r.get("faulted") else {}),
            } for r in recs},
    }
    log = []
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            log = json.load(f)
    log.append(row)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(log, f, indent=1)
    print(f"recorded {sorted(row['mixes'])} @ {commit} -> {path}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000, help="db size")
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--duration", type=float, default=0.6,
                    help="open-loop trace duration (trace seconds)")
    ap.add_argument("--speed", type=float, default=1.0,
                    help="open-loop replay speedup")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mix", default="all",
                    choices=["all", *MIXES])
    ap.add_argument("--mode", default="both",
                    choices=["both", "open", "closed"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; assert report schema only")
    ap.add_argument("--faults", action="store_true",
                    help="also replay each mix (open loop) under the "
                         "deterministic fault_plan() chaos schedule with "
                         "admission control on — asserts bounded errors "
                         "and zero stuck queries (DESIGN.md §18); "
                         "``make chaos-smoke`` wires this into CI")
    ap.add_argument("--trace", action="store_true",
                    help="record per-query spans; write one Chrome "
                         "trace-event artifact per mix/mode to "
                         "artifacts/bench/ (DESIGN.md §17)")
    ap.add_argument("--record", action="store_true",
                    help=f"append SLO metrics to {BENCH_LOG}")
    ap.add_argument("--commit", default="unknown",
                    help="commit label for --record")
    ap.add_argument("--date", default=time.strftime("%Y-%m-%d"),
                    help="date label for --record")
    args = ap.parse_args()

    if args.smoke:
        args.n = min(args.n, 300)
        args.duration = min(args.duration, 0.2)

    db = dataset("aids", args.n)
    csv = Csv()
    mixes = list(MIXES) if args.mix == "all" else [args.mix]
    modes = ["open", "closed"] if args.mode == "both" else [args.mode]
    recs = [run_mix(csv, db, mix, mode, backend=args.backend,
                    workers=args.workers, duration_s=args.duration,
                    seed=args.seed, speed=args.speed,
                    span_trace=args.trace, validate=args.smoke)
            for mix in mixes for mode in modes]
    if args.faults:
        recs += [run_mix(csv, db, mix, "open", backend=args.backend,
                         workers=args.workers, duration_s=args.duration,
                         seed=args.seed, speed=args.speed, faulted=True)
                 for mix in mixes]

    save_json("serving_slo.json", recs)
    csv.dump(art_path("serving_slo.csv"))
    if args.smoke:
        print(f"smoke OK: {len(recs)} mix/mode reports, schema checked"
              + (" (incl. faulted)" if args.faults else ""))
    if args.record:
        record_trajectory(recs, args.commit, args.date)


if __name__ == "__main__":
    main()
