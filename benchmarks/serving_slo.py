"""Mixed-tenant SLO benchmark for the async serving pipeline.

Replays deterministic seeded traffic traces (``repro.serve.traffic``,
DESIGN.md §15) — per-tenant mixes of range-τ, top-k, and deadline
queries, in open- and closed-loop arrival models — against an
``AsyncGraphQueryEngine`` and records p50/p99 latency, goodput under
each tenant's deadline SLO, and partial-result rates.

    PYTHONPATH=src python -m benchmarks.serving_slo [--n 2000] [--smoke]

``--record --commit <sha> --date <YYYY-MM-DD>`` appends one row per run
to the repo-root ``BENCH_serving_slo.json`` trajectory (same convention
as ``BENCH_query_throughput.json``): this is the serving harness every
later PR gets judged by.  ``--smoke`` runs a tiny trace and asserts the
report schema (non-empty percentiles, goodput, partial-rate, per-stage
breakdown columns) — wired into ``make bench-smoke``.  ``--trace`` turns
span recording on and writes one Chrome trace-event artifact per
mix/mode to ``artifacts/bench/`` (DESIGN.md §17); with ``--smoke`` the
artifact is schema-validated too.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List

from benchmarks.common import Csv, art_path, dataset, save_json
from repro.serve.traffic import TenantSpec, generate_trace, replay

BENCH_LOG = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serving_slo.json"))

# the two standing tenant mixes every serving PR is judged on: an
# interactive/bulk split and a deadline-heavy top-k explorer mix
MIXES: Dict[str, List[TenantSpec]] = {
    "interactive_bulk": [
        TenantSpec("interactive", weight=1.0, rate_qps=60.0, clients=3,
                   queries_per_client=6, topk_frac=0.7, k_range=(1, 4),
                   cap=4, tau_range=(1, 2), deadline_s=0.25,
                   edits_range=(1, 2)),
        TenantSpec("bulk", weight=1.0, rate_qps=25.0, clients=2,
                   queries_per_client=5, topk_frac=0.0, tau_range=(1, 3),
                   deadline_s=None, edits_range=(1, 2)),
    ],
    "topk_explorer": [
        TenantSpec("explorer", weight=1.0, rate_qps=45.0, clients=3,
                   queries_per_client=6, topk_frac=1.0, k_range=(2, 6),
                   cap=5, deadline_s=0.35, edits_range=(1, 3)),
        TenantSpec("analytics", weight=1.0, rate_qps=20.0, clients=2,
                   queries_per_client=4, topk_frac=0.3, k_range=(1, 3),
                   cap=4, tau_range=(2, 3), deadline_s=0.8,
                   edits_range=(1, 2)),
    ],
}


def make_pipe(db, *, backend: str = "numpy", workers: int = 2,
              max_batch: int = 8, obs=None):
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQueryEngine
    from repro.serve.pipeline import AsyncGraphQueryEngine
    eng = GraphQueryEngine(FlatMSQIndex(db), backend=backend,
                           result_cache_size=0, obs=obs)
    return AsyncGraphQueryEngine(eng, max_batch=max_batch,
                                 max_delay_s=0.002, num_workers=workers)


def check_report(rep: dict) -> None:
    """Schema gate (the bench-smoke assertion): percentiles present and
    finite, goodput/partial-rate/SLO fields populated, per-stage
    breakdown columns present (DESIGN.md §17)."""
    for scope, b in [("overall", rep["overall"]),
                     *rep["per_tenant"].items()]:
        assert b["n"] > 0, f"{scope}: empty bucket"
        for fld in ("p50_ms", "p99_ms"):
            assert math.isfinite(b[fld]) and b[fld] > 0, \
                f"{scope}.{fld} not a positive finite latency: {b[fld]}"
        for fld in ("goodput_qps", "partial_rate", "slo_miss_rate"):
            assert fld in b and b[fld] >= 0, f"{scope}.{fld} missing"
        for fld in ("filter_ms", "lb_ms", "verify_ms", "queue_ms"):
            assert fld in b and math.isfinite(b[fld]) and b[fld] >= 0, \
                f"{scope}.{fld} breakdown missing/invalid: {b.get(fld)}"
        assert b["errors"] == 0, f"{scope}: {b['errors']} query errors"


def run_mix(csv: Csv, db, mix: str, mode: str, *, backend: str,
            workers: int, duration_s: float, seed: int,
            speed: float, span_trace: bool = False,
            validate: bool = False) -> Dict:
    trace = generate_trace(MIXES[mix], len(db), mode=mode,
                           duration_s=duration_s, seed=seed)
    obs = None
    if span_trace:
        from repro.obs import Observability
        obs = Observability(spans=True)
    pipe = make_pipe(db, backend=backend, workers=workers, obs=obs)
    try:
        # warm the slab + caches so the first arrivals don't pay build
        # cost — the bench measures steady-state serving
        from repro.serve.graph_engine import GraphQuery
        pipe.submit(GraphQuery(db[0], 1, verify=False)).result(60)
        report = replay(trace, pipe, db, speed=speed)
    finally:
        pipe.close()
    rep = report.to_json()
    check_report(rep)
    trace_path = None
    if span_trace:
        trace_path = art_path(f"serving_slo_{mix}_{mode}.trace.json")
        obs.export_trace(trace_path)
        print(f"[{mix}/{mode}] trace -> {trace_path} "
              f"({len(obs.spans)} spans, {obs.spans.dropped} dropped)")
        if validate:
            from repro.obs.export import load_trace, validate_trace
            validate_trace(load_trace(trace_path))
    o = rep["overall"]
    key = f"{mix}/{mode}"
    csv.add(f"slo_{mix}_{mode}_p99", o["p99_ms"] / 1e3,
            f"{o['goodput_qps']:.1f} good q/s, "
            f"{o['partial_rate'] * 100:.1f}% partial")
    print(f"[{key}] n={o['n']} (topk {o['n_topk']}) "
          f"p50={o['p50_ms']:.1f}ms p99={o['p99_ms']:.1f}ms "
          f"goodput={o['goodput_qps']:.1f} q/s "
          f"partial={o['partial_rate']:.3f} "
          f"slo_miss={o['slo_miss_rate']:.3f}")
    return {"mix": mix, "mode": mode, "seed": seed,
            "n_db": len(db), "backend": backend, "workers": workers,
            "trace_digest": trace.digest(), "span_trace": trace_path,
            **rep}


def record_trajectory(recs: List[Dict], commit: str, date: str,
                      path: str = BENCH_LOG) -> Dict:
    """Append one per-PR row (per mix x loop SLO metrics) to the
    repo-root trajectory log and return it."""
    row = {
        "commit": commit, "date": date, "n_db": recs[0]["n_db"],
        "mixes": {f"{r['mix']}/{r['mode']}": {
            "n": r["overall"]["n"],
            "p50_ms": r["overall"]["p50_ms"],
            "p99_ms": r["overall"]["p99_ms"],
            "goodput_qps": r["overall"]["goodput_qps"],
            "partial_rate": r["overall"]["partial_rate"],
            "slo_miss_rate": r["overall"]["slo_miss_rate"],
            # per-tenant stage breakdowns (DESIGN.md §17)
            "per_tenant": {name: {
                "filter_ms": b["filter_ms"], "lb_ms": b["lb_ms"],
                "verify_ms": b["verify_ms"], "queue_ms": b["queue_ms"],
            } for name, b in r["per_tenant"].items()},
        } for r in recs},
    }
    log = []
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            log = json.load(f)
    log.append(row)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(log, f, indent=1)
    print(f"recorded {sorted(row['mixes'])} @ {commit} -> {path}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000, help="db size")
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--duration", type=float, default=0.6,
                    help="open-loop trace duration (trace seconds)")
    ap.add_argument("--speed", type=float, default=1.0,
                    help="open-loop replay speedup")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mix", default="all",
                    choices=["all", *MIXES])
    ap.add_argument("--mode", default="both",
                    choices=["both", "open", "closed"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; assert report schema only")
    ap.add_argument("--trace", action="store_true",
                    help="record per-query spans; write one Chrome "
                         "trace-event artifact per mix/mode to "
                         "artifacts/bench/ (DESIGN.md §17)")
    ap.add_argument("--record", action="store_true",
                    help=f"append SLO metrics to {BENCH_LOG}")
    ap.add_argument("--commit", default="unknown",
                    help="commit label for --record")
    ap.add_argument("--date", default=time.strftime("%Y-%m-%d"),
                    help="date label for --record")
    args = ap.parse_args()

    if args.smoke:
        args.n = min(args.n, 300)
        args.duration = min(args.duration, 0.2)

    db = dataset("aids", args.n)
    csv = Csv()
    mixes = list(MIXES) if args.mix == "all" else [args.mix]
    modes = ["open", "closed"] if args.mode == "both" else [args.mode]
    recs = [run_mix(csv, db, mix, mode, backend=args.backend,
                    workers=args.workers, duration_s=args.duration,
                    seed=args.seed, speed=args.speed,
                    span_trace=args.trace, validate=args.smoke)
            for mix in mixes for mode in modes]

    save_json("serving_slo.json", recs)
    csv.dump(art_path("serving_slo.csv"))
    if args.smoke:
        print(f"smoke OK: {len(recs)} mix/mode reports, schema checked")
    if args.record:
        record_trajectory(recs, args.commit, args.date)


if __name__ == "__main__":
    main()
