# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--full]``.

One entry per paper table/figure (DESIGN.md §8):
  Table 2  -> encoding_bits      (bits/entry across coders)
  Table 3  -> index_size         (T_Q vs T_SQ decomposition + build time)
  Fig 7    -> index_size sweep   (size/build vs |G| + baselines)
  Fig 8    -> filter_quality     (candidates + response time vs tau)
  Fig 10-13-> scalability        (|V_h|, |G|, |Sigma_V|, rho)
  kernels  -> kernels_bench      (hot-path micro-benchmarks + TPU model)
  dry-run  -> roofline           (summary of artifacts/dryrun, if present)
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma list: table2,table3,fig8,scal,throughput,"
                         "kernels,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks.common import Csv, art_path
    from benchmarks import (encoding_bits, filter_quality, index_size,
                            kernels_bench, query_throughput, roofline,
                            scalability)

    csv = Csv()
    full = args.full

    def want(key: str) -> bool:
        return only is None or key in only

    if want("table2"):
        encoding_bits.run(csv, {"aids": 20000 if full else 2000,
                                "s100k": 20000 if full else 1500,
                                "pubchem": 20000 if full else 2000})
    if want("table3"):
        index_size.run(csv, {"aids": 20000 if full else 2000,
                             "s100k": 20000 if full else 1500,
                             "pubchem": 20000 if full else 2000},
                       sweep=([2000, 8000, 20000, 42687] if full
                              else [500, 1000, 2000]))
    if want("fig8"):
        filter_quality.run(csv, "aids", 10000 if full else 1000,
                           taus=(1, 2, 3, 4, 5) if full else (1, 2, 3),
                           n_queries=10 if full else 4)
        filter_quality.run(csv, "s100k", 5000 if full else 600,
                           taus=(1, 2, 3), n_queries=4, verify=False)
    if want("scal"):
        scalability.vary_query_size(csv, 8000 if full else 1500)
        scalability.vary_db_size(
            csv, (2000, 8000, 20000, 50000) if full else (500, 1000, 2000))
        scalability.vary_labels(csv, 2000 if full else 600)
        scalability.vary_density(csv, 2000 if full else 600)
    if want("throughput"):
        query_throughput.run(csv, n_db=5000 if full else 1000,
                             n_queries=64 if full else 16)
    if want("kernels"):
        kernels_bench.bench_qgram_filter(csv)
        kernels_bench.bench_bitunpack(csv)
        kernels_bench.bench_rank(csv)
        kernels_bench.bench_attention(csv)
    if want("roofline"):
        try:
            roofline.summarize(csv)
        except Exception as e:  # artifacts may not exist yet
            print(f"roofline summary skipped: {e}", file=sys.stderr)

    csv.dump(art_path("bench_results.csv"))


if __name__ == "__main__":
    main()
