"""Figure 8: candidate-set size and response time vs tau, MSQ-Index against
the C-Star / Branch(Mixed) / path-q-gram baselines (per-pair filters) —
plus verification time, on the paper's query protocol (random data graphs)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Csv, dataset, queries_for, save_json
from repro.core import baselines
from repro.core.search import MSQIndex
from repro.core.verify import ged_upto


def baseline_candidates(db, h, tau: int, fn) -> int:
    cnt = 0
    for g in db:
        if fn(g, h) <= tau:
            cnt += 1
    return cnt


def run(csv: Csv, kind: str = "aids", n: int = 1500, taus=(1, 2, 3, 4, 5),
        n_queries: int = 5, verify: bool = True,
        with_baselines: bool = True) -> Dict:
    db = dataset(kind, n)
    idx = MSQIndex(db)
    queries = queries_for(db, num=n_queries)
    out = {"kind": kind, "n": n, "taus": {}}
    for tau in taus:
        cand_sizes, f_times, v_times, match_counts = [], [], [], []
        b_counts = {"cstar": [], "branch": [], "path": []}
        b_times = {"cstar": [], "branch": [], "path": []}
        for h in queries:
            res = idx.query(h, tau, verify=verify)
            cand_sizes.append(len(res.candidates))
            f_times.append(res.filter_time_s)
            v_times.append(res.verify_time_s)
            match_counts.append(len(res.matches))
            if with_baselines:
                for name, fn in (("cstar", baselines.cstar_lb),
                                 ("branch", baselines.branch_lb),
                                 ("path", baselines.path_qgram_lb)):
                    t0 = time.perf_counter()
                    b_counts[name].append(baseline_candidates(db, h, tau, fn))
                    b_times[name].append(time.perf_counter() - t0)
        rec = {
            "msq_candidates": float(np.mean(cand_sizes)),
            "msq_matches": float(np.mean(match_counts)),
            "msq_filter_s": float(np.mean(f_times)),
            "msq_verify_s": float(np.mean(v_times)),
        }
        if with_baselines:
            for name in b_counts:
                rec[f"{name}_candidates"] = float(np.mean(b_counts[name]))
                rec[f"{name}_filter_s"] = float(np.mean(b_times[name]))
        out["taus"][tau] = rec
        csv.add(f"fig8/{kind}/tau{tau}/msq_candidates",
                rec["msq_filter_s"], round(rec["msq_candidates"], 1))
        if with_baselines:
            csv.add(f"fig8/{kind}/tau{tau}/cstar_candidates",
                    rec["cstar_filter_s"], round(rec["cstar_candidates"], 1))
            csv.add(f"fig8/{kind}/tau{tau}/branch_candidates",
                    rec["branch_filter_s"], round(rec["branch_candidates"], 1))
    save_json(f"fig8_filter_quality_{kind}.json", out)
    return out


def main() -> None:
    csv = Csv()
    run(csv, "aids", 1500)
    run(csv, "s100k", 800, taus=(1, 2, 3), verify=False)


if __name__ == "__main__":
    main()
