"""Table 3 + Figure 7: index storage (T_Q vs T_SQ decomposed into
S_a/S_b/S_c), construction time, and the size-vs-|G| sweep against the
C-Star / Branch(Mixed) / path-q-gram baselines.

Also measures the *serving* formats: bits-per-graph of the F_D carrier
for each FilterSlab layout (dense vs hot vs packed, DESIGN.md §11) — the
space-reduction claim on the form the filter pass actually runs against,
not just the archival trees."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Csv, dataset, save_json, timer
from repro.core import baselines
from repro.core.search import MSQIndex
from repro.core.slab import FilterSlab


def serving_slab_sizes(idx: MSQIndex, hot_d: int = 128) -> Dict:
    """Bits-per-graph of the three serving slab layouts over one DB."""
    out: Dict[str, Dict] = {}
    for layout in ("dense", "hot", "packed"):
        slab = FilterSlab.build(idx.db, idx.enc, idx.partition,
                                layout=layout, hot_d=hot_d)
        bits = slab.size_bits()
        out[layout] = {"bits_per_graph": round(slab.bits_per_graph(), 1),
                       "parts_bits": bits}
    dense_bpg = out["dense"]["bits_per_graph"]
    for layout in ("hot", "packed"):
        out[layout]["vs_dense"] = round(
            out[layout]["bits_per_graph"] / max(dense_bpg, 1e-9), 4)
    return out


def run(csv: Csv, sizes: Dict[str, int], sweep: List[int] = ()) -> Dict:
    out = {}
    for kind, n in sizes.items():
        db = dataset(kind, n)
        idx, build_s = timer(MSQIndex, db)
        sq = idx.size_bits()
        q = idx.plain_size_bits()
        mb = 1 / 8 / 2 ** 20
        rec = {
            "graphs": n,
            "T_Q_MB": {k: round(v * mb, 4) for k, v in q.items()},
            "T_SQ_MB": {k: round(v * mb, 4) for k, v in sq.items()},
            "reduction": round(1 - sq["total"] / q["total"], 4),
            "freq_reduction": round(
                1 - (sq["S_b"] + sq["S_c"]) / (q["S_b"] + q["S_c"]), 4),
            "build_seconds": round(build_s, 2),
            "baseline_MB": {
                "cstar": round(baselines.cstar_index_bits(db) * mb, 4),
                "branch_mixed": round(baselines.branch_index_bits(db) * mb, 4),
                "path_gsimjoin": round(baselines.path_index_bits(db) * mb, 4),
            },
        }
        rec["serving_slab"] = serving_slab_sizes(idx)
        out[kind] = rec
        csv.add(f"table3/{kind}/tsq_total_MB", build_s, rec["T_SQ_MB"]["total"])
        csv.add(f"table3/{kind}/space_reduction", 0.0, rec["reduction"])
        csv.add(f"table3/{kind}/vs_branch_ratio", 0.0,
                round(sq["total"] * mb / rec["baseline_MB"]["branch_mixed"], 4))
        for layout, s in rec["serving_slab"].items():
            csv.add(f"table3/{kind}/slab_{layout}_bits_per_graph", 0.0,
                    s["bits_per_graph"])
        csv.add(f"table3/{kind}/slab_packed_vs_dense", 0.0,
                rec["serving_slab"]["packed"]["vs_dense"])
    if sweep:
        rows = []
        for n in sweep:
            db = dataset("aids", n)
            idx, build_s = timer(MSQIndex, db)
            bits = idx.size_bits()["total"]
            rows.append({"n": n, "tsq_MB": bits / 8 / 2 ** 20,
                         "build_s": build_s,
                         "branch_MB": baselines.branch_index_bits(db) / 8 / 2 ** 20,
                         "cstar_MB": baselines.cstar_index_bits(db) / 8 / 2 ** 20})
            csv.add(f"fig7/aids_n{n}/tsq_MB", build_s,
                    round(bits / 8 / 2 ** 20, 4))
        out["fig7_sweep"] = rows
    save_json("table3_index_size.json", out)
    return out


def main() -> None:
    from benchmarks.common import art_path
    csv = Csv()
    run(csv, {"aids": 3000, "s100k": 2000, "pubchem": 3000},
        sweep=[500, 1000, 2000, 4000])
    csv.dump(art_path("table3_index_size.csv"))


if __name__ == "__main__":
    main()
