"""Batched GraphQueryEngine vs looped single-query baseline.

The serving claim of the engine subsystem: a 64-query batch over a >= 5k
graph DB answers at >= 2x the queries/sec of looping ``FlatMSQIndex.query``
— with *identical* candidate sets (asserted here, not assumed).

    PYTHONPATH=src python -m benchmarks.query_throughput [--n 5000] [--q 64]

``--layout {dense,hot,packed,all}`` picks the serving FilterSlab layout
(DESIGN.md §11); ``all`` measures every layout with identical-candidate
assertions and records the space/speed comparison (bits-per-graph of the
resident F_D carrier vs q/s) to
``artifacts/bench/query_throughput_layouts.{csv,json}``.

``--sharded`` additionally runs the ``ShardedGraphQueryEngine`` on a
simulated multi-device CPU mesh (``--devices``, default 8) in both the
graph- and vocab-sharded layouts (``--sharded-layout``), asserts candidate
parity against the single-host engine, and records single-host vs sharded
numbers to ``artifacts/bench/query_throughput_sharded.{csv,json}`` (same
schema).  On fake CPU devices this measures the orchestration overhead
floor, not a speedup — the per-device win needs real accelerators
(DESIGN.md §10).

``--pipeline`` measures the async pipelined engine (DESIGN.md §12) with
verification ON: synchronous ``submit`` vs ``AsyncGraphQueryEngine``
(``--pipeline-workers`` verifiers, batches of ``--pipeline-batch``),
asserts bit-identical results, and records overlap-efficiency — how much
of the device filter time ran *while* verification was in flight — to
``artifacts/bench/query_throughput_pipeline.{csv,json}``.

``--obs-overhead`` measures span-recording overhead (DESIGN.md §17):
engine q/s with spans off vs on, identical candidates asserted, recorded
to ``artifacts/bench/query_throughput_obs.json`` (budget: <= 2% loss).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Csv, art_path, dataset, save_json

# the per-PR perf trajectory lives at the repo root so regressions are a
# one-file diff review away (``--record``, DESIGN.md §13)
BENCH_LOG = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_query_throughput.json"))


def record_trajectory(recs: List[Dict], commit: str, date: str,
                      path: str = BENCH_LOG,
                      verified: Dict = None) -> Dict:
    """Append one per-PR row (q/s per backend x layout, plus the
    verified-q/s LB on/off section when measured) to the repo-root
    trajectory log and return it."""
    row = {
        "commit": commit, "date": date,
        "n_db": recs[0]["n_db"], "n_queries": recs[0]["n_queries"],
        "qps_loop": recs[0]["qps_loop"],
        "qps": {f"{r['backend']}/{r['slab']}": round(r["qps_batched"], 1)
                for r in recs},
    }
    if verified is not None:
        row["verified"] = {
            "dataset": verified["dataset"], "tau": verified["tau"],
            "n_queries": verified["n_queries"],
            "qps_off": round(verified["qps_verified_off"], 3),
            "qps_on": round(verified["qps_verified_on"], 3),
            "speedup": round(verified["verified_speedup"], 2),
            "lb_pruned": verified["lb_pruned"],
            "identical_matches": verified["identical_matches"],
        }
    log = []
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            log = json.load(f)
    log.append(row)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(log, f, indent=1)
    print(f"recorded {row['qps']} @ {commit} -> {path}")
    return row


def make_queries(db, num: int, seed: int = 1):
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(db), size=num, replace=True)
    taus = rng.integers(1, 4, size=num)
    graphs = [perturb_graph(db[int(i)], int(t), rng, db.n_vlabels,
                            db.n_elabels) for i, t in zip(idx, taus)]
    return graphs, [int(t) for t in taus]


def run(csv: Csv, n_db: int = 5000, n_queries: int = 64,
        backend: str = "auto", repeats: int = 3,
        slab: str = "dense", hot_d: int = 128) -> Dict:
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    db = dataset("aids", n_db)
    flat = FlatMSQIndex(db)
    graphs, taus = make_queries(db, n_queries)
    reqs = [GraphQuery(g, t, verify=False) for g, t in zip(graphs, taus)]

    # looped per-query baseline (candidate generation only; verification
    # cost is identical on both paths).  Warm once, then best-of-repeats —
    # the same protocol as the engine path below, so qps_loop is
    # comparable across --record rows instead of drifting with whatever
    # first-pass cache/alloc effects the host happens to have.
    base = [flat.query(g, t, verify=False).candidates
            for g, t in zip(graphs, taus)]              # warm
    t_loops = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        base = [flat.query(g, t, verify=False).candidates
                for g, t in zip(graphs, taus)]
        t_loops.append(time.perf_counter() - t0)
    t_loop = min(t_loops)

    # result_cache_size=0: every timed submit does the real filter work
    engine = GraphQueryEngine(flat, backend=backend, result_cache_size=0,
                              slab_layout=slab, hot_d=hot_d)
    engine.submit(reqs)                      # warm: builds the slab, jits
    t_batch = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = engine.submit(reqs)
        t_batch.append(time.perf_counter() - t0)
    t_eng = min(t_batch)

    for got, want in zip(out, base):
        assert got.candidates == want, "candidate sets diverged"

    slab_bits = flat.filter_eval(engine.backend, slab=slab,
                                 hot_d=hot_d).slab.bits_per_graph()
    qps_loop = n_queries / t_loop
    qps_eng = n_queries / t_eng
    speedup = qps_eng / qps_loop
    csv.add(f"throughput_loop_n{n_db}_q{n_queries}", t_loop / n_queries,
            f"{qps_loop:.1f} q/s")
    csv.add(f"throughput_batched_{engine.backend}_{slab}_n{n_db}"
            f"_q{n_queries}",
            t_eng / n_queries, f"{qps_eng:.1f} q/s ({speedup:.1f}x)")
    rec = {"n_db": n_db, "n_queries": n_queries,
           "backend": engine.backend, "slab": slab,
           "slab_bits_per_graph": slab_bits,
           "qps_loop": qps_loop, "qps_batched": qps_eng,
           "speedup": speedup, "identical_candidates": True}
    print(f"batched engine [{engine.backend}/{slab}]: {qps_eng:.1f} q/s vs "
          f"looped {qps_loop:.1f} q/s -> {speedup:.2f}x "
          f"({slab_bits:.0f} slab bits/graph, identical candidate sets)")
    return rec


def run_obs_overhead(csv: Csv, n_db: int = 5000, n_queries: int = 64,
                     backend: str = "auto", repeats: int = 5,
                     slab: str = "dense") -> Dict:
    """Tracing overhead: engine q/s with span recording OFF (the default
    ``Observability``) vs ON (DESIGN.md §17), same warm + best-of-repeats
    protocol as ``run`` and identical candidate sets asserted.  The PR
    acceptance budget is <= 2% q/s loss with spans on."""
    from repro.core.search import FlatMSQIndex
    from repro.obs import Observability
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    db = dataset("aids", n_db)
    flat = FlatMSQIndex(db)
    graphs, taus = make_queries(db, n_queries)
    reqs = [GraphQuery(g, t, verify=False) for g, t in zip(graphs, taus)]

    def rate(obs):
        eng = GraphQueryEngine(flat, backend=backend, result_cache_size=0,
                               slab_layout=slab, obs=obs)
        eng.submit(reqs)                     # warm: builds the slab, jits
        best, out = np.inf, None
        for _ in range(repeats):
            t0 = time.perf_counter()
            o = eng.submit(reqs)
            dt = time.perf_counter() - t0
            if dt < best:
                best, out = dt, o
        return n_queries / best, out

    qps_off, ref = rate(None)                # default: spans disabled
    obs_on = Observability(spans=True)
    qps_on, got = rate(obs_on)
    for a, b in zip(got, ref):
        assert a.candidates == b.candidates, "candidate sets diverged"

    overhead_pct = (qps_off - qps_on) / qps_off * 100.0
    rec = {"n_db": n_db, "n_queries": n_queries, "backend": backend,
           "slab": slab, "qps_obs_off": qps_off, "qps_obs_on": qps_on,
           "overhead_pct": overhead_pct,
           "spans_recorded": len(obs_on.spans),
           "identical_candidates": True}
    csv.add(f"obs_off_n{n_db}_q{n_queries}", 1.0 / qps_off,
            f"{qps_off:.1f} q/s")
    csv.add(f"obs_on_n{n_db}_q{n_queries}", 1.0 / qps_on,
            f"{qps_on:.1f} q/s ({overhead_pct:+.2f}%)")
    print(f"obs overhead [{slab}]: spans on {qps_on:.1f} q/s vs off "
          f"{qps_off:.1f} q/s -> {overhead_pct:+.2f}% "
          f"({rec['spans_recorded']} spans recorded, identical "
          f"candidate sets)")
    return rec


def run_verified(csv: Csv, n_db: int = 5000, n_queries: int = 16,
                 backend: str = "auto", tau: int = 6,
                 repeats: int = 2) -> Dict:
    """Verified q/s (filter + A* verification end-to-end) with the
    stage-1.5 assignment lower bound off vs on (DESIGN.md §16).

    Runs on the label-poor graphgen DB ('s100k', 5 vertex labels) at a
    verification-heavy tau: the q-gram filter admits hundreds of
    candidates per query whose true GED is far above tau, and the A*
    exhaustion bill on those non-matches dominates wall time.  The
    branch bound prices exactly that gap, so the LB pass prunes the
    worklist before a single A* node expands.  Match sets are asserted
    bit-identical — the bound is provable, it moves work, not recall.
    """
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import perturb_graph
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    db = dataset("s100k", n_db)
    flat = FlatMSQIndex(db)
    rng = np.random.default_rng(2)
    idx = rng.choice(len(db), size=n_queries, replace=False)
    graphs = [perturb_graph(db[int(i)], max(tau // 2, 1), rng,
                            db.n_vlabels, db.n_elabels) for i in idx]
    reqs = [GraphQuery(g, tau, verify=True) for g in graphs]

    def rate(assign_lb: bool):
        eng = GraphQueryEngine(flat, backend=backend, result_cache_size=0,
                               assign_lb=assign_lb)
        eng.submit([GraphQuery(g, tau, verify=False)    # warm: slab + jit
                    for g in graphs[:4]])
        best, out = np.inf, None
        for _ in range(repeats):
            t0 = time.perf_counter()
            o = eng.submit(reqs)
            dt = time.perf_counter() - t0
            if dt < best:
                best, out = dt, o
        return n_queries / best, out, dict(eng.stats)

    qps_off, ref, _ = rate(False)
    qps_on, got, st = rate(True)
    for a, b in zip(got, ref):
        assert a.candidates == b.candidates, "candidate sets diverged"
        assert a.matches == b.matches, "match sets diverged (LB unsound?)"

    speedup = qps_on / qps_off
    rec = {"dataset": "s100k", "n_db": n_db, "n_queries": n_queries,
           "tau": tau,
           "qps_verified_off": qps_off, "qps_verified_on": qps_on,
           "verified_speedup": speedup,
           "lb_pruned": st.get("lb_pruned", 0),
           "lb_tightened": st.get("lb_tightened", 0),
           "verified_pairs_on": st.get("verified_pairs", 0),
           "identical_matches": True}
    csv.add(f"verified_lb_off_s100k_n{n_db}_q{n_queries}_t{tau}",
            1.0 / qps_off, f"{qps_off:.2f} q/s")
    csv.add(f"verified_lb_on_s100k_n{n_db}_q{n_queries}_t{tau}",
            1.0 / qps_on, f"{qps_on:.2f} q/s ({speedup:.1f}x)")
    print(f"verified q/s [s100k n={n_db} tau={tau}]: LB on "
          f"{qps_on:.2f} q/s vs off {qps_off:.2f} q/s -> {speedup:.2f}x "
          f"({rec['lb_pruned']} pairs pruned before A*, identical "
          f"match sets)")
    return rec


def run_sharded(csv: Csv, n_db: int = 5000, n_queries: int = 64,
                layout: str = "graph", model_parallel: int = 1,
                repeats: int = 3, slab: str = "dense",
                hot_d: int = 128) -> Dict:
    """Single-host (numpy) vs sharded engine on the host's device mesh;
    identical candidates asserted, both rates recorded."""
    from repro.core.search import FlatMSQIndex
    from repro.launch.mesh import make_serving_mesh
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = dataset("aids", n_db)
    graphs, taus = make_queries(db, n_queries)
    reqs = [GraphQuery(g, t, verify=False) for g, t in zip(graphs, taus)]

    def rate(engine) -> float:
        engine.submit(reqs)                  # warm: builds arrays, jits
        best = min(_timed(engine, reqs) for _ in range(repeats))
        return n_queries / best

    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy",
                              result_cache_size=0)
    sharded = ShardedGraphQueryEngine(
        FlatMSQIndex(db), make_serving_mesh(model_parallel), layout=layout,
        slab_layout=slab, hot_d=hot_d, result_cache_size=0)
    qps_single = rate(single)
    qps_sharded = rate(sharded)
    ref = single.submit(reqs)
    got = sharded.submit(reqs)
    for a, b in zip(got, ref):
        assert a.candidates == b.candidates, "candidate sets diverged"

    import jax
    devices = len(jax.devices())
    speedup = qps_sharded / qps_single
    csv.add(f"throughput_single_host_n{n_db}_q{n_queries}",
            1.0 / qps_single, f"{qps_single:.1f} q/s")
    csv.add(f"throughput_sharded_{layout}_{slab}_d{devices}_n{n_db}"
            f"_q{n_queries}",
            1.0 / qps_sharded, f"{qps_sharded:.1f} q/s ({speedup:.2f}x)")
    rec = {"n_db": n_db, "n_queries": n_queries, "devices": devices,
           "layout": layout, "slab": slab,
           "model_parallel": model_parallel,
           "qps_single_host": qps_single, "qps_sharded": qps_sharded,
           "speedup": speedup, "identical_candidates": True,
           "shard_stats": sharded.shard_stats}
    print(f"sharded engine [{layout}/{slab}, {devices} devices]: "
          f"{qps_sharded:.1f} q/s vs single-host {qps_single:.1f} q/s "
          f"-> {speedup:.2f}x (identical candidate sets)")
    return rec


def _timed(engine, reqs) -> float:
    t0 = time.perf_counter()
    engine.submit(reqs)
    return time.perf_counter() - t0


def _union_length(spans) -> float:
    """Total length of the union of (start, end) spans."""
    total = 0.0
    end = -np.inf
    for s, e in sorted(spans):
        if e <= end:
            continue
        total += e - max(s, end)
        end = e
    return total


def _overlap_length(a, b) -> float:
    """Length of intersection(union(a), union(b)) by two-pointer merge."""
    def merged(spans):
        out = []
        for s, e in sorted(spans):
            if out and s <= out[-1][1]:
                out[-1][1] = max(out[-1][1], e)
            else:
                out.append([s, e])
        return out

    am, bm = merged(a), merged(b)
    i = j = 0
    total = 0.0
    while i < len(am) and j < len(bm):
        lo = max(am[i][0], bm[j][0])
        hi = min(am[i][1], bm[j][1])
        if hi > lo:
            total += hi - lo
        if am[i][1] <= bm[j][1]:
            i += 1
        else:
            j += 1
    return total


def run_pipeline(csv: Csv, n_db: int = 5000, n_queries: int = 64,
                 backend: str = "auto", workers: int = 2,
                 max_batch: int = 0, repeats: int = 1) -> Dict:
    """Sync submit vs the async pipelined engine, verification ON, with
    filter/verify overlap accounting (device busy during verification)."""
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine
    from repro.serve.pipeline import AsyncGraphQueryEngine

    db = dataset("aids", n_db)
    flat = FlatMSQIndex(db)
    graphs, taus = make_queries(db, n_queries)
    reqs = [GraphQuery(g, t, verify=True) for g, t in zip(graphs, taus)]
    max_batch = max_batch or max(4, n_queries // 8)

    sync = GraphQueryEngine(flat, backend=backend, result_cache_size=0)
    sync.submit([GraphQuery(g, t, verify=False)       # warm: slab + jit
                 for g, t in zip(graphs[:4], taus[:4])])
    t0 = time.perf_counter()
    ref = sync.submit(reqs)
    wall_sync = time.perf_counter() - t0

    wall_async = np.inf
    for _ in range(repeats):
        eng = GraphQueryEngine(flat, backend=backend, result_cache_size=0)
        run_pipe = AsyncGraphQueryEngine(eng, max_batch=max_batch,
                                         max_delay_s=0.002,
                                         num_workers=workers,
                                         record_intervals=True)
        t0 = time.perf_counter()
        tickets = run_pipe.submit_many(reqs)
        run_out = [t.result(timeout=600) for t in tickets]
        wall = time.perf_counter() - t0
        run_pipe.close()
        if wall < wall_async:   # keep wall + intervals from the same run
            wall_async, apipe, out = wall, run_pipe, run_out

    for got, want in zip(out, ref):
        assert got.candidates == want.candidates, "candidate sets diverged"
        assert got.matches == want.matches, "match sets diverged"

    filter_busy = _union_length(apipe.filter_intervals)
    verify_busy = _union_length(apipe.verify_intervals)
    overlap = _overlap_length(apipe.filter_intervals, apipe.verify_intervals)
    qps_sync = n_queries / wall_sync
    qps_async = n_queries / wall_async
    rec = {"n_db": n_db, "n_queries": n_queries, "backend": eng.backend,
           "workers": workers, "max_batch": max_batch,
           "wall_sync_s": wall_sync, "wall_async_s": wall_async,
           "qps_sync": qps_sync, "qps_async": qps_async,
           "speedup": qps_async / qps_sync,
           "filter_busy_s": filter_busy, "verify_busy_s": verify_busy,
           "overlap_s": overlap,
           # fraction of device-filter time that ran while A* verification
           # was simultaneously in flight (the pipelining claim)
           "overlap_frac_of_filter": overlap / max(filter_busy, 1e-12),
           "pipeline_efficiency": (filter_busy + verify_busy)
                                  / max(wall_async, 1e-12),
           "identical_results": True}
    csv.add(f"pipeline_sync_{eng.backend}_n{n_db}_q{n_queries}",
            wall_sync / n_queries, f"{qps_sync:.1f} q/s")
    csv.add(f"pipeline_async_{eng.backend}_w{workers}_b{max_batch}"
            f"_n{n_db}_q{n_queries}",
            wall_async / n_queries,
            f"{qps_async:.1f} q/s ({rec['speedup']:.2f}x) "
            f"overlap {overlap * 1e3:.1f}ms "
            f"({rec['overlap_frac_of_filter'] * 100:.0f}% of filter)")
    print(f"pipelined engine [{eng.backend}, {workers} workers]: "
          f"{qps_async:.1f} q/s vs sync {qps_sync:.1f} q/s "
          f"({rec['speedup']:.2f}x); filter busy {filter_busy * 1e3:.1f}ms, "
          f"verify busy {verify_busy * 1e3:.1f}ms, overlap "
          f"{overlap * 1e3:.1f}ms "
          f"({rec['overlap_frac_of_filter'] * 100:.0f}% of filter time had "
          f"verification in flight); identical results")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "numpy", "jax", "pallas"])
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "hot", "packed", "all"],
                    help="serving FilterSlab layout (DESIGN.md §11); "
                         "'all' measures every layout and records the "
                         "space/speed comparison")
    ap.add_argument("--hot-d", type=int, default=128,
                    help="hot-prefix width of the 'hot' slab layout")
    ap.add_argument("--sharded", action="store_true",
                    help="also measure ShardedGraphQueryEngine on a "
                         "multi-device CPU mesh (both sharding layouts)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--sharded-layout", default="both",
                    choices=["both", "graph", "vocab"])
    ap.add_argument("--pipeline", action="store_true",
                    help="measure AsyncGraphQueryEngine (verification ON) "
                         "with filter/verify overlap accounting "
                         "(DESIGN.md §12)")
    ap.add_argument("--pipeline-workers", type=int, default=2)
    ap.add_argument("--pipeline-batch", type=int, default=0,
                    help="async batch-former size (0 = n_queries // 8)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="measure span-recording overhead: engine q/s "
                         "with spans off vs on (DESIGN.md §17; budget "
                         "is <= 2%% q/s loss)")
    ap.add_argument("--verified", action="store_true",
                    help="also measure verified q/s (A* verification ON) "
                         "with the stage-1.5 assignment LB off vs on "
                         "(DESIGN.md §16) on the verification-heavy "
                         "s100k workload")
    ap.add_argument("--verified-q", type=int, default=16)
    ap.add_argument("--verified-tau", type=int, default=6,
                    help="tau for the verified section (6 is "
                         "verification-heavy on s100k: the filter admits "
                         "~100+ candidates/query, almost all non-matches)")
    ap.add_argument("--record", action="store_true",
                    help="append this run (q/s per backend x layout) to "
                         "the repo-root BENCH_query_throughput.json "
                         "perf trajectory")
    ap.add_argument("--commit", default="unknown",
                    help="commit label for --record")
    ap.add_argument("--date", default=time.strftime("%Y-%m-%d"),
                    help="date label for --record")
    args = ap.parse_args()
    if args.sharded:
        # must land before the first jax import: jax locks the device
        # count on backend init.  Append to any pre-set XLA_FLAGS — a
        # setdefault would silently drop the device-count override.
        import os
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        have = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in have:
            os.environ["XLA_FLAGS"] = f"{have} {flag}".strip()
    csv = Csv()
    slabs = (["dense", "hot", "packed"] if args.layout == "all"
             else [args.layout])
    recs = [run(csv, n_db=args.n, n_queries=args.q, backend=args.backend,
                slab=s, hot_d=args.hot_d) for s in slabs]
    save_json("query_throughput.json", recs[0])
    if args.obs_overhead:
        orec = run_obs_overhead(csv, n_db=args.n, n_queries=args.q,
                                backend=args.backend, slab=slabs[0])
        save_json("query_throughput_obs.json", orec)
    vrec = None
    if args.verified:
        vrec = run_verified(csv, n_db=args.n, n_queries=args.verified_q,
                            backend=args.backend, tau=args.verified_tau)
        save_json("query_throughput_verified.json", vrec)
    csv.dump(art_path("query_throughput.csv"))
    if args.record:
        record_trajectory(recs, args.commit, args.date, verified=vrec)
    if len(recs) > 1:
        # the space/speed trade-off on the serving format, one row per
        # layout (bits-per-graph of the resident F_D carrier vs q/s)
        save_json("query_throughput_layouts.json", recs)
        lcsv = Csv()
        for r in recs:
            lcsv.add(f"layout_{r['slab']}_n{args.n}_q{args.q}",
                     1.0 / r["qps_batched"],
                     f"{r['qps_batched']:.1f} q/s @ "
                     f"{r['slab_bits_per_graph']:.0f} bits/graph")
        lcsv.dump(art_path("query_throughput_layouts.csv"))
    if args.pipeline:
        pcsv = Csv()
        prec = run_pipeline(pcsv, n_db=args.n, n_queries=args.q,
                            backend=args.backend,
                            workers=args.pipeline_workers,
                            max_batch=args.pipeline_batch)
        save_json("query_throughput_pipeline.json", prec)
        pcsv.dump(art_path("query_throughput_pipeline.csv"))
    if args.sharded:
        layouts = {"both": ["graph", "vocab"], "graph": ["graph"],
                   "vocab": ["vocab"]}[args.sharded_layout]
        sharded_csv = Csv()
        srecs = []
        for lay in layouts:
            # vocab sharding needs a 'model' axis of >= 2 devices
            mp = max(args.devices // 2, 2) if lay == "vocab" else 1
            if lay == "vocab" and (args.devices < 2 or args.devices % mp):
                print(f"skipping vocab layout: {args.devices} devices "
                      f"don't split into a (data, model={mp}) mesh")
                continue
            if len(slabs) > 1:
                print(f"sharded section measures slab {slabs[0]!r} only "
                      f"(one slab per --sharded run)")
            slab = slabs[0]
            if lay == "vocab" and slab == "packed":
                # packed has no vocab dim to shard over 'model'
                print("vocab sharding cannot split the packed slab; "
                      "measuring dense instead for this layout")
                slab = "dense"
            srecs.append(run_sharded(sharded_csv, n_db=args.n,
                                     n_queries=args.q, layout=lay,
                                     model_parallel=mp, slab=slab,
                                     hot_d=args.hot_d))
        save_json("query_throughput_sharded.json", srecs)
        sharded_csv.dump(art_path("query_throughput_sharded.csv"))


if __name__ == "__main__":
    main()
