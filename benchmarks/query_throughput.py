"""Batched GraphQueryEngine vs looped single-query baseline.

The serving claim of the engine subsystem: a 64-query batch over a >= 5k
graph DB answers at >= 2x the queries/sec of looping ``FlatMSQIndex.query``
— with *identical* candidate sets (asserted here, not assumed).

    PYTHONPATH=src python -m benchmarks.query_throughput [--n 5000] [--q 64]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Csv, art_path, dataset, save_json


def make_queries(db, num: int, seed: int = 1):
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(db), size=num, replace=True)
    taus = rng.integers(1, 4, size=num)
    graphs = [perturb_graph(db[int(i)], int(t), rng, db.n_vlabels,
                            db.n_elabels) for i, t in zip(idx, taus)]
    return graphs, [int(t) for t in taus]


def run(csv: Csv, n_db: int = 5000, n_queries: int = 64,
        backend: str = "auto", repeats: int = 3) -> Dict:
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    db = dataset("aids", n_db)
    flat = FlatMSQIndex(db)
    graphs, taus = make_queries(db, n_queries)
    reqs = [GraphQuery(g, t, verify=False) for g, t in zip(graphs, taus)]

    # looped per-query baseline (candidate generation only; verification
    # cost is identical on both paths)
    t0 = time.perf_counter()
    base = [flat.query(g, t, verify=False).candidates
            for g, t in zip(graphs, taus)]
    t_loop = time.perf_counter() - t0

    engine = GraphQueryEngine(flat, backend=backend)
    engine.submit(reqs)                      # warm: builds DBArrays, jits
    engine._res_cache = type(engine._res_cache)(0)   # defeat result cache
    t_batch = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = engine.submit(reqs)
        t_batch.append(time.perf_counter() - t0)
    t_eng = min(t_batch)

    for got, want in zip(out, base):
        assert got.candidates == want, "candidate sets diverged"

    qps_loop = n_queries / t_loop
    qps_eng = n_queries / t_eng
    speedup = qps_eng / qps_loop
    csv.add(f"throughput_loop_n{n_db}_q{n_queries}", t_loop / n_queries,
            f"{qps_loop:.1f} q/s")
    csv.add(f"throughput_batched_{engine.backend}_n{n_db}_q{n_queries}",
            t_eng / n_queries, f"{qps_eng:.1f} q/s ({speedup:.1f}x)")
    rec = {"n_db": n_db, "n_queries": n_queries,
           "backend": engine.backend,
           "qps_loop": qps_loop, "qps_batched": qps_eng,
           "speedup": speedup, "identical_candidates": True}
    print(f"batched engine [{engine.backend}]: {qps_eng:.1f} q/s vs "
          f"looped {qps_loop:.1f} q/s -> {speedup:.2f}x "
          f"(identical candidate sets)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "numpy", "jax", "pallas"])
    args = ap.parse_args()
    csv = Csv()
    rec = run(csv, n_db=args.n, n_queries=args.q, backend=args.backend)
    save_json("query_throughput.json", rec)
    csv.dump(art_path("query_throughput.csv"))


if __name__ == "__main__":
    main()
