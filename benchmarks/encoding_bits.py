"""Table 2: average bits/entry of Psi_D and Psi_L under fixed-length,
Golomb, Elias delta, Elias gamma and the paper's hybrid encoding."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import Csv, dataset, save_json, timer
from repro.core.qgrams import EncodedDB
from repro.core.region import default_partition, group_by_region
from repro.core.succinct import encoded_bits_per_entry
from repro.core.tree import QGramTree, leaves_from_encoded

SCHEMES = ("fixed", "golomb", "delta", "gamma", "hybrid", "hybrid3")


def psi_values(db):
    """All Psi_D / Psi_L values of the region trees (leaves + unions)."""
    enc = EncodedDB.build(db)
    nv, ne = db.sizes()
    part = default_partition(nv, ne, l=4)
    psi_d, psi_l = [], []
    for key, gids in group_by_region(part, nv, ne).items():
        tree = QGramTree(leaves_from_encoded(enc, gids), fanout=8)
        for node in tree.nodes:
            psi_d.extend(v for _, v in sorted(node.f_d.items()))
            psi_l.extend(v for _, v in sorted(node.f_l.items()))
    return psi_d, psi_l


def run(csv: Csv, sizes: Dict[str, int]) -> Dict:
    out = {}
    for kind, n in sizes.items():
        db = dataset(kind, n)
        (pd, pl), dt = timer(psi_values, db)
        row = {"Psi_D": {}, "Psi_L": {}}
        for scheme in SCHEMES:
            row["Psi_D"][scheme] = round(
                encoded_bits_per_entry(pd, scheme, block=16), 3)
            row["Psi_L"][scheme] = round(
                encoded_bits_per_entry(pl, scheme, block=16), 3)
        out[kind] = row
        csv.add(f"table2/{kind}/psi_d_hybrid_bits", dt,
                row["Psi_D"]["hybrid"])
        csv.add(f"table2/{kind}/psi_l_hybrid_bits", dt,
                row["Psi_L"]["hybrid"])
        # paper claim: hybrid <= min(fixed, gamma) — its two components
        comp = min(row["Psi_D"]["fixed"], row["Psi_D"]["gamma"])
        csv.add(f"table2/{kind}/hybrid_le_components", 0.0,
                f"{row['Psi_D']['hybrid']:.2f}<={comp:.2f}:"
                f"{row['Psi_D']['hybrid'] <= comp + 1e-9}")
        # beyond-paper: hybrid3 <= every single-scheme column
        best = min(v for k, v in row["Psi_D"].items()
                   if k not in ("hybrid", "hybrid3"))
        csv.add(f"table2/{kind}/hybrid3_le_all", 0.0,
                f"{row['Psi_D']['hybrid3']:.2f}<={best:.2f}+flag:"
                f"{row['Psi_D']['hybrid3'] <= best + 2 / 16 + 1e-9}")
    save_json("table2_encoding_bits.json", out)
    return out


def main() -> None:
    csv = Csv()
    run(csv, {"aids": 3000, "s100k": 2000, "pubchem": 3000})


if __name__ == "__main__":
    main()
