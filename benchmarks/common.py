"""Shared benchmark plumbing: cached datasets, timing, CSV emission."""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def art_path(name: str) -> str:
    os.makedirs(ART, exist_ok=True)
    return os.path.join(ART, name)


@functools.lru_cache(maxsize=8)
def dataset(kind: str, n: int, seed: int = 0):
    from repro.graphs.generators import aids_like_db, graphgen_db
    if kind == "aids":
        return aids_like_db(n, seed=seed)
    if kind == "s100k":
        return graphgen_db(n, num_edges=30, density=0.5, n_vlabels=5,
                           n_elabels=2, seed=seed)
    if kind == "pubchem":
        return aids_like_db(n, seed=seed + 7, mean_v=23.4, n_vlabels=101,
                            n_elabels=3)
    raise ValueError(kind)


def queries_for(db, num: int = 10, tau: int = 3, seed: int = 1):
    """Paper protocol: randomly selected graphs (perturbed so answers are
    non-trivial)."""
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(db), size=num, replace=False)
    return [perturb_graph(db[int(i)], max(tau // 2, 1), rng, db.n_vlabels,
                          db.n_elabels) for i in idx]


def timer(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


class Csv:
    """Collects 'name,us_per_call,derived' rows (the run.py contract)."""

    def __init__(self) -> None:
        self.rows: List[str] = []

    def add(self, name: str, seconds: float, derived: Any = "") -> None:
        row = f"{name},{seconds * 1e6:.1f},{derived}"
        self.rows.append(row)
        print(row, flush=True)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            f.write("\n".join(self.rows) + "\n")


def save_json(name: str, obj: Any) -> None:
    with open(art_path(name), "w") as f:
        json.dump(obj, f, indent=1)
