"""Figures 10-13: scalability of candidate size / filter time in
query-graph size |V_h|, database size |G|, label alphabet |Sigma_V| and
density rho.  Also the distributed per-shard throughput model that stands
in for the paper's PubChem-25M runs (DESIGN.md §9)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import Csv, dataset, save_json
from repro.core.search import FlatMSQIndex, MSQIndex
from repro.graphs.generators import graphgen_db, perturb_graph, random_graph


def vary_query_size(csv: Csv, n: int = 2000, sizes=(10, 20, 30, 40, 50, 60),
                    tau: int = 3) -> List[Dict]:
    db = dataset("pubchem", n)
    idx = MSQIndex(db)
    rng = np.random.default_rng(0)
    rows = []
    for vh in sizes:
        h = random_graph(rng, vh, vh + vh // 12, db.n_vlabels, db.n_elabels,
                         max_degree=4)
        res = idx.query(h, tau, verify=False)
        rows.append({"vh": vh, "candidates": len(res.candidates),
                     "filter_s": res.filter_time_s,
                     "regions_visited": res.stats.get("regions_visited", -1)})
        csv.add(f"fig10/vh{vh}/candidates", res.filter_time_s,
                len(res.candidates))
    save_json("fig10_vary_vh.json", rows)
    return rows


def vary_db_size(csv: Csv, sizes=(500, 1000, 2000, 4000), tau: int = 5
                 ) -> List[Dict]:
    rows = []
    for n in sizes:
        db = dataset("pubchem", n)
        idx = MSQIndex(db)
        qs = [perturb_graph(db[i], 2, np.random.default_rng(i),
                            db.n_vlabels, db.n_elabels)
              for i in (1, n // 2, n - 2)]
        cands, times = [], []
        for h in qs:
            res = idx.query(h, tau, verify=False)
            cands.append(len(res.candidates))
            times.append(res.filter_time_s)
        rows.append({"n": n, "candidates": float(np.mean(cands)),
                     "filter_s": float(np.mean(times))})
        csv.add(f"fig11/g{n}/candidates", float(np.mean(times)),
                round(float(np.mean(cands)), 1))
    save_json("fig11_vary_g.json", rows)
    return rows


def vary_labels(csv: Csv, n: int = 800, labels=(2, 5, 10, 20), tau: int = 5
                ) -> List[Dict]:
    rows = []
    for nl in labels:
        db = graphgen_db(n, num_edges=30, density=0.5, n_vlabels=nl,
                         n_elabels=2, seed=nl)
        idx = FlatMSQIndex(db)
        rng = np.random.default_rng(nl)
        cands = []
        for i in (3, n // 2, n - 3):
            h = perturb_graph(db[i], 2, rng, db.n_vlabels, db.n_elabels)
            cands.append(len(idx.candidates(h, tau)))
        rows.append({"n_vlabels": nl, "candidates": float(np.mean(cands))})
        csv.add(f"fig12/labels{nl}/candidates", 0.0,
                round(float(np.mean(cands)), 1))
    save_json("fig12_vary_labels.json", rows)
    return rows


def vary_density(csv: Csv, n: int = 800, rhos=(0.2, 0.4, 0.6, 0.8),
                 tau: int = 5) -> List[Dict]:
    rows = []
    for rho in rhos:
        db = graphgen_db(n, num_edges=30, density=rho, n_vlabels=5,
                         n_elabels=2, seed=int(rho * 10))
        idx = FlatMSQIndex(db)
        rng = np.random.default_rng(int(rho * 100))
        cands = []
        for i in (3, n // 2, n - 3):
            h = perturb_graph(db[i], 2, rng, db.n_vlabels, db.n_elabels)
            cands.append(len(idx.candidates(h, tau)))
        rows.append({"rho": rho, "candidates": float(np.mean(cands))})
        csv.add(f"fig13/rho{int(rho*100)}/candidates", 0.0,
                round(float(np.mean(cands)), 1))
    save_json("fig13_vary_density.json", rows)
    return rows


def main() -> None:
    csv = Csv()
    vary_query_size(csv)
    vary_db_size(csv)
    vary_labels(csv)
    vary_density(csv)


if __name__ == "__main__":
    main()
