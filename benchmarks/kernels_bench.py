"""Kernel micro-benchmarks: wall time of the jnp/XLA serving paths on CPU
(correctness-scale; real-TPU time comes from the §Roofline model) plus the
analytic HBM-traffic roofline of each kernel on v5e constants.

``--smoke-batched`` runs only the batched fused-filter and assignment-LB
kernels on tiny shapes and asserts bit-identical bounds against their
references (the CI smoke for DESIGN.md §13 and §16)."""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, dataset, save_json, timer
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def bench_qgram_filter(csv: Csv, B: int = 4096, U: int = 2048) -> dict:
    from repro.kernels.qgram_filter.ref import fused_filter_bounds_ref
    from repro.kernels.qgram_filter.ops import make_aux, make_scalars
    rng = np.random.default_rng(0)
    args = (make_scalars(20, 22, 3, 25, 27, 4),
            jnp.asarray(rng.integers(0, 4, (B, U)).astype(np.int32)),
            jnp.asarray(rng.integers(0, 4, U).astype(np.int32)),
            jnp.asarray(rng.integers(0, 5, (B, 62)).astype(np.int32)),
            jnp.asarray(rng.integers(0, 5, 62).astype(np.int32)),
            jnp.asarray(rng.integers(0, 5, (B, 3)).astype(np.int32)),
            jnp.asarray(rng.integers(0, 5, 3).astype(np.int32)),
            jnp.asarray(-np.sort(-rng.integers(0, 5, (B, 64)), 1).astype(np.int32)),
            jnp.asarray(-np.sort(-rng.integers(0, 5, 64)).astype(np.int32)),
            jnp.asarray(np.concatenate(
                [rng.integers(1, 30, (B, 2)), rng.integers(-3, 4, (B, 2)),
                 np.zeros((B, 1), int)], 1).astype(np.int32)))
    fn = jax.jit(fused_filter_bounds_ref)
    fn(*args)[0].block_until_ready()
    _, dt = timer(lambda: fn(*args)[0].block_until_ready(), repeat=20)
    bytes_moved = B * U * 4 + B * (62 + 3 + 64 + 5) * 4
    tpu_s = bytes_moved / HBM_BW  # memory-bound kernel
    csv.add("kernel/qgram_filter/xla_cpu", dt,
            f"graphs_per_s={B / dt:.0f}")
    csv.add("kernel/qgram_filter/tpu_roofline", tpu_s,
            f"graphs_per_s={B / tpu_s:.0f}")
    return {"cpu_s": dt, "tpu_model_s": tpu_s, "bytes": bytes_moved}


def bench_qgram_filter_batched(csv: Csv, Q: int = 16, B: int = 256,
                               U: int = 512, interpret: bool = True,
                               assert_identical: bool = True) -> dict:
    """Query-batched kernel vs a loop of Q single-query launches on one
    shape: one F_D stream amortised over the block (DESIGN.md §13).  The
    batched/looped bounds are asserted identical — the CI smoke gate."""
    from repro.kernels.qgram_filter.kernel import N_SCALARS
    from repro.kernels.qgram_filter.ops import (fused_filter_bounds,
                                                fused_filter_bounds_batched)
    rng = np.random.default_rng(5)
    NV, NE, VM = 62, 3, 64
    fd = jnp.asarray(rng.integers(0, 4, (B, U)).astype(np.int32))
    vh = jnp.asarray(rng.integers(0, 5, (B, NV)).astype(np.int32))
    eh = jnp.asarray(rng.integers(0, 5, (B, NE)).astype(np.int32))
    ds = jnp.asarray(-np.sort(-rng.integers(0, 5, (B, VM)), 1)
                     .astype(np.int32))
    aux = jnp.asarray(np.concatenate(
        [rng.integers(1, 30, (B, 2)), rng.integers(-3, 4, (B, 2))],
        1).astype(np.int32))
    sc = np.concatenate(
        [rng.integers(1, 30, (Q, 2)), rng.integers(1, 4, (Q, 1)),
         np.full((Q, 2), 25), np.full((Q, 1), 4)], 1).astype(np.int32)
    assert sc.shape[1] == N_SCALARS
    qfd = jnp.asarray(rng.integers(0, 4, (Q, U)).astype(np.int32))
    qvh = jnp.asarray(rng.integers(0, 5, (Q, NV)).astype(np.int32))
    qeh = jnp.asarray(rng.integers(0, 5, (Q, NE)).astype(np.int32))
    qsig = jnp.asarray(-np.sort(-rng.integers(0, 5, (Q, VM)), 1)
                       .astype(np.int32))
    aux5 = jnp.concatenate([aux, jnp.zeros((B, 1), jnp.int32)], axis=1)

    def looped():
        return [np.asarray(fused_filter_bounds(
            jnp.asarray(sc[r]), fd, qfd[r], vh, qvh[r], eh, qeh[r], ds,
            qsig[r], aux5, interpret=interpret)[0]) for r in range(Q)]

    def batched():
        return np.asarray(fused_filter_bounds_batched(
            jnp.asarray(sc), fd, qfd, vh, qvh, eh, qeh, ds, qsig, aux,
            interpret=interpret)[0])

    loop_out = np.stack(looped())          # warm + reference
    batch_out = batched()
    if assert_identical:
        assert np.array_equal(loop_out, batch_out), \
            "batched kernel bounds diverged from the looped kernel"
    _, t_loop = timer(looped, repeat=3)
    _, t_batch = timer(lambda: batched(), repeat=3)
    csv.add(f"kernel/qgram_filter/looped_q{Q}_b{B}_u{U}", t_loop,
            f"pairs_per_s={Q * B / t_loop:.0f}")
    csv.add(f"kernel/qgram_filter/batched_q{Q}_b{B}_u{U}", t_batch,
            f"pairs_per_s={Q * B / t_batch:.0f} "
            f"({t_loop / t_batch:.2f}x vs looped)")
    print(f"batched fused filter [{Q}x{B}x{U}]: {t_batch * 1e3:.1f}ms vs "
          f"looped {t_loop * 1e3:.1f}ms ({t_loop / t_batch:.2f}x), "
          f"identical bounds")
    return {"loop_s": t_loop, "batch_s": t_batch,
            "speedup": t_loop / t_batch, "identical": True}


def bench_assign_lb(csv: Csv, Q: int = 8, N: int = 256, VMq: int = 32,
                    VM: int = 32, interpret: bool = True) -> dict:
    """Stage-1.5 assignment-LB kernel (DESIGN.md §16) vs the jnp
    reference on one padded block; the kernel == ref integer assertion
    is the CI smoke gate (the bound is exact integers, not a tolerance)."""
    from repro.kernels.assign_lb.ops import assign_lb_bounds_batched
    from repro.kernels.assign_lb.ref import batched_assign_lb_ref
    rng = np.random.default_rng(6)
    NE = 3

    def feats(rows, vm):
        cnt = rng.integers(1, vm + 1, rows).astype(np.int32)
        v = np.full((rows, vm), -1, np.int32)
        d = np.zeros((rows, vm), np.int32)
        eh = np.zeros((rows, vm, NE), np.int32)
        for r, c in enumerate(cnt):
            v[r, :c] = rng.integers(0, 5, c)
            eh[r, :c] = rng.integers(0, 3, (c, NE))
            d[r, :c] = eh[r, :c].sum(1)
        return v, d, eh, cnt

    qv, qd, qeh, qn = feats(Q, VMq)
    dv, dd, deh, dn = feats(N, VM)
    args = (qv, qd, qeh, qn, dv, dd, deh, dn)
    jargs = [jnp.asarray(x) for x in args]
    ref_fn = jax.jit(batched_assign_lb_ref)
    ref_out = np.asarray(ref_fn(*jargs))

    def kern():
        return np.asarray(assign_lb_bounds_batched(
            *args, qb=min(8, Q), bb=min(128, N), interpret=interpret))

    assert np.array_equal(kern(), ref_out), \
        "assign_lb kernel bounds diverged from the jnp reference"
    _, t_ref = timer(lambda: np.asarray(ref_fn(*jargs)), repeat=3)
    _, t_k = timer(kern, repeat=3)
    csv.add(f"kernel/assign_lb/ref_q{Q}_n{N}", t_ref,
            f"pairs_per_s={Q * N / t_ref:.0f}")
    csv.add(f"kernel/assign_lb/pallas_q{Q}_n{N}", t_k,
            f"pairs_per_s={Q * N / t_k:.0f}")
    print(f"assign_lb [{Q}x{N}, vm {VMq}/{VM}]: kernel {t_k * 1e3:.1f}ms "
          f"vs jnp ref {t_ref * 1e3:.1f}ms, identical bounds")
    return {"ref_s": t_ref, "kernel_s": t_k, "identical": True}


def bench_bitunpack(csv: Csv, n: int = 1 << 18) -> dict:
    from repro.kernels.bitunpack.ops import pack_hybrid, packed_size_bits
    from repro.kernels.bitunpack.ref import unpack_hybrid_ref
    rng = np.random.default_rng(1)
    vals = rng.integers(1, 12, n)
    words, sb, widths, nv = pack_hybrid(vals)
    fn = jax.jit(unpack_hybrid_ref)
    args = (jnp.asarray(sb), jnp.asarray(widths), jnp.asarray(words))
    fn(*args).block_until_ready()
    _, dt = timer(lambda: fn(*args).block_until_ready(), repeat=20)
    packed_bits = packed_size_bits(words, sb, widths)
    csv.add("kernel/bitunpack/xla_cpu", dt,
            f"vals_per_s={n / dt:.0f};bits_per_val={packed_bits / n:.2f}")
    tpu_s = (packed_bits / 8 + n * 4) / HBM_BW  # read packed, write int32
    csv.add("kernel/bitunpack/tpu_roofline", tpu_s,
            f"vals_per_s={n / tpu_s:.0f}")
    return {"cpu_s": dt, "tpu_model_s": tpu_s,
            "bits_per_val": packed_bits / n}


def bench_rank(csv: Csv, n: int = 1 << 20) -> dict:
    from repro.kernels.rank_popcount.ops import build_rank_dictionary, rank1_query
    rng = np.random.default_rng(2)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    words, cum = build_rank_dictionary(bits, interpret=True)
    idx = jnp.asarray(rng.integers(0, n, 4096).astype(np.int32))
    rank1_query(words, cum, idx).block_until_ready()
    _, dt = timer(lambda: rank1_query(words, cum, idx).block_until_ready(),
                  repeat=20)
    csv.add("kernel/rank1/xla_cpu", dt, f"queries_per_s={4096 / dt:.0f}")
    return {"cpu_s": dt}


def bench_attention(csv: Csv) -> dict:
    from repro.kernels.flash_attention.ops import flash_attention
    rng = np.random.default_rng(3)
    B, H, S, D = 1, 8, 1024, 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, H // 2, S, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, H // 2, S, D)), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                 impl="xla"))
    fn(q, k, v).block_until_ready()
    _, dt = timer(lambda: fn(q, k, v).block_until_ready(), repeat=5)
    flops = 2 * 2 * B * H * S * S * D / 2  # causal half, qk + pv
    tpu_s = flops / PEAK_FLOPS_BF16
    csv.add("kernel/flash_attention/xla_cpu", dt,
            f"tflops={flops / dt / 1e12:.3f}")
    csv.add("kernel/flash_attention/tpu_roofline", tpu_s,
            f"compute_bound_s={tpu_s:.2e}")
    return {"cpu_s": dt, "tpu_model_s": tpu_s}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke-batched", action="store_true",
                    help="tiny-shape batched fused-filter run only, with "
                         "the batched == looped bounds assertion (CI)")
    args = ap.parse_args()
    csv = Csv()
    if args.smoke_batched:
        out = {"qgram_filter_batched":
               bench_qgram_filter_batched(csv, Q=6, B=48, U=160),
               "assign_lb":
               bench_assign_lb(csv, Q=4, N=32, VMq=8, VM=16)}
        save_json("kernels_bench_smoke.json", out)
        return
    out = {
        "qgram_filter": bench_qgram_filter(csv),
        "qgram_filter_batched": bench_qgram_filter_batched(csv),
        "assign_lb": bench_assign_lb(csv),
        "bitunpack": bench_bitunpack(csv),
        "rank1": bench_rank(csv),
        "flash_attention": bench_attention(csv),
    }
    save_json("kernels_bench.json", out)


if __name__ == "__main__":
    main()
