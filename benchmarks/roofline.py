"""Roofline table assembly: reads artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and renders the §Roofline table —
compute / memory / collective seconds per (arch x shape x mesh), dominant
term, MODEL_FLOPS vs HLO FLOPs ratio, and the roofline fraction."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DRY = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: Optional[str] = None, tag: str = "") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def fmt_row(r: Dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | — | {r['reason'][:48]}… |")
    if r["status"] == "error":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — | — | {r.get('error', '')[:48]} |")
    ro = r.get("roofline_kernel") or r["roofline"]
    raw = r["roofline"]
    note = {
        "compute_s": "raise useful FLOPs/chip (sharding) or cut remat",
        "memory_s": "cut HBM traffic: fuse, shrink activations, remat policy",
        "collective_s": "cut wire bytes: reshard, overlap, compress",
    }[ro["dominant"]]
    return ("| {arch} | {shape} | {mesh} | {c:.3f} | {m:.3f} | {x:.3f} | "
            "{dom} | {uf:.2f} | {rf:.4f} | {raw:.4f} | {note} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=ro["compute_s"], m=ro["memory_s"], x=ro["collective_s"],
        dom=ro["dominant"].replace("_s", ""),
        uf=ro["useful_flops_ratio"], rf=ro["roofline_fraction"],
        raw=raw["roofline_fraction"], note=note)


def table(mesh: Optional[str] = "pod16x16", tag: str = "") -> str:
    cells = load_cells(mesh, tag)
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful_flops | frac(kernel) | frac(raw) | "
           "what moves it |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in cells)


def summarize(csv=None) -> Dict:
    out = {}
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load_cells(mesh)
        ok = [c for c in cells if c["status"] == "ok"]
        out[mesh] = {
            "cells": len(cells),
            "ok": len(ok),
            "skipped": sum(c["status"] == "skipped" for c in cells),
            "errors": sum(c["status"] == "error" for c in cells),
            "dominant": {
                k: sum(c["roofline"]["dominant"] == k for c in ok)
                for k in ("compute_s", "memory_s", "collective_s")},
        }
        if csv is not None:
            csv.add(f"dryrun/{mesh}/cells_ok", 0.0,
                    f"{len(ok)}ok/{out[mesh]['skipped']}skip/"
                    f"{out[mesh]['errors']}err")
    return out


def main() -> None:
    print(table("pod16x16"))
    print()
    print(json.dumps(summarize(), indent=1))


if __name__ == "__main__":
    main()
