.PHONY: check check-docs check-slow lint bench-throughput bench-smoke \
	chaos-smoke

# Static analysis gate (DESIGN.md §14): lock discipline, JAX hygiene,
# Pallas contracts, doc citations. Pure stdlib — no jax/numpy needed.
lint:
	python scripts/lint.py

# Tier-1 tests, offline-safe, with per-test + total timeouts (fail fast
# instead of wedging CI). Override budgets via REPRO_TEST_TIMEOUT /
# REPRO_TOTAL_TIMEOUT.
check:
	bash scripts/check.sh

# Just the DESIGN.md citation gate (alias into the lint framework).
check-docs:
	python scripts/lint.py --select DOC

# Everything, including @pytest.mark.slow model cases.
check-slow:
	bash scripts/check.sh --runslow

bench-throughput:
	PYTHONPATH=src python -m benchmarks.query_throughput --n 5000 --q 64

# Tiny offline pipeline smoke (CI): exercises the async pipelined engine
# end-to-end — parity asserted, overlap recorded to artifacts/bench/ —
# plus the query-batched fused filter and assignment-LB kernels on tiny
# shapes, asserting bounds identical to their references (DESIGN.md §13,
# §16), and the SLO traffic
# simulator on a tiny trace (both tenant mixes, open + closed loop),
# asserting the report schema — non-empty percentiles, goodput,
# partial-rate, per-stage breakdowns (DESIGN.md §15, §17) — and the
# span-trace artifact schema (--trace + --smoke validates it).
bench-smoke:
	PYTHONPATH=src python -m benchmarks.query_throughput --n 300 --q 16 \
	    --pipeline --pipeline-workers 2
	PYTHONPATH=src python -m benchmarks.kernels_bench --smoke-batched
	PYTHONPATH=src python -m benchmarks.serving_slo --smoke --trace

# Chaos smoke (CI): replays the SLO mixes under the deterministic
# fault_plan() schedule — poisoned filter batches, latency spikes, a
# SIGKILLed verifier worker, admission shedding — asserting bounded
# errors, finite p99, and zero stuck queries (DESIGN.md §18).
chaos-smoke:
	PYTHONPATH=src python -m benchmarks.serving_slo --faults --smoke \
	    --mode open
