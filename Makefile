.PHONY: check check-docs check-slow bench-throughput

# Tier-1 tests, offline-safe, with per-test + total timeouts (fail fast
# instead of wedging CI). Override budgets via REPRO_TEST_TIMEOUT /
# REPRO_TOTAL_TIMEOUT.
check:
	bash scripts/check.sh

# Just the DESIGN.md citation gate (also part of `check`).
check-docs:
	python scripts/check_docs.py

# Everything, including @pytest.mark.slow model cases.
check-slow:
	bash scripts/check.sh --runslow

bench-throughput:
	PYTHONPATH=src python -m benchmarks.query_throughput --n 5000 --q 64
