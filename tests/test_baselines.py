"""Baseline filters (C-Star, Branch, path q-grams, kappa-AT) must also be
admissible, and the paper's comparative claims should hold in trend."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core import baselines
from repro.core.verify import ged_bruteforce
from repro.graphs.generators import perturb_graph, random_graph

NV, NE = 4, 3


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_baseline_bounds_admissible(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    true = ged_bruteforce(g, h)
    assert baselines.cstar_lb(g, h) <= true + 1e-9
    assert baselines.branch_lb(g, h) <= true + 1e-9
    assert baselines.path_qgram_lb(g, h, p=2) <= true + 1e-9
    assert baselines.kat_lb(g, h) <= true + 1e-9


def test_baseline_zero_on_identity():
    rng = np.random.default_rng(1)
    g = random_graph(rng, 6, 7, NV, NE)
    assert baselines.cstar_lb(g, g) == 0
    assert baselines.branch_lb(g, g) == 0
    assert baselines.path_qgram_lb(g, g) == 0
    assert baselines.kat_lb(g, g) == 0


def test_index_size_ordering():
    """Fig 7 claim (trend at test scale): MSQ-Index is a fraction of the
    baselines.  The paper's 5–15% ratio needs large |G| to amortise the
    per-node tree overhead — benchmarks/index_size.py measures that; here
    we assert the ordering at small |G|."""
    from repro.core.search import MSQIndex
    from repro.graphs.generators import aids_like_db
    db = aids_like_db(500, seed=4)
    idx = MSQIndex(db)
    msq_bits = idx.size_bits()["total"]
    assert msq_bits < 0.30 * baselines.branch_index_bits(db)
    assert msq_bits < 0.35 * baselines.cstar_index_bits(db)
    assert msq_bits < 0.45 * baselines.path_index_bits(db, p=2)


def test_star_structures_shapes():
    rng = np.random.default_rng(2)
    g = random_graph(rng, 5, 6, NV, NE)
    stars = baselines.star_structures(g)
    assert len(stars) == g.n
    degs = g.degrees()
    for v, (l, nb, el) in enumerate(stars):
        assert len(nb) == degs[v] == len(el)
