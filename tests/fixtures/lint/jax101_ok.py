"""Fixture twin: control flow on host values only (shapes, statics,
is-None tests, shape-arithmetic helpers)."""
import functools

import jax


def _bucket(m, n):
    while m < n:
        m *= 2
    return m


@functools.partial(jax.jit, static_argnames=("mode",))
def clean(x, n=None, mode="dense"):
    for i in range(x.ndim):
        x = x + i
    if x.shape[0] > 2:
        x = x * 2
    if mode == "sparse":
        x = x * 3
    k = _bucket(1, x.shape[0])
    if n is None:
        return x * k
    return (x + n) * k
