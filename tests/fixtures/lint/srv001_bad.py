"""SRV001 violation fixture: broad excepts that swallow failures."""


class Worker:
    def __init__(self):
        self.errors = 0

    def run_bare(self, job):
        try:
            job.run()
        except:                               # expect: SRV001
            pass

    def run_broad(self, job):
        try:
            job.run()
        except Exception:                     # expect: SRV001
            print("oops")

    def run_tuple(self, job):
        try:
            job.run()
        except (ValueError, BaseException):   # expect: SRV001
            job.retries += 1
