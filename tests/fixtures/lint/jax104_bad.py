"""Fixture: jitted-callable construction inside a loop body."""
import jax


def run_all(fns, x):
    outs = []
    for f in fns:
        jf = jax.jit(f)             # expect: JAX104
        outs.append(jf(x))
    return outs


def retry(f, x):
    while x is None:
        x = jax.jit(f)(0)           # expect: JAX104
    return x
