"""Fixture: static_argnames drift and unhashable static defaults."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("mode", "missing"))  # expect: JAX103
def stale(x, mode="dense"):
    return x


@functools.partial(jax.jit, static_argnames=("opts",))
def mutable_default(x, opts=[]):    # expect: JAX103
    return x
