"""Fixture: kernel arity disagrees with in_specs + outputs + scratch."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, ghost_ref):
    o_ref[...] = x_ref[...]


def call(x):
    return pl.pallas_call(      # expect: PLC301
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(x)
