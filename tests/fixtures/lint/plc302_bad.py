"""Fixture: index_map arity disagrees with the grid rank."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def call(x):
    return pl.pallas_call(
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],  # expect: PLC302
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(x)
