"""Fixture: AB/BA nested acquisition — a lock-order inversion."""
import threading


class Pair:
    def __init__(self):
        self._la = threading.Lock()
        self._lb = threading.Lock()

    def forward(self):
        with self._la:
            with self._lb:      # expect: LCK004
                pass

    def backward(self):
        with self._lb:
            with self._la:      # expect: LCK004
                pass
