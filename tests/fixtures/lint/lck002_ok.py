"""Fixture twin: the wait sits inside a while predicate loop."""
import threading


class Box:
    def __init__(self):
        self._cv = threading.Condition()
        self.ready = False

    def take(self):
        with self._cv:
            while not self.ready:
                self._cv.wait()
            self.ready = False
