"""Fixture twin: every guarded access holds the lock (or is __init__,
or a caller-holds-lock helper)."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0          # guarded_by: self._lock

    def bump(self):
        with self._lock:
            self._bump_locked()

    def _bump_locked(self):     # guarded_by: self._lock
        self.count += 1

    def peek(self):
        with self._lock:
            return self.count

    def schedule(self):
        def later():
            with self._lock:
                self.count += 1
        return later
