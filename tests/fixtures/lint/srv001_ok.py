"""SRV001 clean twin: broad excepts that visibly propagate the fault."""


class Worker:
    def __init__(self):
        self.errors = 0
        self.unverified = 0

    def run_reraise(self, job):
        try:
            job.run()
        except Exception:
            raise

    def run_counts(self, job):
        try:
            job.run()
        except Exception:
            self.errors += 1

    def run_fails_ticket(self, job, ticket):
        try:
            job.run()
        except Exception as e:
            ticket.resolve(None, e)

    def run_uses_bound(self, job, log):
        try:
            job.run()
        except Exception as e:
            log(repr(e))

    def run_narrow(self, job):
        try:
            job.run()
        except KeyError:
            pass

    def run_suppressed(self, job):
        try:
            job.run()
        except Exception:   # lint: disable=SRV001
            pass
