"""Fixture twin: the worker thread has an explicit join path."""
import threading


class Managed:
    def __init__(self):
        self._t = threading.Thread(target=print, daemon=True)
        self._t.start()

    def close(self):
        self._t.join()
