"""Fixture twin: .item()/np.asarray only on host values or outside the
jit-reachable closure."""
import jax
import numpy as np


def host_only(x):
    return np.asarray(x)


@jax.jit
def clean(x):
    n = x.shape[0]
    pad = int(n * 2)
    return x + pad
