"""Fixture: Python control flow on traced values inside jit."""
import jax


@jax.jit
def branch(x):
    if x > 0:               # expect: JAX101
        return x
    return -x


@jax.jit
def spin(x):
    while x.sum() > 0:      # expect: JAX101
        x = x - 1
    return x


@jax.jit
def sweep(x):
    for v in x:             # expect: JAX101
        x = x + v
    return x
