"""Fixture: cross-class inversion through method calls made under a
lock into methods that themselves lock."""
import threading


class Sched:
    def __init__(self):
        self._cv = threading.Condition()
        self.pipe = None

    def kick(self):
        with self._cv:
            pass

    def ping(self):
        with self._cv:
            self.pipe.poke_locked()     # expect: LCK004


class Pipe:
    def __init__(self):
        self._cv2 = threading.Condition()
        self.sched = Sched()

    def poke_locked(self):
        with self._cv2:
            pass

    def poke(self):
        with self._cv2:
            self.sched.kick()           # expect: LCK004
