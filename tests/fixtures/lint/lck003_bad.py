"""Fixture: thread started, no join/close path anywhere in the class."""
import threading


class Leaky:
    def __init__(self):
        self._t = threading.Thread(target=print, daemon=True)  # expect: LCK003
        self._t.start()
