"""Cites DESIGN.md (the fixture one): a good one and a dangling one."""
GOOD = "DESIGN.md §1"
BAD = "DESIGN.md §9"    # expect: DOC401
