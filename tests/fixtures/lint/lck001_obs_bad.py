"""Fixture: the obs registry idiom with broken lock discipline — a
``# guarded_by:``-annotated counter store mutated outside its lock, the
exact shape LCK001 must keep catching now that ``src/repro/obs/`` is a
lint target."""
import threading


class MiniRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self._counters = {}     # guarded_by: self._lock

    def counter_add(self, name, v):
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + v

    def snapshot(self):
        with self._lock:
            return dict(self._counters)

    def reset(self):
        self._counters = {}     # expect: LCK001

    def absorb(self, snap):
        for name, v in snap.get("counters", {}).items():
            old = self._counters.get(name, 0)   # expect: LCK001
            self._counters[name] = old + v      # expect: LCK001
