"""Fixture: kernel stores a dtype other than the declared out_shape."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float32)     # expect: PLC304


def call(x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.int32),
    )(x)
