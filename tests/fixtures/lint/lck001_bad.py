"""Fixture: guarded field touched outside its lock."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0          # guarded_by: self._lock

    def bump(self):
        with self._lock:
            self.count += 1

    def peek(self):
        return self.count       # expect: LCK001

    def reset(self):
        self.count = 0          # expect: LCK001
