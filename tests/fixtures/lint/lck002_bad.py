"""Fixture: Condition.wait guarded by `if`, not a predicate loop."""
import threading


class Box:
    def __init__(self):
        self._cv = threading.Condition()
        self.ready = False

    def take(self):
        with self._cv:
            if not self.ready:
                self._cv.wait()     # expect: LCK002
            self.ready = False
