"""Fixture twin: jit constructed once, called in the loop."""
import jax


def run_all(fns, x):
    jitted = [jax.jit(f) for f in fns]
    outs = []
    for jf in jitted:
        outs.append(jf(x))
    return outs
