"""Fixture twin: statics name real params, hashable defaults."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("mode", "width"))
def clean(x, mode="dense", width=128):
    return x
