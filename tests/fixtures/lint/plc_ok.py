"""Fixture twin: a contract-clean pallas_call — arity, index maps,
scalar SMEM reads, matching dtypes (partial-bound kwonly config)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, x_ref, o_ref, acc_ref, *, scale: float):
    n = s_ref[0]
    acc_ref[...] = x_ref[...].astype(jnp.float32) * scale + n
    o_ref[...] = acc_ref[...].astype(jnp.int32)


def call(scalars, x):
    kernel = functools.partial(_kernel, scale=2.0)
    grid = (2, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.int32),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
    )(scalars, x)
