"""Fixture: host syncs on traced values inside jit."""
import jax
import numpy as np


@jax.jit
def item_sync(x):
    total = x.sum()
    return total.item()     # expect: JAX102


@jax.jit
def np_sync(x):
    return np.asarray(x)    # expect: JAX102


@jax.jit
def bool_sync(x):
    return bool(x)          # expect: JAX102
