"""Fixture twin: both paths acquire in the same A-then-B order."""
import threading


class Pair:
    def __init__(self):
        self._la = threading.Lock()
        self._lb = threading.Lock()

    def forward(self):
        with self._la:
            with self._lb:
                pass

    def also_forward(self):
        with self._la:
            with self._lb:
                pass
