"""Fixture: SMEM block read with a non-scalar index."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, o_ref):
    o_ref[...] = jnp.zeros((8,), jnp.float32) + s_ref[...]  # expect: PLC303


def call(scalars):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((8,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
    )(scalars)
