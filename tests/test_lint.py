"""repro-lint analyzer tests (DESIGN.md §14).

Fixture-driven golden findings: every rule code has a seeded-violation
fixture under ``tests/fixtures/lint/`` whose ``# expect: CODE`` markers
are the exact (line, code) set the analyzer must produce, plus a clean
twin that must produce nothing.  Also covered: inline suppressions, the
baseline mechanism (tolerates baselined fingerprints across line drift,
blocks new ones, multiplicity-aware), the legacy check-docs shim, and
the real tree linting clean end-to-end.

Pure stdlib on purpose — these tests must run without jax/numpy, like
the lint gate itself.
"""
import json
import os
import subprocess
import sys
from collections import Counter

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if os.path.join(REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.core import (Finding, FileCtx, filter_suppressed,
                                 load_baseline, new_findings, write_baseline)
from repro.analysis.docs import DocCitationRule
from repro.analysis.locks import GuardedFieldRule
from repro.analysis.runner import all_rules, run_lint

FIX = "tests/fixtures/lint"


def _expected(relpath):
    """(line, code) pairs from the fixture's ``# expect:`` markers."""
    out = set()
    with open(os.path.join(REPO, relpath), encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if "# expect:" in line:
                for code in line.split("# expect:")[1].split(","):
                    out.add((i, code.strip().split()[0]))
    return out


def _got(relpath, select):
    findings, _ = run_lint(REPO, select=select, files=[relpath])
    return {(f.line, f.code) for f in findings}


FIXTURES = [
    ("lck001_bad.py", "LCK"), ("lck001_ok.py", "LCK"),
    ("lck001_obs_bad.py", "LCK"),
    ("lck002_bad.py", "LCK"), ("lck002_ok.py", "LCK"),
    ("lck003_bad.py", "LCK"), ("lck003_ok.py", "LCK"),
    ("lck004_bad.py", "LCK"), ("lck004_cross_bad.py", "LCK"),
    ("lck004_ok.py", "LCK"),
    ("jax101_bad.py", "JAX"), ("jax101_ok.py", "JAX"),
    ("jax102_bad.py", "JAX"), ("jax102_ok.py", "JAX"),
    ("jax103_bad.py", "JAX"), ("jax103_ok.py", "JAX"),
    ("jax104_bad.py", "JAX"), ("jax104_ok.py", "JAX"),
    ("plc301_bad.py", "PLC"), ("plc302_bad.py", "PLC"),
    ("plc303_bad.py", "PLC"), ("plc304_bad.py", "PLC"),
    ("plc_ok.py", "PLC"),
    ("srv001_bad.py", "SRV"), ("srv001_ok.py", "SRV"),
]


@pytest.mark.parametrize("name,family", FIXTURES,
                         ids=[n for n, _ in FIXTURES])
def test_fixture_findings_exact(name, family):
    rel = f"{FIX}/{name}"
    want = _expected(rel)
    if name.endswith("_ok.py"):
        assert want == set(), f"clean twin {name} must carry no markers"
    else:
        assert want, f"violation fixture {name} must carry expect markers"
    assert _got(rel, family) == want


def test_obs_modules_are_lock_targets():
    """The observability substrate's shared state stays under LCK
    coverage (DESIGN.md §17)."""
    from repro.analysis.targets import targets_for
    lck = set(targets_for(REPO)["LCK"])
    assert "src/repro/obs/metrics.py" in lck
    assert "src/repro/obs/spans.py" in lck


def test_every_rule_code_has_a_violation_fixture():
    """The fixture set stays exhaustive as rule families grow."""
    covered = set()
    for name, _fam in FIXTURES:
        covered |= {c for _ln, c in _expected(f"{FIX}/{name}")}
    covered |= {"DOC400", "DOC401"}          # exercised by the doc tests
    all_codes = {c for _f, r in all_rules() for c in r.codes}
    assert all_codes <= covered, f"uncovered: {sorted(all_codes - covered)}"


# ---- DOC family (scans a fixture docroot, not the real tree) --------------

def test_doc_rule_flags_dangling_citation():
    root = os.path.join(REPO, FIX, "docroot")
    got = {(f.path, f.code)
           for f in DocCitationRule().run_project([], root)}
    assert got == {("src/mod.py", "DOC401")}


def test_doc_rule_missing_design(tmp_path):
    got = [f.code for f in DocCitationRule().run_project([], str(tmp_path))]
    assert got == ["DOC400"]


def test_check_docs_shim_green():
    r = subprocess.run([sys.executable, "scripts/check_docs.py"],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


# ---- suppression ----------------------------------------------------------

_SUPPRESSED_SRC = """\
import threading


class C:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded_by: self._lock

    def peek(self):
        return self.n  # lint: disable=LCK001

    def peek_all(self):
        return self.n  # lint: disable=*

    def leak(self):
        return self.n
"""


def test_inline_suppression():
    ctx = FileCtx("mem.py", "mem.py", _SUPPRESSED_SRC)
    raw = list(GuardedFieldRule().run(ctx))
    assert sorted(f.line for f in raw) == [10, 13, 16]
    kept = filter_suppressed(raw, {"mem.py": ctx})
    assert [f.line for f in kept] == [16]    # only the unsuppressed leak


# ---- baseline mechanics ---------------------------------------------------

def test_baseline_tolerates_old_blocks_new(tmp_path):
    old = Finding("a.py", 3, "LCK001", "C.n unguarded")
    drifted = Finding("a.py", 33, "LCK001", "C.n unguarded")
    fresh = Finding("b.py", 1, "JAX101", "traced if")
    path = str(tmp_path / "base.json")
    write_baseline(path, [old])

    base = load_baseline(path)
    # same fingerprint at a different line: still baselined
    assert new_findings([drifted], base) == []
    # a finding the baseline has never seen: blocks
    assert new_findings([drifted, fresh], base) == [fresh]


def test_baseline_is_multiplicity_aware(tmp_path):
    f = Finding("a.py", 1, "LCK001", "same message")
    again = Finding("a.py", 9, "LCK001", "same message")
    path = str(tmp_path / "base.json")
    write_baseline(path, [f])
    base = load_baseline(path)
    # one baselined occurrence tolerates one finding; the second blocks
    assert new_findings([f, again], base) == [again]


def test_missing_baseline_is_empty():
    assert load_baseline("/nonexistent/base.json") == Counter()


def test_shipped_baseline_is_empty():
    with open(os.path.join(REPO, "scripts", "lint_baseline.json")) as f:
        assert json.load(f) == []


# ---- end to end -----------------------------------------------------------

def test_repo_lints_clean():
    """The real tree has zero unsuppressed findings (empty baseline)."""
    r = subprocess.run([sys.executable, "scripts/lint.py"],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "lint: clean" in r.stdout


def test_select_single_code():
    findings, _ = run_lint(REPO, select="LCK003",
                           files=[f"{FIX}/lck003_bad.py",
                                  f"{FIX}/lck001_bad.py"])
    assert {f.code for f in findings} == {"LCK003"}
