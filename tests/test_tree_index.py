"""End-to-end index tests: Algorithm 1/2 correctness, no false dismissal,
tree == flat equivalence, region reduction soundness, space accounting."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core.region import default_partition, group_by_region
from repro.core.search import FlatMSQIndex, MSQIndex
from repro.core.verify import ged_upto
from repro.graphs.generators import aids_like_db, graphgen_db, perturb_graph


@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(150, seed=11)


@pytest.fixture(scope="module")
def index(small_db):
    return MSQIndex(small_db)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


@pytest.mark.parametrize("tau", [0, 1, 2, 3, 5])
def test_no_false_dismissal(small_db, index, tau):
    rng = np.random.default_rng(tau)
    h = perturb_graph(small_db[17], max(tau, 1), rng, small_db.n_vlabels,
                      small_db.n_elabels)
    res = index.query(h, tau)
    truth = sorted(i for i in range(len(small_db))
                   if ged_upto(small_db[i], h, tau) <= tau)
    assert sorted(m[0] for m in res.matches) == truth
    assert set(truth) <= set(res.candidates)


@pytest.mark.parametrize("tau", [1, 3, 5])
def test_tree_equals_flat(small_db, index, flat, tau):
    rng = np.random.default_rng(100 + tau)
    for qi in (3, 40, 77):
        h = perturb_graph(small_db[qi], tau, rng, small_db.n_vlabels,
                          small_db.n_elabels)
        assert index.candidates(h, tau)[0] == flat.candidates(h, tau)


def test_self_query_finds_self(small_db, index):
    res = index.query(small_db[42], 0)
    assert any(gid == 42 and d == 0 for gid, d in res.matches)


def test_region_reduction_sound(small_db):
    """Every graph within number-count tau of the query must fall inside
    the reduced query region Q_h (Section 4)."""
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne, l=4)
    ri, rj = part.region_of(nv, ne)
    rng = np.random.default_rng(5)
    for tau in (1, 2, 4):
        h = perturb_graph(small_db[int(rng.integers(0, len(small_db)))],
                          tau, rng, small_db.n_vlabels, small_db.n_elabels)
        i1, i2, j1, j2 = part.query_region(h.n, h.m, tau)
        close = np.abs(nv - h.n) + np.abs(ne - h.m) <= tau
        inside = (ri >= i1) & (ri <= i2) & (rj >= j1) & (rj <= j2)
        assert np.all(inside[close])


def test_regions_partition_db(small_db):
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    groups = group_by_region(part, nv, ne)
    all_ids = np.sort(np.concatenate(list(groups.values())))
    assert np.array_equal(all_ids, np.arange(len(small_db)))


def test_succinct_smaller_than_plain(index):
    sq = index.size_bits()
    q = index.plain_size_bits()
    # Table 3: >80% total reduction, >90% on the frequency arrays
    assert sq["total"] < 0.2 * q["total"]
    assert sq["S_b"] + sq["S_c"] < 0.12 * (q["S_b"] + q["S_c"])


def test_dense_graphs_db():
    db = graphgen_db(60, num_edges=30, density=0.5, n_vlabels=5,
                     n_elabels=2, seed=2)
    idx = MSQIndex(db)
    rng = np.random.default_rng(0)
    h = perturb_graph(db[10], 2, rng, db.n_vlabels, db.n_elabels)
    res = idx.query(h, 2, verify=False)
    flat = FlatMSQIndex(db)
    assert res.candidates == flat.candidates(h, 2)


def test_query_stats(index, small_db):
    rng = np.random.default_rng(3)
    h = perturb_graph(small_db[5], 1, rng, small_db.n_vlabels,
                      small_db.n_elabels)
    res = index.query(h, 1, collect_stats=True)
    s = res.stats
    assert s["regions_visited"] <= s["regions_total"]
    assert s["leaves_checked"] <= s["nodes_visited"]
