"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.kernels.bitunpack.ops import pack_hybrid, unpack_hybrid
from repro.kernels.bitunpack.ref import unpack_hybrid_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.qgram_filter.ops import (fused_filter_bounds,
                                            fused_filter_bounds_batched,
                                            make_aux, make_scalars,
                                            shape_bucket)
from repro.kernels.qgram_filter.ref import (fused_batched_bounds_ref,
                                            fused_filter_bounds_ref)
from repro.kernels.rank_popcount.kernel import block_popcounts
from repro.kernels.rank_popcount.ops import build_rank_dictionary, rank1_query
from repro.kernels.rank_popcount.ref import block_popcounts_ref, rank1_query_ref
from repro.core.succinct import BitVector


# --------------------------------------------------------------------------
# qgram_filter
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,U,NV,NE,VM", [
    (7, 33, 5, 3, 9), (64, 256, 62, 3, 40), (130, 700, 16, 2, 16),
])
def test_qgram_filter_kernel_vs_ref(B, U, NV, NE, VM):
    rng = np.random.default_rng(B * U)
    fd = rng.integers(0, 4, (B, U)).astype(np.int32)
    qfd = rng.integers(0, 4, U).astype(np.int32)
    vh = rng.integers(0, 5, (B, NV)).astype(np.int32)
    qvh = rng.integers(0, 5, NV).astype(np.int32)
    eh = rng.integers(0, 5, (B, NE)).astype(np.int32)
    qeh = rng.integers(0, 5, NE).astype(np.int32)
    ds = -np.sort(-rng.integers(0, 6, (B, VM)), axis=1).astype(np.int32)
    qs = -np.sort(-rng.integers(0, 6, VM)).astype(np.int32)
    aux = np.asarray(make_aux(
        jnp.asarray(rng.integers(1, 30, B).astype(np.int32)),
        jnp.asarray(rng.integers(0, 40, B).astype(np.int32)),
        jnp.asarray(rng.integers(-3, 4, B).astype(np.int32)),
        jnp.asarray(rng.integers(-3, 4, B).astype(np.int32)),
        jnp.asarray(rng.integers(0, 3, B).astype(np.int32))))
    sc = make_scalars(10, 12, 3, 25, 27, 4)
    b1, m1 = fused_filter_bounds(sc, fd, qfd, vh, qvh, eh, qeh, ds, qs, aux,
                                 interpret=True)
    b2, m2 = fused_filter_bounds_ref(sc, jnp.asarray(fd), jnp.asarray(qfd),
                                     jnp.asarray(vh), jnp.asarray(qvh),
                                     jnp.asarray(eh), jnp.asarray(qeh),
                                     jnp.asarray(ds), jnp.asarray(qs),
                                     jnp.asarray(aux))
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(m1), np.asarray(m2))


def _batched_case(rng, Q, B, U, NV=7, NE=3, VM=11):
    """Random operands for the query-batched kernel + its per-query ref."""
    fd = rng.integers(0, 4, (B, U)).astype(np.int32)
    vh = rng.integers(0, 5, (B, NV)).astype(np.int32)
    eh = rng.integers(0, 5, (B, NE)).astype(np.int32)
    ds = -np.sort(-rng.integers(0, 6, (B, VM)), axis=1).astype(np.int32)
    aux = np.concatenate([rng.integers(1, 30, (B, 2)),
                          rng.integers(-3, 4, (B, 2))], 1).astype(np.int32)
    cdt = rng.integers(0, 3, (Q, B)).astype(np.int32)
    sc = np.concatenate(
        [rng.integers(1, 30, (Q, 2)), rng.integers(1, 4, (Q, 1)),
         np.full((Q, 2), 25), np.full((Q, 1), 4)], 1).astype(np.int32)
    qfd = rng.integers(0, 4, (Q, U)).astype(np.int32)
    qvh = rng.integers(0, 5, (Q, NV)).astype(np.int32)
    qeh = rng.integers(0, 5, (Q, NE)).astype(np.int32)
    qsig = -np.sort(-rng.integers(0, 6, (Q, VM)), axis=1).astype(np.int32)
    return sc, fd, qfd, vh, qvh, eh, qeh, ds, qsig, aux, cdt


def _batched_ref(case):
    """(Q, B) oracle via ref.fused_batched_bounds_ref (itself a loop of
    the already-ref-tested single-query ref)."""
    b, m = fused_batched_bounds_ref(*[jnp.asarray(x) for x in case])
    return np.asarray(b), np.asarray(m)


@pytest.mark.parametrize("Q,B,U", [
    (1, 7, 33),        # everything ragged and tiny
    (5, 130, 260),     # Q/B/U all off the tile multiples
    (8, 64, 128),      # exactly tile-aligned
    (13, 97, 515),     # ragged against every default tile
])
def test_qgram_filter_batched_vs_ref_ragged(Q, B, U):
    rng = np.random.default_rng(Q * 1000 + B)
    case = _batched_case(rng, Q, B, U)
    want_b, want_m = _batched_ref(case)
    got_b, got_m = fused_filter_bounds_batched(
        *[jnp.asarray(x) for x in case], interpret=True)
    assert np.array_equal(np.asarray(got_b), want_b)
    assert np.array_equal(np.asarray(got_m), want_m)


def test_qgram_filter_batched_tile_sweep():
    """The (qb, bb, bu) choice must never change a single bound/mask bit
    — that is what makes the autotuner safe to run blind."""
    rng = np.random.default_rng(42)
    case = _batched_case(rng, 6, 70, 300)
    want_b, want_m = _batched_ref(case)
    args = [jnp.asarray(x) for x in case]
    for qb in (2, 4, 8, 16):
        for bb, bu in [(16, 128), (32, 256), (64, 512), (128, 128)]:
            got_b, got_m = fused_filter_bounds_batched(
                *args, qb=qb, bb=bb, bu=bu, interpret=True)
            assert np.array_equal(np.asarray(got_b), want_b), (qb, bb, bu)
            assert np.array_equal(np.asarray(got_m), want_m), (qb, bb, bu)


def test_qgram_filter_batched_no_cdt_means_zeros():
    rng = np.random.default_rng(3)
    case = _batched_case(rng, 4, 33, 140)
    zero = list(case)
    zero[-1] = np.zeros_like(case[-1])
    want_b, want_m = _batched_ref(tuple(zero))
    got_b, got_m = fused_filter_bounds_batched(
        *[jnp.asarray(x) for x in case[:-1]], None, interpret=True)
    assert np.array_equal(np.asarray(got_b), want_b)
    assert np.array_equal(np.asarray(got_m), want_m)


def test_shape_bucket_ladder():
    # powers of two times base up to cap, then cap multiples — and always
    # divisible by min(block, bucket) for power-of-two blocks
    assert [shape_bucket(n, 8, 512) for n in (1, 8, 9, 65, 512, 513)] == \
        [8, 8, 16, 128, 512, 1024]
    for n in (3, 17, 100, 700, 2000):
        for blk in (8, 16, 64, 128, 512):
            bucket = shape_bucket(n, 8, 512)
            assert bucket >= n and bucket % min(blk, bucket) == 0


def test_qgram_filter_block_size_invariance():
    rng = np.random.default_rng(0)
    B, U = 96, 512
    args = (make_scalars(8, 9, 2, 20, 22, 4),
            rng.integers(0, 3, (B, U)).astype(np.int32),
            rng.integers(0, 3, U).astype(np.int32),
            rng.integers(0, 4, (B, 8)).astype(np.int32),
            rng.integers(0, 4, 8).astype(np.int32),
            rng.integers(0, 4, (B, 3)).astype(np.int32),
            rng.integers(0, 4, 3).astype(np.int32),
            -np.sort(-rng.integers(0, 5, (B, 12)), axis=1).astype(np.int32),
            -np.sort(-rng.integers(0, 5, 12)).astype(np.int32),
            np.concatenate([rng.integers(1, 20, (B, 2)),
                            rng.integers(-2, 3, (B, 2)),
                            np.zeros((B, 1), int)], 1).astype(np.int32))
    outs = [fused_filter_bounds(*args, bb=bb, bu=bu, interpret=True)
            for bb, bu in [(16, 64), (32, 128), (96, 512)]]
    for b, m in outs[1:]:
        assert np.array_equal(np.asarray(outs[0][0]), np.asarray(b))
        assert np.array_equal(np.asarray(outs[0][1]), np.asarray(m))


# --------------------------------------------------------------------------
# assign_lb (stage-1.5 assignment lower bound, DESIGN.md §16)
# --------------------------------------------------------------------------

def _assign_lb_case(rng, Q, N, vmq_raw, vm_raw):
    """Ragged branch-feature blocks padded with the production helpers:
    query side via pad_query_block, db side with the slab-gather fills
    (label -1 / degree 0 / zero hists, nv pad 0)."""
    from repro.kernels.assign_lb.ops import (N_BASE, N_CAP, VM_BASE, VM_CAP,
                                             pad_query_block)

    def feats(counts, vm):
        v = np.full((len(counts), vm), -1, np.int32)
        d = np.zeros((len(counts), vm), np.int32)
        eh = np.zeros((len(counts), vm, 3), np.int32)
        for r, c in enumerate(counts):
            v[r, :c] = rng.integers(0, 5, c)
            eh[r, :c] = rng.integers(0, 3, (c, 3))
            d[r, :c] = eh[r, :c].sum(1)
        return v, d, eh

    qn = rng.integers(1, vmq_raw + 1, Q).astype(np.int32)
    dn = rng.integers(1, vm_raw + 1, N).astype(np.int32)
    qv, qd, qeh = feats(qn, vmq_raw)
    dv, dd, deh = feats(dn, vm_raw)
    qv, qd, qeh, qn = pad_query_block(qv, qd, qeh, qn)
    npad = shape_bucket(N, N_BASE, N_CAP)
    vmp = shape_bucket(vm_raw, VM_BASE, VM_CAP)
    pr = npad - N
    dv = np.pad(dv, [(0, pr), (0, vmp - vm_raw)], constant_values=-1)
    dd = np.pad(dd, [(0, pr), (0, vmp - vm_raw)])
    deh = np.pad(deh, [(0, pr), (0, vmp - vm_raw), (0, 0)])
    dn = np.pad(dn, (0, pr))
    return qv, qd, qeh, qn, dv, dd, deh, dn


@pytest.mark.parametrize("Q,N,VMq,VM", [
    (1, 7, 5, 9),       # everything ragged and tiny
    (5, 130, 11, 17),   # every axis off its bucket
    (8, 64, 8, 16),     # exactly bucket-aligned
    (13, 97, 30, 40),   # ragged against the default tiles
])
def test_assign_lb_kernel_vs_ref_ragged(Q, N, VMq, VM):
    from repro.kernels.assign_lb.ops import (assign_lb_bounds_batched,
                                             assign_lb_np)
    from repro.kernels.assign_lb.ref import batched_assign_lb_ref
    rng = np.random.default_rng(Q * 1000 + N)
    case = _assign_lb_case(rng, Q, N, VMq, VM)
    want = assign_lb_np(*case)
    ref = np.asarray(batched_assign_lb_ref(*[jnp.asarray(x) for x in case]))
    got = np.asarray(assign_lb_bounds_batched(
        *case, qb=min(8, case[0].shape[0]), bb=min(128, case[4].shape[0]),
        interpret=True))
    assert np.array_equal(ref, want)
    assert np.array_equal(got, want)


def test_assign_lb_tile_sweep():
    """The (qb, bb) tile choice must never change a single bound — what
    makes the assign_lb autotuner safe to run blind."""
    from repro.kernels.assign_lb.ops import (assign_lb_bounds_batched,
                                             assign_lb_np)
    rng = np.random.default_rng(7)
    case = _assign_lb_case(rng, 6, 70, 10, 14)      # pads to (8, 128)
    want = assign_lb_np(*case)
    for qb in (2, 4, 8):
        for bb in (16, 32, 64, 128):
            got = np.asarray(assign_lb_bounds_batched(
                *case, qb=qb, bb=bb, interpret=True))
            assert np.array_equal(got, want), (qb, bb)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_assign_lb_le_exact_ged(seed):
    """Provability on random graph pairs: Hausdorff <= Hungarian <= the
    exact GED (so stage-1.5 pruning can never drop a true match)."""
    from repro.core.verify import GEDSearch
    from repro.graphs.generators import random_graph
    from repro.kernels.assign_lb.ops import (assign_lb_np,
                                             graph_branch_features,
                                             hungarian_lb_pair)
    rng = np.random.default_rng(seed)
    n1, n2 = (int(rng.integers(2, 7)) for _ in range(2))
    g = random_graph(rng, n1, int(rng.integers(n1 - 1, 2 * n1)), 4, 2)
    h = random_graph(rng, n2, int(rng.integers(n2 - 1, 2 * n2)), 4, 2)
    ged = GEDSearch(g, h, 60).run()     # tau far above any possible GED
    qf = graph_branch_features(g, 2)
    hf = graph_branch_features(h, 2)
    haus = int(assign_lb_np(
        qf[0][None], qf[1][None], qf[2][None], np.array([g.n]),
        hf[0][None], hf[1][None], hf[2][None], np.array([h.n]))[0, 0])
    hung = hungarian_lb_pair(*qf, *hf)
    assert haus <= ged
    if hung is not None:                # scipy-gated
        assert haus <= hung <= ged


# --------------------------------------------------------------------------
# bitunpack
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 600),
       st.sampled_from([2, 14, 250, 60000, 2 ** 30]))
def test_bitunpack_roundtrip(seed, n, hi):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, hi, n).astype(np.int64)
    words, sb, widths, nv = pack_hybrid(vals)
    out = np.asarray(unpack_hybrid(sb, widths, words, nv, interpret=True))
    assert np.array_equal(out, vals)
    ref = np.asarray(unpack_hybrid_ref(jnp.asarray(sb), jnp.asarray(widths),
                                       jnp.asarray(words)))
    assert np.array_equal(ref.reshape(-1)[:nv], vals)


def test_bitunpack_mixed_widths():
    # force different widths across blocks
    vals = np.concatenate([np.ones(128, np.int64),
                           np.full(128, 200, np.int64),
                           np.full(128, 70000, np.int64),
                           np.arange(1, 129, dtype=np.int64)])
    words, sb, widths, nv = pack_hybrid(vals)
    assert len(set(widths.tolist())) >= 3
    out = np.asarray(unpack_hybrid(sb, widths, words, nv, interpret=True))
    assert np.array_equal(out, vals)


# --------------------------------------------------------------------------
# rank_popcount
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 30000))
def test_rank_kernel_matches_refs(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    words, cum = build_rank_dictionary(bits, interpret=True)
    assert np.array_equal(np.asarray(block_popcounts(words, interpret=True)),
                          np.asarray(block_popcounts_ref(words)))
    idx = rng.integers(0, n + 1, 48).astype(np.int32)
    r_k = np.asarray(rank1_query(words, cum, jnp.asarray(idx)))
    r_r = np.asarray(rank1_query_ref(words, jnp.asarray(idx)))
    bv = BitVector(bits)
    r_h = np.array([bv.rank1(int(i)) for i in idx])
    assert np.array_equal(r_k, r_r)
    assert np.array_equal(r_k, r_h)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", [
    dict(B=2, Hq=4, Hkv=2, Sq=64, Skv=64, D=16, causal=True, window=0,
         off=0, bq=16, bk=16),
    dict(B=1, Hq=8, Hkv=8, Sq=32, Skv=32, D=8, causal=True, window=8,
         off=0, bq=8, bk=8),
    dict(B=1, Hq=4, Hkv=1, Sq=16, Skv=128, D=16, causal=True, window=0,
         off=112, bq=16, bk=32),
    dict(B=2, Hq=2, Hkv=2, Sq=48, Skv=48, D=32, causal=False, window=0,
         off=0, bq=16, bk=16),
    dict(B=1, Hq=2, Hkv=1, Sq=40, Skv=40, D=16, causal=True, window=12,
         off=0, bq=8, bk=8),
])
def test_flash_attention_vs_ref(case, dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(case["B"], case["Hq"], case["Sq"],
                                     case["D"])), dtype)
    k = jnp.asarray(rng.normal(size=(case["B"], case["Hkv"], case["Skv"],
                                     case["D"])), dtype)
    v = jnp.asarray(rng.normal(size=(case["B"], case["Hkv"], case["Skv"],
                                     case["D"])), dtype)
    out = flash_attention(q, k, v, causal=case["causal"],
                          window=case["window"], kv_offset=case["off"],
                          bq=case["bq"], bk=case["bk"], impl="interpret")
    ref = attention_ref(q, k, v, causal=case["causal"],
                        window=case["window"], kv_offset=case["off"])
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol,
                               rtol=1e-2)


def test_flash_attention_xla_impl_matches_ref():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 32, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, impl="xla")
    b = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
