"""Traffic simulator determinism: golden traces, replay, SLO reports.

The simulator is the serving harness's load source (DESIGN.md §15,
``benchmarks/serving_slo.py``): the same (tenants, mode, seed) must
regenerate byte-identical traces forever — the goldens under
``tests/fixtures/traffic/`` pin that contract — and a live replay must
issue every scheduled query and produce a schema-complete SLO report.
"""
import json
import os

import numpy as np
import pytest

from repro.serve.traffic import (TenantSpec, TrafficTrace, generate_trace,
                                 percentile, replay)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "traffic")

# the exact mix the goldens were generated from — changing it (or the
# generator's draw order) is a fixture-breaking change and must be
# deliberate: regenerate the goldens and say so in the PR
GOLDEN_TENANTS = [
    TenantSpec("interactive", weight=1.0, rate_qps=40.0, clients=2,
               queries_per_client=4, topk_frac=0.6, k_range=(1, 4), cap=4,
               tau_range=(1, 2), deadline_s=0.25, edits_range=(1, 2)),
    TenantSpec("bulk", weight=1.0, rate_qps=15.0, clients=2,
               queries_per_client=3, topk_frac=0.0, tau_range=(1, 3),
               deadline_s=None, edits_range=(1, 2)),
]


def _golden(mode):
    with open(os.path.join(FIXTURES, f"golden_{mode}_seed42.json"),
              encoding="utf-8") as f:
        return json.load(f)


@pytest.mark.parametrize("mode", ["open", "closed"])
def test_generate_reproduces_golden_trace(mode):
    """Same (tenants, mode, seed) -> the stored golden, field for field:
    arrival schedule, tenant interleave, query parameters, digest."""
    trace = generate_trace(GOLDEN_TENANTS, 120, mode=mode,
                           duration_s=0.5, seed=42)
    golden = TrafficTrace.from_json(_golden(mode))
    assert trace.digest() == golden.digest()
    assert trace.to_json() == golden.to_json()
    # and the digest covers what it claims: any schedule drift is caught
    assert [q.t for q in trace.queries] == [q.t for q in golden.queries]
    assert [q.tenant for q in trace.queries] \
        == [q.tenant for q in golden.queries]


@pytest.mark.parametrize("mode", ["open", "closed"])
def test_trace_roundtrip_and_per_tenant_stats(mode):
    """JSON round-trip is lossless, and per-tenant counts/modality splits
    are identical across independent generations."""
    a = generate_trace(GOLDEN_TENANTS, 120, mode=mode, duration_s=0.5,
                       seed=42)
    b = generate_trace(GOLDEN_TENANTS, 120, mode=mode, duration_s=0.5,
                       seed=42)
    assert TrafficTrace.from_json(a.to_json()).digest() == a.digest()
    for t in ("interactive", "bulk"):
        qa = [q for q in a.queries if q.tenant == t]
        qb = [q for q in b.queries if q.tenant == t]
        assert len(qa) == len(qb) > 0
        assert [q.kind for q in qa] == [q.kind for q in qb]
        assert [q.qseed for q in qa] == [q.qseed for q in qb]
    assert all(q.kind == "range" for q in a.queries if q.tenant == "bulk")


def test_tenant_stream_invariant_under_mix_changes():
    """Per-tenant child generators: adding a tenant to the mix must not
    change an existing tenant's query stream (same seed)."""
    solo = generate_trace(GOLDEN_TENANTS[:1], 120, mode="open",
                          duration_s=0.5, seed=42)
    mixed = generate_trace(GOLDEN_TENANTS, 120, mode="open",
                           duration_s=0.5, seed=42)
    mine = [q for q in mixed.queries if q.tenant == "interactive"]
    assert [(q.t, q.qseed, q.kind) for q in solo.queries] \
        == [(q.t, q.qseed, q.kind) for q in mine]


def test_materialise_is_deterministic():
    from repro.graphs.generators import aids_like_db
    db = aids_like_db(120, seed=3)
    trace = TrafficTrace.from_json(_golden("closed"))
    g1 = trace.materialise(db)
    g2 = trace.materialise(db)
    assert len(g1) == len(trace.queries)
    for a, b in zip(g1, g2):
        assert a.n == b.n and np.array_equal(a.vlabels, b.vlabels)
        assert np.array_equal(a.edges, b.edges)


def test_generate_rejects_unknown_mode():
    with pytest.raises(ValueError):
        generate_trace(GOLDEN_TENANTS, 10, mode="lockstep")


def test_percentile_nearest_rank():
    xs = [0.1, 0.2, 0.3, 0.4]
    assert percentile(xs, 50) == 0.2
    assert percentile(xs, 99) == 0.4
    assert np.isnan(percentile([], 50))


@pytest.mark.parametrize("mode", ["open", "closed"])
def test_replay_issues_every_query_and_reports(mode):
    """Live replay against a tiny pipeline: every scheduled query is
    issued and observed, per-tenant buckets are complete, and the report
    carries finite percentiles (deadlines off so nothing is partial)."""
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db
    from repro.serve.graph_engine import GraphQueryEngine
    from repro.serve.pipeline import AsyncGraphQueryEngine

    db = aids_like_db(60, seed=3)
    tenants = [TenantSpec(t.name, weight=t.weight, rate_qps=t.rate_qps,
                          clients=t.clients,
                          queries_per_client=t.queries_per_client,
                          topk_frac=t.topk_frac, tau_range=t.tau_range,
                          k_range=t.k_range, cap=t.cap, deadline_s=None,
                          edits_range=t.edits_range)
               for t in GOLDEN_TENANTS]
    trace = generate_trace(tenants, len(db), mode=mode, duration_s=0.25,
                           seed=5)
    eng = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    pipe = AsyncGraphQueryEngine(eng, max_batch=4, max_delay_s=0.002,
                                 num_workers=2)
    try:
        report = replay(trace, pipe, db, speed=4.0)
    finally:
        pipe.close()
    rep = report.to_json()
    assert rep["overall"]["n"] == len(trace.queries)
    assert rep["overall"]["errors"] == 0
    assert rep["overall"]["partial_rate"] == 0.0   # no deadlines set
    assert sum(b["n"] for b in rep["per_tenant"].values()) \
        == len(trace.queries)
    for b in rep["per_tenant"].values():
        assert b["p50_ms"] > 0 and b["p99_ms"] >= b["p50_ms"]
        assert b["goodput_qps"] > 0
