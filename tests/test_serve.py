"""Serving engine: batched request completion + determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_params
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-1.7b")).replace(n_units=1)
    params = build_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n, plen=6, new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, plen)
                    .astype(np.int32), max_new_tokens=new)
            for _ in range(n)]


def test_all_requests_complete(small_model):
    cfg, params = small_model
    reqs = _reqs(cfg, 5)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert eng.stats["tokens"] > 0


def test_greedy_decode_deterministic(small_model):
    cfg, params = small_model
    a = _reqs(cfg, 2, seed=3)
    b = _reqs(cfg, 2, seed=3)
    ServeEngine(cfg, params, batch_size=2, max_len=32).run(a)
    ServeEngine(cfg, params, batch_size=2, max_len=32).run(b)
    for ra, rb in zip(a, b):
        assert ra.out_tokens == rb.out_tokens
