"""Chaos suite: every injection point resolves typed, never hangs.

The fault-tolerance invariant (DESIGN.md §18): under any injected fault
— filter-batch exception, device-op failure, verifier worker kill,
overload burst — every ticket resolves to a result or a typed
``QueryError``, every *completed* query's matches are bit-identical to
the fault-free run (the degradation ladder trades latency for
availability, never recall), and every ladder decision is visible in
the metrics snapshot.  Schedules are deterministic (seeded
``FaultInjector``), so outcomes are asserted exactly.
"""
import numpy as np
import pytest

from repro.core.search import FlatMSQIndex
from repro.serve.errors import (AdmissionError, FilterStageError,
                                QueryError)
from repro.serve.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serve.graph_engine import GraphQuery, GraphQueryEngine
from repro.serve.pipeline import AsyncGraphQueryEngine


@pytest.fixture(scope="module")
def small_db():
    from repro.graphs.generators import aids_like_db
    return aids_like_db(120, seed=9)


@pytest.fixture(scope="module")
def flat(small_db):
    """Read-only index for tests that never trip a ladder.  Tests that
    mutate shared evaluator state (health machines, slab rebuilds)
    build their own FlatMSQIndex instead."""
    return FlatMSQIndex(small_db)


def _requests(db, n, seed, tau_hi=3, **kw):
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tau = int(rng.integers(1, tau_hi))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        out.append(GraphQuery(h, tau, **kw))
    return out


def _assert_same(got, ref):
    for a, b in zip(got, ref):
        assert a.candidates == b.candidates
        assert a.matches == b.matches
        assert a.n_filtered == b.n_filtered


# --------------------------------------------------------------------------
# filter stage: a poisoned batch fails typed; the pipeline survives
# --------------------------------------------------------------------------

def test_filter_batch_fault_fails_only_struck_batch(small_db, flat):
    reqs = _requests(small_db, 5, seed=21)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)

    faults = FaultInjector([FaultSpec("filter.batch", on_calls=(2,))])
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=1, num_workers=1,
                               faults=faults) as apipe:
        tickets = apipe.submit_many(reqs)
        outcomes = []
        for t in tickets:
            try:
                outcomes.append(t.result(timeout=90))
            except QueryError as e:
                outcomes.append(e)
    # max_batch=1: batch i is ticket i, so call #2 strikes exactly one
    struck = [o for o in outcomes if isinstance(o, Exception)]
    assert len(struck) == 1 and isinstance(outcomes[1], FilterStageError)
    assert isinstance(outcomes[1].cause, InjectedFault)
    assert outcomes[1].stage == "filter"
    ok = [(o, r) for o, r in zip(outcomes, ref)
          if not isinstance(o, Exception)]
    _assert_same(*zip(*ok))
    assert faults.count("filter.batch") == 5


def test_filter_batch_delay_is_latency_only(small_db, flat):
    reqs = _requests(small_db, 4, seed=22)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    faults = FaultInjector(
        [FaultSpec("filter.batch", kind="delay", every=1, delay_s=0.02)])
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=2, num_workers=2,
                               faults=faults) as apipe:
        out = [t.result(timeout=90) for t in apipe.submit_many(reqs)]
    _assert_same(out, ref)
    assert len(faults.fired_at("filter.batch")) >= 2


# --------------------------------------------------------------------------
# device faults: the backend ladder keeps answers bit-identical
# --------------------------------------------------------------------------

def _jax_eval(index, backend="jax"):
    evs = [e for e in index._filter_evals.values() if e.backend == backend]
    assert len(evs) == 1
    return evs[0]


def test_device_fault_ladder_falls_back_bit_identical(small_db):
    """Every jax device pass fails -> the numpy rung answers; candidates
    and matches are bit-identical and the fallback is visible in both
    ladder_stats and the engine's metrics snapshot."""
    index = FlatMSQIndex(small_db)
    reqs = _requests(small_db, 8, seed=23)
    ref = GraphQueryEngine(index, backend="numpy").submit(reqs)

    faults = FaultInjector([FaultSpec("device.filter", every=1)])
    eng = GraphQueryEngine(index, backend="jax", faults=faults)
    _assert_same(eng.submit(reqs), ref)

    ev = _jax_eval(index)
    assert ev.ladder_stats["backend_fallbacks"] >= 1
    snap = eng.obs.metrics.snapshot()
    assert snap["counters"].get("filter.backend_fallbacks", 0) >= 1
    assert "health.filter_backend" in snap["gauges"]


def test_device_fault_sticky_skip_then_probe_recovery(small_db):
    """Three consecutive device failures trip FAILING (sticky-skip);
    once the fault schedule is exhausted, the periodic probe restores
    HEALTHY — and every answer along the way matched the numpy rung."""
    index = FlatMSQIndex(small_db)
    faults = FaultInjector([FaultSpec("device.filter", every=1, times=3)])
    eng = GraphQueryEngine(index, backend="jax", faults=faults)
    refeng = GraphQueryEngine(index, backend="numpy")

    ev = None
    for i in range(20):
        reqs = _requests(small_db, 3, seed=100 + i)
        _assert_same(eng.submit(reqs), refeng.submit(reqs))
        ev = _jax_eval(index)
        if ev.ladder_stats["primary_skips"] and \
                ev.backend_health.state == "healthy":
            break
    assert ev.backend_health.state == "healthy"     # probe recovered
    assert ev.ladder_stats["backend_fallbacks"] == 3
    assert ev.ladder_stats["primary_skips"] >= 1    # sticky-skip happened
    snap = eng.obs.metrics.snapshot()
    assert snap["gauges"]["health.filter_backend"] == 0


def test_slab_decode_fault_steps_packed_to_hot(small_db):
    """Repeated decode-attributed failures rebuild the resident slab one
    rung denser (packed -> hot); candidates stay bit-identical."""
    index = FlatMSQIndex(small_db)
    reqs = _requests(small_db, 8, seed=24)
    ref = GraphQueryEngine(index, backend="numpy").submit(reqs)

    faults = FaultInjector(
        [FaultSpec("device.decode", every=1, times=2, tag="decode")])
    eng = GraphQueryEngine(index, backend="jax", slab_layout="packed",
                           faults=faults)
    _assert_same(eng.submit(reqs), ref)

    evs = [e for e in index._filter_evals.values()
           if e.backend == "jax" and e.slab_layout == "hot"]
    assert len(evs) == 1, "packed slab should have been rebuilt as hot"
    assert evs[0].ladder_stats["slab_fallbacks"] == 1
    snap = eng.obs.metrics.snapshot()
    assert snap["counters"].get("filter.slab_fallbacks", 0) == 1


def test_device_cache_fault_falls_back(small_db):
    """An upload-build failure inside DeviceSlabCache attributes to the
    device rung and falls back recall-safe."""
    index = FlatMSQIndex(small_db)
    reqs = _requests(small_db, 6, seed=25)
    ref = GraphQueryEngine(index, backend="numpy").submit(reqs)
    faults = FaultInjector([FaultSpec("device.cache", on_calls=(1,))])
    eng = GraphQueryEngine(index, backend="jax", faults=faults)
    _assert_same(eng.submit(reqs), ref)
    assert _jax_eval(index).ladder_stats["backend_fallbacks"] >= 1


# --------------------------------------------------------------------------
# verify stage: slice faults are contained per pair, pools are rebuilt
# --------------------------------------------------------------------------

def test_verify_slice_fault_contained_per_pair(small_db, flat):
    reqs = _requests(small_db, 6, seed=26)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    assert sum(len(r.candidates) for r in ref) > 2

    faults = FaultInjector([FaultSpec("verify.slice", on_calls=(2,))])
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2,
                               faults=faults) as apipe:
        out = [t.result(timeout=90) for t in apipe.submit_many(reqs)]
    # exactly one pair was struck: contained as unverified, flagged
    # partial on its query; everything else is bit-identical
    assert apipe.scheduler.stats["error_pairs"] == 1
    partial = [o for o in out if o.stats.get("partial")]
    assert len(partial) == 1
    _assert_same(*zip(*[(o, r) for o, r in zip(out, ref)
                        if not o.stats.get("partial")]))
    for o, r in zip(out, ref):
        assert o.candidates == r.candidates     # recall-safe even when hit


def test_worker_kill_through_async_pipeline(small_db, flat):
    """A SIGKILLed pool worker mid-run: the broken pool is rebuilt, the
    in-flight searches resume at their frontiers, and the final matches
    are bit-identical — the kill is completely recoverable."""
    reqs = _requests(small_db, 6, seed=27)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    faults = FaultInjector(
        [FaultSpec("verify.pool", kind="kill_worker", on_calls=(2,))],
        seed=3)
    eng = GraphQueryEngine(flat, backend="numpy", faults=faults)
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2,
                               verify_executor="process",
                               slice_expansions=40,
                               faults=faults) as apipe:
        out = [t.result(timeout=180) for t in apipe.submit_many(reqs)]
    _assert_same(out, ref)
    sched = apipe.scheduler.stats
    assert sched["pool_rebuilds"] >= 1
    assert sched["error_pairs"] == 0
    assert faults.fired_at("verify.pool")


# --------------------------------------------------------------------------
# overload burst: bounded inbox, typed rejections, tenant-weighted shed
# --------------------------------------------------------------------------

def test_overload_reject_policy(small_db, flat):
    reqs = _requests(small_db, 8, seed=28)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    eng = GraphQueryEngine(flat, backend="numpy")
    # a huge batch + delay keeps the former waiting, so the burst lands
    # on a full inbox deterministically; close() flushes the admitted
    with AsyncGraphQueryEngine(eng, max_batch=64, max_delay_s=5.0,
                               inbox_limit=3,
                               shed_policy="reject") as apipe:
        tickets = apipe.submit_many(reqs)
        rejected = []
        for t in tickets[3:]:
            with pytest.raises(AdmissionError) as ei:
                t.result(timeout=10)
            rejected.append(ei.value)
        apipe.close()
        out = [t.result(timeout=90) for t in tickets[:3]]
    _assert_same(out, ref[:3])
    assert all(e.policy == "reject" and not e.shed for e in rejected)
    assert apipe.stats["rejected"] == 5
    assert apipe.stats["shed"] == 0
    assert apipe.stats["inbox_hwm"] == 3


def test_overload_inbox_bytes_bound(small_db, flat):
    reqs = _requests(small_db, 3, seed=29)
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=64, max_delay_s=5.0,
                               inbox_bytes=1,
                               shed_policy="reject") as apipe:
        tickets = apipe.submit_many(reqs)
        # an empty inbox always admits (no livelock on oversized
        # requests); the rest bounce off the byte budget
        with pytest.raises(AdmissionError):
            tickets[1].result(timeout=10)
        with pytest.raises(AdmissionError):
            tickets[2].result(timeout=10)
        apipe.close()
        assert tickets[0].result(timeout=90) is not None
    assert apipe.stats["rejected"] == 2
    assert apipe.stats["inbox_bytes_hwm"] > 1


def test_overload_shed_oldest_tenant_weights(small_db, flat):
    """shed_oldest victims come from the tenant with the highest
    weighted occupancy: tenant B (weight 0.5) is shed to admit tenant
    A's burst (weight 4.0), and the shed tickets resolve typed."""
    base = _requests(small_db, 6, seed=30)
    for q, ten in zip(base, ("A", "A", "B", "B", "A", "A")):
        q.tenant = ten
    ref = GraphQueryEngine(flat, backend="numpy").submit(base)

    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=64, max_delay_s=5.0,
                               inbox_limit=4, shed_policy="shed_oldest",
                               tenant_weights={"A": 4.0, "B": 0.5}
                               ) as apipe:
        tickets = apipe.submit_many(base)
        shed = []
        for t in (tickets[2], tickets[3]):      # B's two queries
            with pytest.raises(AdmissionError) as ei:
                t.result(timeout=10)
            shed.append(ei.value)
        apipe.close()
        out = [tickets[i].result(timeout=90) for i in (0, 1, 4, 5)]
    _assert_same(out, [ref[i] for i in (0, 1, 4, 5)])
    assert all(e.shed and e.policy == "shed_oldest" and e.tenant == "B"
               for e in shed)
    assert apipe.stats["shed"] == 2 and apipe.stats["rejected"] == 0


# --------------------------------------------------------------------------
# close()/shutdown() racing in-flight top-k escalation under faults
# --------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["thread", "process"])
def test_close_races_topk_escalation_under_faults(small_db, flat, executor):
    """close() must drain in-flight top-k escalation rounds and tear
    down the (possibly just-poisoned) pool without hanging; surviving
    results stay bit-identical to the fault-free sync run."""
    reqs = _requests(small_db, 4, seed=31, tau_hi=4)
    topk = [GraphQuery(q.graph, tau=q.tau + 2, top_k=2) for q in reqs[:2]]
    mix = topk + reqs[2:]
    ref = GraphQueryEngine(flat, backend="numpy").submit(mix)

    if executor == "process":
        faults = FaultInjector(
            [FaultSpec("verify.pool", kind="kill_worker", on_calls=(2,))],
            seed=7)
    else:
        faults = FaultInjector([FaultSpec("verify.slice", on_calls=(3,))])
    eng = GraphQueryEngine(flat, backend="numpy", faults=faults)
    apipe = AsyncGraphQueryEngine(eng, max_batch=2, num_workers=2,
                                  verify_executor=executor,
                                  slice_expansions=30, faults=faults)
    try:
        tickets = apipe.submit_many(mix)
    finally:
        apipe.close(timeout=120)    # races escalation + pool teardown
    out = [t.result(timeout=10) for t in tickets]   # all resolved already
    clean = [(o, r) for o, r in zip(out, ref) if not o.stats.get("partial")]
    _assert_same(*zip(*clean))
    if executor == "process":
        assert len(clean) == len(mix)       # a kill is fully recoverable
        assert apipe.scheduler.stats["pool_rebuilds"] >= 1
    else:
        assert len(clean) >= len(mix) - 1   # one struck pair at most
    # idempotent + still no hang
    apipe.close(timeout=30)


def test_injector_summary_shape():
    faults = FaultInjector([FaultSpec("filter.batch", on_calls=(1,))])
    with pytest.raises(InjectedFault):
        faults.fire("filter.batch")
    faults.fire("admit")
    s = faults.summary()
    assert s == {"calls": {"filter.batch": 1, "admit": 1},
                 "fired": {"filter.batch:raise": 1}, "n_fired": 1}
    faults.reset()
    assert faults.summary()["n_fired"] == 0
