"""Single-host vs sharded GraphQueryEngine parity (multi-device CPU mesh).

The acceptance invariant of the distributed serving path: on a >= 2-device
mesh, in BOTH layouts (graph-sharded and vocab-sharded), the
``ShardedGraphQueryEngine``'s candidate ids and final ``QueryResult``s are
IDENTICAL to the single-host engine for mixed-tau batches — including
buckets whose fixed-size candidate blocks overflow (the recall-safe exact
fallback, never a silent drop).

The main test process must keep seeing 1 device (the dry-run owns the
512-device override), so each scenario runs as a child python with
XLA_FLAGS=--xla_force_host_platform_device_count set in its environment,
same pattern as tests/test_distributed_subprocess.py.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_engine_parity_both_layouts():
    """Mixed-tau batch (some verified): candidates, matches and n_filtered
    match the single-host engine in graph- and vocab-sharded layouts."""
    run_child("""
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(150, seed=11)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(10):
        tau = int(rng.integers(1, 5))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=(i % 3 == 0)))
    ref = single.submit(reqs)

    mesh = jc.make_mesh((2, 4), ("data", "model"))
    for layout in ("graph", "vocab"):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, layout=layout,
                                      k=64, shard_pad=64)
        out = eng.submit(reqs)
        for a, b in zip(out, ref):
            assert a.candidates == b.candidates, layout
            assert a.matches == b.matches, layout
            assert a.n_filtered == b.n_filtered, layout
    print("OK")
    """)


def test_sharded_engine_overflow_falls_back_exactly():
    """k=1 forces per-device candidate-block overflow; the exact fallback
    must keep candidate sets bit-identical (and must actually trigger)."""
    run_child("""
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(300, seed=11)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(2)
    reqs = []
    for _ in range(8):
        tau = int(rng.integers(4, 7))       # wide taus -> crowded buckets
        h = perturb_graph(db[int(rng.integers(0, len(db)))], 2, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=False))
    ref = single.submit(reqs)
    assert max(len(r.candidates) for r in ref) > 1   # something to overflow

    mesh = jc.make_mesh((2, 4), ("data", "model"))
    for layout in ("graph", "vocab"):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, layout=layout,
                                      k=1, shard_pad=64)
        out = eng.submit(reqs)
        for a, b in zip(out, ref):
            assert a.candidates == b.candidates, layout
        assert eng.shard_stats["overflow_blocks"] > 0, layout
    print("OK")
    """)


def test_sharded_engine_slab_layouts_eight_devices():
    """FilterSlab x sharding-layout matrix on the 8-device mesh: hot
    (graph- and vocab-sharded, tail correction psum'd then added) and
    packed (graph-sharded; words rows shard, decode inside shard_map)
    stay bit-identical to the single-host dense engine; packed + vocab
    refuses cleanly (DESIGN.md §11)."""
    run_child("""
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(120, seed=11)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(8):
        tau = int(rng.integers(1, 5))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=(i % 4 == 0)))
    ref = single.submit(reqs)

    mesh = jc.make_mesh((2, 4), ("data", "model"))
    for layout, slab in (("graph", "hot"), ("vocab", "hot"),
                         ("graph", "packed")):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, layout=layout,
                                      slab_layout=slab, hot_d=4,
                                      k=64, shard_pad=64)
        out = eng.submit(reqs)
        for a, b in zip(out, ref):
            assert a.candidates == b.candidates, (layout, slab)
            assert a.matches == b.matches, (layout, slab)
    try:
        ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, layout="vocab",
                                slab_layout="packed")
        raise AssertionError("vocab+packed must refuse")
    except ValueError:
        pass
    print("OK")
    """)


def test_sharded_engine_single_device_mesh_all_slabs():
    """Degenerate 1-device mesh: the shard_map path must stay bit-identical
    for every slab layout (shard == whole slab, no collectives needed)."""
    run_child("""
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(90, seed=5)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(7)
    reqs = []
    for _ in range(5):
        tau = int(rng.integers(1, 4))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=False))
    ref = single.submit(reqs)

    mesh = jc.make_mesh((1,), ("data",))
    for slab in ("dense", "hot", "packed"):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh,
                                      layout="graph", slab_layout=slab,
                                      hot_d=4, k=32, shard_pad=64)
        out = eng.submit(reqs)
        for a, b in zip(out, ref):
            assert a.candidates == b.candidates, slab
    print("OK")
    """, devices=1)


def test_sharded_engine_succinct_slab_overflow_falls_back_exactly():
    """k=1 forces candidate-block overflow with the succinct slabs: the
    exact host fallback re-evaluates through the same slab layout, so
    candidates stay bit-identical (and overflow must actually trigger)."""
    run_child("""
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(150, seed=11)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(2)
    reqs = []
    for _ in range(6):
        tau = int(rng.integers(4, 7))       # wide taus -> crowded buckets
        h = perturb_graph(db[int(rng.integers(0, len(db)))], 2, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=False))
    ref = single.submit(reqs)
    assert max(len(r.candidates) for r in ref) > 1   # something to overflow

    mesh = jc.make_mesh((2,), ("data",))
    for slab in ("hot", "packed"):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh,
                                      layout="graph", slab_layout=slab,
                                      hot_d=4, k=1, shard_pad=64)
        out = eng.submit(reqs)
        for a, b in zip(out, ref):
            assert a.candidates == b.candidates, slab
        assert eng.shard_stats["overflow_blocks"] > 0, slab
    print("OK")
    """, devices=2)


def test_sharded_engine_two_device_mesh_and_config():
    """Minimum mesh (2 devices, 'data' only) + layout selection from the
    MSQConfig (msq_pubchem -> vocab-sharded needs a model axis, so the
    2-device case exercises the graph-sharded config default)."""
    run_child("""
    import numpy as np
    from repro.configs.msq_aids import get_config as aids_cfg
    from repro.configs.msq_pubchem import get_config as pubchem_cfg
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    assert aids_cfg().sharded_layout == "graph"
    assert aids_cfg().slab_layout == "dense"
    assert pubchem_cfg().sharded_layout == "vocab"
    assert pubchem_cfg().slab_layout == "hot"   # succinct serving default

    db = aids_like_db(120, seed=5)
    single = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    rng = np.random.default_rng(7)
    reqs = []
    for _ in range(6):
        tau = int(rng.integers(1, 4))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=True))
    ref = single.submit(reqs)

    mesh = jc.make_mesh((2,), ("data",))
    eng = ShardedGraphQueryEngine.from_config(FlatMSQIndex(db), mesh,
                                              aids_cfg(), shard_pad=64)
    out = eng.submit(reqs)
    for a, b in zip(out, ref):
        assert a.candidates == b.candidates
        assert a.matches == b.matches
    print("OK")
    """, devices=2)


_TOPK_CHILD = """
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.core.verify import ged_upto
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    db = aids_like_db(90, seed=9)
    rng = np.random.default_rng(17)
    qs = [perturb_graph(db[int(rng.integers(0, len(db)))],
                        int(rng.integers(1, 3)), rng, db.n_vlabels,
                        db.n_elabels) for _ in range(3)]
    reqs = [GraphQuery(g, cap, top_k=k)
            for g in qs for k, cap in ((1, 3), (3, 4))]

    def oracle(g, k, cap):
        ds = sorted((ged_upto(g, h, cap), gid)
                    for gid, h in enumerate(db))
        return [(gid, d) for d, gid in ds if d <= cap][:k]

    want = [oracle(g, k, cap) for g in qs for k, cap in ((1, 3), (3, 4))]
    ref = GraphQueryEngine(FlatMSQIndex(db), backend="numpy",
                           result_cache_size=0).submit(reqs)
    for got, w in zip(ref, want):
        assert [tuple(m) for m in got.matches] == w

    mesh = jc.make_mesh(MESH_SHAPE, MESH_AXES)
    for slab in ("dense", "hot", "packed"):
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh,
                                      layout="graph", slab_layout=slab,
                                      hot_d=4, k=32, shard_pad=64,
                                      result_cache_size=0)
        out = eng.submit(reqs)
        for got, b, w in zip(out, ref, want):
            assert [tuple(m) for m in got.matches] == w, slab
            assert got.candidates == b.candidates, slab
        decided = (eng.stats["verified_pairs"] + eng.stats["pruned_pairs"]
                   + eng.stats["expired_pairs"])
        assert decided == sum(len(r.candidates) for r in out), slab
    print("OK")
"""


def test_sharded_engine_topk_single_device_mesh():
    """Top-k through the shard_map path, 1-device mesh, every slab
    layout: matches are bit-identical to the brute-force oracle and to
    the single-host engine, and escalation never re-decides a pair."""
    run_child(_TOPK_CHILD.replace("MESH_SHAPE", "(1,)")
              .replace("MESH_AXES", '("data",)'), devices=1)


def test_sharded_engine_topk_two_device_mesh():
    """Same top-k oracle parity on the minimum real mesh (2 devices)."""
    run_child(_TOPK_CHILD.replace("MESH_SHAPE", "(2,)")
              .replace("MESH_AXES", '("data",)'), devices=2)
