"""End-to-end behaviour tests for the paper's system (MSQ-Index).

The top-level invariant chain: generators -> q-grams -> filters -> succinct
tree -> region reduction -> Algorithm 2 -> A* verification produces EXACTLY
the graphs within GED tau of the query — validated against exhaustive
per-graph ``ged_upto``.
"""
import numpy as np
import pytest

from repro.core.search import FlatMSQIndex, MSQIndex
from repro.core.verify import ged_upto
from repro.graphs.generators import aids_like_db, perturb_graph


@pytest.fixture(scope="module")
def db():
    return aids_like_db(120, seed=21)


@pytest.fixture(scope="module")
def index(db):
    return MSQIndex(db)


@pytest.mark.parametrize("qi,tau", [(0, 1), (33, 2), (64, 3), (99, 4)])
def test_query_exactness(db, index, qi, tau):
    rng = np.random.default_rng(qi)
    h = perturb_graph(db[qi], tau, rng, db.n_vlabels, db.n_elabels)
    res = index.query(h, tau)
    truth = sorted(i for i in range(len(db))
                   if ged_upto(db[i], h, tau) <= tau)
    assert sorted(m[0] for m in res.matches) == truth
    # the perturbed source graph must be found (ged <= tau by construction)
    assert qi in [m[0] for m in res.matches]
    # every reported distance is exact
    for gid, d in res.matches:
        assert d == ged_upto(db[gid], h, tau)
        assert d <= tau


def test_candidates_never_below_matches(db, index):
    rng = np.random.default_rng(5)
    h = perturb_graph(db[10], 2, rng, db.n_vlabels, db.n_elabels)
    res = index.query(h, 2)
    assert set(m[0] for m in res.matches) <= set(res.candidates)
    assert res.n_filtered == len(db) - len(res.candidates)


def test_build_time_and_sizes_reported(index):
    assert index.build_time_s > 0
    sizes = index.size_bits()
    assert sizes["total"] > 0
    assert set(sizes) == {"S_a", "S_b", "S_c", "total"}


def test_flat_and_tree_agree_large_tau(db, index):
    flat = FlatMSQIndex(db)
    rng = np.random.default_rng(6)
    h = perturb_graph(db[50], 5, rng, db.n_vlabels, db.n_elabels)
    assert index.candidates(h, 6)[0] == flat.candidates(h, 6)
