"""FilterSlab layouts (DESIGN.md §11): codec edge cases + parity matrix.

Two invariants:

* the packed/hot codecs round-trip exactly (host packer vs numpy / jnp /
  Pallas-kernel decoders, incl. empty, all-zero and single-value blocks);
* candidate sets are bit-identical across every FilterSlab layout and
  every single-host backend (the distributed matrix lives in
  tests/test_sharded_engine.py).
"""
import numpy as np
import pytest

from repro.core.qgrams import EncodedDB
from repro.core.region import default_partition
from repro.core.slab import FilterSlab
from repro.core.succinct import HybridEncodedArray
from repro.graphs.generators import aids_like_db, perturb_graph
from repro.kernels.bitunpack.ops import (flatten_packed_rows, pack_hybrid,
                                         pack_hybrid_rows,
                                         packed_rows_size_bits,
                                         unpack_hybrid, unpack_rows_np)


# --------------------------------------------------------------------------
# HybridEncodedArray edge cases (the archival hybrid coder)
# --------------------------------------------------------------------------

def test_hybrid_array_empty():
    arr = HybridEncodedArray([], block=16)
    assert arr.n == 0
    assert arr.decode_all().tolist() == []
    assert arr.access_bulk(np.zeros(0, np.int64)).tolist() == []
    assert arr.size_bits().s_bits == 0
    with pytest.raises(IndexError):
        arr.access(0)


def test_hybrid_array_single_value_blocks():
    # constant blocks: fixed path wins, every entry 1 bit wide for value 1
    for v in (1, 7, 255):
        arr = HybridEncodedArray([v] * 48, block=16)
        assert arr.decode_all().tolist() == [v] * 48
        assert arr.access(47) == v


def test_hybrid_array_out_of_order_access_bulk():
    rng = np.random.default_rng(3)
    values = rng.integers(1, 300, 200).tolist()
    arr = HybridEncodedArray(values, block=16)
    idx = rng.permutation(200)[:50]
    want = np.asarray(values, np.int64)[idx]
    assert np.array_equal(arr.access_bulk(idx), want)
    # repeats + reversed order
    idx2 = np.array([5, 5, 199, 0, 120, 0])
    assert np.array_equal(arr.access_bulk(idx2),
                          np.asarray(values, np.int64)[idx2])


def test_hybrid_array_rejects_zeros():
    with pytest.raises(ValueError):
        HybridEncodedArray([1, 0, 2])


# --------------------------------------------------------------------------
# pack_hybrid / unpack_hybrid (flat kernel format) edge cases
# --------------------------------------------------------------------------

def test_pack_hybrid_empty_and_all_zero_blocks():
    for vals in (np.zeros(0, np.int64), np.zeros(128, np.int64),
                 np.zeros(300, np.int64)):
        words, sb, widths, nv = pack_hybrid(vals)
        assert nv == len(vals)
        out = np.asarray(unpack_hybrid(sb, widths, words, nv,
                                       interpret=True))
        assert np.array_equal(out, vals)
        # all-zero blocks take the narrowest width
        assert (widths == 2).all()


def test_pack_hybrid_single_value_blocks_ref_vs_kernel():
    from repro.kernels.bitunpack.ref import unpack_hybrid_ref
    import jax.numpy as jnp
    vals = np.concatenate([np.full(128, 3, np.int64),
                           np.full(128, 65535, np.int64),
                           np.full(17, 1, np.int64)])
    words, sb, widths, nv = pack_hybrid(vals)
    out = np.asarray(unpack_hybrid(sb, widths, words, nv, interpret=True))
    ref = np.asarray(unpack_hybrid_ref(jnp.asarray(sb), jnp.asarray(widths),
                                       jnp.asarray(words)))
    assert np.array_equal(out, vals)
    assert np.array_equal(ref.reshape(-1)[:nv], vals)


# --------------------------------------------------------------------------
# pack_hybrid_rows (the rectangular packed slab)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,U,hi", [
    (7, 5, 4), (16, 300, 3), (3, 130, 70000), (4, 1, 2), (6, 40, 1),
])
def test_pack_rows_roundtrip_np_jnp_kernel(B, U, hi):
    import jax.numpy as jnp
    from repro.kernels.bitunpack.ref import unpack_rows_ref
    rng = np.random.default_rng(B * U + hi)
    mat = rng.integers(0, hi, (B, U)).astype(np.int64)
    pk = pack_hybrid_rows(mat)
    assert np.array_equal(unpack_rows_np(pk), mat)
    ref = np.asarray(unpack_rows_ref(jnp.asarray(pk.words),
                                     jnp.asarray(pk.sb),
                                     jnp.asarray(pk.widths)))
    assert np.array_equal(ref[:, :U], mat)
    words, sb, widths = flatten_packed_rows(pk)
    KB = pk.sb.shape[1]
    out = np.asarray(unpack_hybrid(sb, widths, words, interpret=True))
    assert np.array_equal(out.reshape(B, KB * 128)[:, :U], mat)


def test_pack_rows_zero_matrix_and_empty():
    pk = pack_hybrid_rows(np.zeros((5, 64), np.int64))
    assert np.array_equal(unpack_rows_np(pk), np.zeros((5, 64)))
    pk0 = pack_hybrid_rows(np.zeros((0, 10), np.int64))
    assert unpack_rows_np(pk0).shape == (0, 10)


def test_pack_rows_rejects_negative():
    with pytest.raises(ValueError):
        pack_hybrid_rows(np.array([[1, -1]]))


def test_packed_rows_measurably_smaller_than_dense():
    rng = np.random.default_rng(0)
    mat = rng.integers(0, 4, (200, 256)).astype(np.int64)
    bits = packed_rows_size_bits(pack_hybrid_rows(mat))
    dense_bits = mat.size * 32
    assert bits["total"] < 0.25 * dense_bits   # small counts -> ~2-4 bits


# --------------------------------------------------------------------------
# FilterSlab: tail correction + layout parity
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(90, seed=11)


def test_tail_intersection_bulk_matches_scalar(small_db):
    enc = EncodedDB.build(small_db)
    rng = np.random.default_rng(5)
    U = enc.vocab.n_degree_ids
    for hot_d in (0, 3, U // 2, U):
        q_ids = np.sort(rng.choice(U, size=min(12, U), replace=False))
        q_cnt = rng.integers(1, 4, len(q_ids))
        q_sparse = {int(i): int(c) for i, c in zip(q_ids, q_cnt)}
        bulk = enc.tail_intersection_bulk(q_ids, q_cnt, hot_d)
        for i in range(0, len(small_db), 7):
            assert bulk[i] == enc.tail_intersection(i, q_sparse, hot_d)


def test_hot_slab_cd_matches_dense(small_db):
    enc = EncodedDB.build(small_db)
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    dense = FilterSlab.build(small_db, enc, part, layout="dense")
    rng = np.random.default_rng(7)
    qfd = np.zeros(dense.U, np.int64)
    pick = rng.choice(dense.U, size=min(20, dense.U), replace=False)
    qfd[pick] = rng.integers(1, 5, len(pick))
    want = dense.cd_one(qfd)
    for hot_d in (1, 4, dense.U):
        hot = FilterSlab.build(small_db, enc, part, layout="hot",
                               hot_d=hot_d)
        assert np.array_equal(hot.cd_one(qfd), want), hot_d
    packed = FilterSlab.build(small_db, enc, part, layout="packed")
    assert np.array_equal(packed.cd_one(qfd), want)


def test_slab_gather_pads_are_inert(small_db):
    enc = EncodedDB.build(small_db)
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    for layout in ("dense", "hot", "packed"):
        slab = FilterSlab.build(small_db, enc, part, layout=layout,
                                hot_d=4)
        sub = slab.gather(np.array([5, 2, 17]), n_pad=8)
        assert sub.B == 8
        qfd = np.ones(slab.U, np.int64)
        cd = sub.cd_one(qfd)
        assert (cd[3:] == 0).all(), layout       # pad rows contribute 0
        assert np.array_equal(cd[:3], slab.cd_one(qfd)[[5, 2, 17]])


def test_slab_size_accounting(small_db):
    enc = EncodedDB.build(small_db)
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    dense = FilterSlab.build(small_db, enc, part, layout="dense")
    hot = FilterSlab.build(small_db, enc, part, layout="hot", hot_d=16)
    packed = FilterSlab.build(small_db, enc, part, layout="packed")
    assert hot.bits_per_graph() < dense.bits_per_graph()
    assert packed.bits_per_graph() < 0.5 * dense.bits_per_graph()


def test_layout_backend_parity(small_db):
    """Candidate sets and matches bit-identical across the layout x
    single-host-backend matrix (the acceptance invariant, DESIGN.md §11)."""
    from repro.core.search import FlatMSQIndex
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    db = small_db
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(6):
        tau = int(rng.integers(1, 5))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=(i % 3 == 0)))
    ref = GraphQueryEngine(FlatMSQIndex(db), backend="numpy").submit(reqs)

    for backend in ("numpy", "jax", "pallas"):
        for slab in ("dense", "hot", "packed"):
            eng = GraphQueryEngine(FlatMSQIndex(db), backend=backend,
                                   slab_layout=slab, hot_d=4)
            out = eng.submit(reqs)
            for a, b in zip(out, ref):
                assert a.candidates == b.candidates, (backend, slab)
                assert a.matches == b.matches, (backend, slab)
                assert a.n_filtered == b.n_filtered, (backend, slab)


def test_slab_rejects_unknown_layout(small_db):
    enc = EncodedDB.build(small_db)
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    with pytest.raises(ValueError):
        FilterSlab.build(small_db, enc, part, layout="sparse")


# --------------------------------------------------------------------------
# hot_mass: data-tuned hot-prefix width selection
# --------------------------------------------------------------------------

def _fake_enc(counts):
    """EncodedDB stand-in with one row per vocabulary id: id i appears
    counts[i] times (the selector only reads d_ids/d_cnt/vocab width)."""
    from types import SimpleNamespace
    counts = np.asarray(counts, np.int64)
    ids = np.flatnonzero(counts)
    return SimpleNamespace(
        d_ids=ids.astype(np.int32), d_cnt=counts[ids].astype(np.int32),
        vocab=SimpleNamespace(n_degree_ids=len(counts)))


def test_hot_d_from_mass_skewed_synthetic():
    from repro.core.slab import hot_d_from_mass

    # zipf-ish skew over 64 ids: id i carries ~1/(i+1) of the mass
    counts = (1000.0 / (np.arange(64) + 1)).astype(np.int64)
    enc = _fake_enc(counts)
    total = counts.sum()
    for mass in (0.25, 0.5, 0.9, 0.99):
        H = hot_d_from_mass(enc, mass)
        # independent check: smallest prefix covering the target by scan
        cum = 0
        want = 64
        for i, c in enumerate(counts):
            cum += int(c)
            if cum >= mass * total:
                want = i + 1
                break
        assert H == want, (mass, H, want)
        assert counts[:H].sum() >= mass * total
        assert H == 1 or counts[:H - 1].sum() < mass * total


def test_hot_d_from_mass_edge_cases():
    from repro.core.slab import hot_d_from_mass

    skew = _fake_enc([90, 9, 1, 0, 0])
    assert hot_d_from_mass(skew, 0.0) == 1
    assert hot_d_from_mass(skew, 0.9) == 1       # head alone covers 90%
    assert hot_d_from_mass(skew, 0.91) == 2
    assert hot_d_from_mass(skew, 1.0) == 3       # zero-mass tail excluded
    assert hot_d_from_mass(skew, 2.0) == 3       # clamped to full mass
    assert hot_d_from_mass(_fake_enc(np.zeros(4, np.int64)), 0.9) == 1


def test_hot_mass_slab_matches_selector_and_stays_bit_identical(small_db):
    from repro.core.search import FlatMSQIndex
    from repro.core.slab import hot_d_from_mass
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine

    enc = EncodedDB.build(small_db)
    nv, ne = small_db.sizes()
    part = default_partition(nv, ne)
    slab = FilterSlab.build(small_db, enc, part, layout="hot",
                            hot_mass=0.9)
    assert slab.hot_d == hot_d_from_mass(enc, 0.9)
    # an explicit hot_d always wins over hot_mass
    forced = FilterSlab.build(small_db, enc, part, layout="hot", hot_d=3,
                              hot_mass=0.9)
    assert forced.hot_d == 3

    rng = np.random.default_rng(6)
    reqs = [GraphQuery(perturb_graph(small_db[int(rng.integers(0, 90))],
                                     2, rng, small_db.n_vlabels,
                                     small_db.n_elabels), 2, verify=False)
            for _ in range(5)]
    ref = GraphQueryEngine(FlatMSQIndex(small_db),
                           backend="numpy").submit(reqs)
    eng = GraphQueryEngine(FlatMSQIndex(small_db), backend="numpy",
                           slab_layout="hot", hot_mass=0.9)
    out = eng.submit(reqs)
    for a, b in zip(out, ref):
        assert a.candidates == b.candidates


def test_configs_default_hot_mass():
    from repro.configs.msq_aids import get_config as aids
    from repro.configs.msq_pubchem import get_config as pubchem
    from repro.configs.msq_s100k import get_config as s100k

    assert aids().hot_mass is not None
    assert pubchem().hot_mass is not None
    assert pubchem().slab_layout == "hot"
    assert s100k().hot_mass is None     # opt-in, not forced everywhere
