"""Multi-device behaviour via subprocesses (8 fake CPU devices).

The main test process must keep seeing 1 device (the dry-run owns the
512-device override), so every multi-device scenario runs as a child
python with XLA_FLAGS set in its environment:
  * sharded MSQ filter (graph-sharded + vocab-sharded TP) == flat oracle,
  * EP MoE (all_to_all dispatch) == dense MoE,
  * pjit'd train step on a (2,4) mesh == single-device step,
  * elastic checkpoint restore onto a different device count.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_msq_filter_matches_flat():
    run_child("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import aids_like_db, perturb_graph
    from repro.core.search import FlatMSQIndex
    from repro.core import filters_jax as fj
    from repro.core.distributed import (make_sharded_search, pad_db_to_shards,
                                        gather_candidates, pad_vocab)
    db = aids_like_db(96, seed=5)
    flat = FlatMSQIndex(db)
    dbar = fj.db_arrays_from_encoded(flat.enc, flat.partition)
    rng = np.random.default_rng(0)
    part = flat.partition
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((2, 4), ("data", "model"))
    for qi, tau in [(3, 1), (20, 3), (50, 5)]:
        h = perturb_graph(db[qi], tau, rng, db.n_vlabels, db.n_elabels)
        q = fj.query_arrays_from_graph(h, flat.vocab, part, tau,
                                       vmax=dbar.degseq.shape[1])
        cand_np = flat.candidates(h, tau)
        dbp, qp = pad_vocab(pad_db_to_shards(dbar, 2), q, 4)
        fn, _, _ = make_sharded_search(mesh, part.x0, part.y0, part.l, k=64,
                                       batch_axes=("data",), model_axis="model")
        with jc.set_mesh(mesh):
            gids, b, c = fn(jax.tree.map(jnp.asarray, dbp),
                            jax.tree.map(jnp.asarray, qp))
        assert gather_candidates(np.asarray(gids), np.asarray(b),
                                 np.asarray(c)).tolist() == cand_np
        fn2, _, _ = make_sharded_search(mesh, part.x0, part.y0, part.l, k=32,
                                        batch_axes=("data", "model"),
                                        model_axis=None)
        dbp8 = pad_db_to_shards(dbar, 8)
        with jc.set_mesh(mesh):
            gids, b, c = fn2(jax.tree.map(jnp.asarray, dbp8),
                             jax.tree.map(jnp.asarray, q))
        assert gather_candidates(np.asarray(gids), np.asarray(b),
                                 np.asarray(c)).tolist() == cand_np
    print("OK")
    """)


def test_ep_moe_matches_dense():
    run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.models import blocks as B
    from repro.models.layers import init_params
    cfg = reduced(get_config('granite-moe-1b-a400m')).replace(capacity_factor=8.0)
    params = init_params(B.moe_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)
    y_ref = B.moe_apply(params, x, cfg)
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((2, 4), ("data", "model"))
    specs = {"router": P(None, None), "w_gate": P("model", None, None),
             "w_up": P("model", None, None), "w_down": P("model", None, None)}
    fn = jax.jit(jc.shard_map(
        lambda p, xl: B.moe_apply_ep(p, xl, cfg, "model"), mesh=mesh,
        in_specs=(specs, P(("data",), None, None)),
        out_specs=P(("data",), None, None)))
    with jc.set_mesh(mesh):
        y = fn(params, x)
    err = float(jnp.abs(y - y_ref).max())
    assert err < 2e-4, err
    print("OK", err)
    """)


def test_ep_moe_pre_sharded_matches_dense():
    """§Perf-B7 path: activations arrive sequence-sharded over the EP axis;
    the body skips the entry/exit gathers but must stay numerically exact."""
    run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.models import blocks as B
    from repro.models.layers import init_params
    cfg = reduced(get_config('granite-moe-1b-a400m')).replace(capacity_factor=8.0)
    params = init_params(B.moe_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)
    y_ref = B.moe_apply(params, x, cfg)
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((2, 4), ("data", "model"))
    specs = {"router": P(None, None), "w_gate": P("model", None, None),
             "w_up": P("model", None, None), "w_down": P("model", None, None)}
    fn = jax.jit(jc.shard_map(
        lambda p, xl: B.moe_apply_ep(p, xl, cfg, "model", pre_sharded=True),
        mesh=mesh, in_specs=(specs, P(("data",), "model", None)),
        out_specs=P(("data",), "model", None)))
    with jc.set_mesh(mesh):
        y = fn(params, x)
    err = float(jnp.abs(y - y_ref).max())
    assert err < 2e-4, err
    print("OK", err)
    """)


def test_pjit_train_step_matches_single_device():
    run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.models import build_params
    from repro.optim import adamw, cosine_schedule
    from repro.train import make_train_step
    from repro.launch.shardings import param_shardings
    cfg = reduced(get_config('qwen3-1.7b')).replace(n_units=2)
    params = build_params(cfg, jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(cosine_schedule(1e-3, 2, 10))
    opt0 = opt_init(params)
    rng = np.random.default_rng(0)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)))}
    step = make_train_step(cfg, opt_update)
    p1, o1, m1 = jax.jit(step)(params, opt0, batch)

    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((2, 4), ("data", "model"))
    p_sh = param_shardings(cfg, mesh)
    b_sh = {"inputs": NamedSharding(mesh, P(("data",), None)),
            "targets": NamedSharding(mesh, P(("data",), None))}
    f = jax.jit(step, in_shardings=(p_sh, None, b_sh))
    with jc.set_mesh(mesh):
        p2, o2, m2 = f(jax.device_put(params, p_sh), opt0, batch)
    assert abs(float(m1['loss']) - float(m2['loss'])) < 2e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 2e-3, d
    print('OK', float(m1['loss']), d)
    """)


def test_elastic_checkpoint_reshard():
    """Save on 8 devices, restore on 4 — device-count elasticity."""
    import tempfile
    tmp = tempfile.mkdtemp()
    run_child(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import CheckpointManager
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    w = jax.device_put(jnp.arange(64.0).reshape(16, 4), sh)
    CheckpointManager("{tmp}").save(1, {{"w": w}})
    print("saved")
    """, devices=8)
    run_child(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import CheckpointManager
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((4,), ("data",))
    sh = {{"w": NamedSharding(mesh, P("data", None))}}
    like = {{"w": jnp.zeros((16, 4))}}
    state, step = CheckpointManager("{tmp}").restore(like, shardings=sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.arange(64.0).reshape(16, 4))
    assert len(state["w"].sharding.device_set) == 4
    print("OK")
    """, devices=4)
