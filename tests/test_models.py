"""Per-arch smoke tests (reduced configs, one fwd + one train-grad step on
CPU: output shapes + finiteness) and decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import (build_params, decode_step, forward, init_cache,
                          loss_fn)
from repro.models.transformer import encode


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    out = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
           "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.is_encdec:
        out["enc_inputs"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return out


# eager grad dispatch is slow for the recurrent scans; keep those runnable
# via --runslow without wedging the tier-1 budget
_SLOW_SMOKE = {"recurrentgemma-2b", "gemma3-12b"}


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_SMOKE else a
    for a in ARCH_IDS])
def test_arch_smoke_forward_and_grad(arch):
    cfg = reduced(get_config(arch))
    params = build_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits = forward(params, cfg, batch["inputs"],
                     enc_inputs=batch.get("enc_inputs"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ["qwen3-1.7b",
                                  pytest.param("gemma3-12b",
                                               marks=pytest.mark.slow),
                                  "recurrentgemma-2b",
                                  pytest.param("xlstm-1.3b",
                                               marks=pytest.mark.slow),
                                  "granite-moe-1b-a400m",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = build_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    enc_out = None
    if cfg.is_encdec:
        full = forward(params, cfg, batch["inputs"],
                       enc_inputs=batch["enc_inputs"])
        enc_out = encode(params, cfg, batch["enc_inputs"])
    else:
        full = forward(params, cfg, batch["inputs"])
    cache = init_cache(cfg, B, max_len=S)
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos,
                                                    enc_out=enc_out))
    outs = []
    for t in range(S):
        lg, cache = step(params, batch["inputs"][:, t:t + 1],
                         cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(full - dec))) / float(jnp.max(jnp.abs(full)))
    assert rel < 5e-3, (arch, rel)


def test_local_window_limits_context():
    """With a tiny window, distant tokens must not influence logits."""
    cfg = reduced(get_config("gemma3-12b")).replace(
        pattern=(("la", "swiglu"),), n_units=2, local_window=4)
    params = build_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 16))
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab_size  # mutate far-away token
    a = forward(params, cfg, jnp.asarray(toks))
    b = forward(params, cfg, jnp.asarray(toks2))
    # position 15 is > window+1 away from position 0 through 2 layers
    np.testing.assert_allclose(np.asarray(a[0, 15]), np.asarray(b[0, 15]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(a[0, 0]), np.asarray(b[0, 0]))


def test_moe_routing_actually_sparse():
    """Zeroing a never-selected expert's weights must not change outputs."""
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    params = build_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    from repro.models import blocks as B
    from repro.models.layers import init_params
    p = init_params(B.moe_spec(cfg), jax.random.PRNGKey(4), jnp.float32)
    xact = jnp.asarray(rng.normal(size=(1, 3, cfg.d_model)), jnp.float32)
    y = B.moe_apply(p, xact, cfg)
    # find an unused expert (3 tokens x top_k=2 over 8 experts: >= 2 unused)
    logits = xact.reshape(-1, cfg.d_model) @ p["router"]
    _, top = jax.lax.top_k(jax.nn.softmax(logits), cfg.top_k)
    used = set(np.asarray(top).reshape(-1).tolist())
    unused = next(e for e in range(cfg.n_experts) if e not in used)
    p2 = dict(p)
    p2["w_down"] = p["w_down"].at[unused].set(0.0)
    y2 = B.moe_apply(p2, xact, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)


def test_param_count_analytic_close_to_actual():
    for arch in ("qwen3-8b", "granite-moe-1b-a400m", "xlstm-1.3b"):
        cfg = get_config(arch)
        from repro.models.transformer import model_spec
        from repro.models.layers import spec_tree_map
        total = sum(int(np.prod(s.shape)) for s in
                    jax.tree.leaves(model_spec(cfg),
                                    is_leaf=lambda x: hasattr(x, "axes")))
        analytic = cfg.param_count()
        assert abs(total - analytic) / total < 0.12, (arch, total, analytic)
