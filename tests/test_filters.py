"""Filter admissibility + cross-implementation equality (property tests).

The central invariant of the paper: every filter is a LOWER bound on GED,
i.e. no false dismissals ever.  We verify against brute-force GED on random
small graphs, and verify the scalar / batched-numpy / batched-jax / Pallas
paths agree exactly.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core import filters
from repro.core.verify import ged_bruteforce
from repro.graphs.generators import perturb_graph, random_graph

NV, NE = 4, 3


def rand_pair(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    return g, h


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_all_filters_admissible(seed):
    g, h = rand_pair(seed)
    true = ged_bruteforce(g, h)
    bounds = filters.pairwise_bounds(g, h, NV, NE)
    for name, b in bounds.items():
        assert b <= true, (name, b, true)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(0, 4))
def test_perturbation_upper_bounds_filters(seed, k):
    """ged(g, perturb(g, k)) <= k, so every filter bound must be <= k."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(2, 7)), int(rng.integers(1, 8)),
                     NV, NE)
    h = perturb_graph(g, k, rng, NV, NE)
    bounds = filters.pairwise_bounds(g, h, NV, NE)
    assert bounds["combined"] <= k, bounds


def test_filters_identity():
    rng = np.random.default_rng(0)
    g = random_graph(rng, 6, 7, NV, NE)
    b = filters.pairwise_bounds(g, g, NV, NE)
    assert b["combined"] == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_degseq_delta_symmetry_and_zero(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 8, rng.integers(1, 9))
    y = rng.integers(0, 8, len(x))
    assert filters.degseq_delta(x, x) == 0
    assert filters.degseq_delta(x, y) == filters.degseq_delta(y, x)


def test_batched_matches_scalar():
    rng = np.random.default_rng(1)
    from repro.graphs.batching import PaddedGraphBatch
    from repro.graphs.graph import GraphDB
    from repro.core.qgrams import EncodedDB, sparse_intersection_size
    from repro.core.tree import QueryTuple

    graphs = [random_graph(rng, int(rng.integers(1, 7)),
                           int(rng.integers(0, 8)), NV, NE, connected=False)
              for _ in range(40)]
    db = GraphDB(graphs, NV, NE)
    h = random_graph(rng, 5, 6, NV, NE)
    enc = EncodedDB.build(db)
    q = QueryTuple.from_graph(h, enc.vocab)
    batch = PaddedGraphBatch.from_db(db)
    c_d = np.array([sparse_intersection_size(*enc.row_degree(i), q.d_ids,
                                             q.d_cnt)
                    for i in range(len(db))])
    sig = np.zeros(batch.vmax, np.int64)
    sig[:min(h.n, batch.vmax)] = q.sigma[:batch.vmax]
    out = filters.batched_bounds_np(
        batch.nv, batch.ne, batch.degseq, batch.vlabel_hist,
        batch.elabel_hist, c_d, h.n, h.m, sig,
        h.vertex_label_hist(NV), h.edge_label_hist(NE))
    for i, g in enumerate(graphs):
        b = filters.pairwise_bounds(g, h, NV, NE)
        for name in ("number_count", "label_qgram", "degree_qgram",
                     "degree_sequence"):
            assert out[name][i] == b[name], (i, name, out[name][i], b[name])


def test_jax_matches_numpy():
    import jax.numpy as jnp
    from repro.core import filters_jax as fj
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db

    db = aids_like_db(60, seed=3)
    flat = FlatMSQIndex(db)
    dbar = fj.db_arrays_from_encoded(flat.enc, flat.partition)
    rng = np.random.default_rng(0)
    h = perturb_graph(db[7], 2, rng, db.n_vlabels, db.n_elabels)
    for tau in (1, 3, 5):
        q = fj.query_arrays_from_graph(h, flat.vocab, flat.partition, tau,
                                       vmax=dbar.degseq.shape[1])
        mask, _ = fj.filter_pass(dbar, q, flat.partition.x0,
                                 flat.partition.y0, flat.partition.l)
        cand_jax = sorted(np.flatnonzero(np.asarray(mask)).tolist())
        assert cand_jax == flat.candidates(h, tau)
