"""Observability substrate tests (DESIGN.md §17).

Covers the registry algebra (snapshot / merge associativity / delta /
absorb), the ``StatsView`` mapping facade the legacy stats dicts became,
the bounded span ring, the Chrome trace-event export round-trip, and —
on a real tiny engine — the span-nesting invariants, cache-hit replay
semantics, and the load-bearing parity claim: observability never
changes candidate or match sets.
"""
import numpy as np
import pytest

from repro.core.search import FlatMSQIndex
from repro.graphs.generators import aids_like_db, perturb_graph
from repro.obs import MetricsRegistry, Observability, SpanRecorder
from repro.obs.export import (load_trace, spans_from_trace, to_trace_events,
                              validate_trace, write_trace)
from repro.serve.graph_engine import GraphQuery, GraphQueryEngine


@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(150, seed=3)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


def _requests(db, n, seed, verify=True, tau_hi=4):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tau = int(rng.integers(1, tau_hi))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        out.append(GraphQuery(h, tau, verify=verify))
    return out


# ---- registry algebra ------------------------------------------------------

def _reg(counters, gauges=(), hist=()):
    r = MetricsRegistry()
    for k, v in counters:
        r.counter_add(k, v)
    for k, v in gauges:
        r.gauge_set(k, v)
    for k, v in hist:
        r.observe(k, v)
    return r


def test_registry_counters_gauges_hists():
    r = _reg([("a.x", 2), ("a.x", 3), ("b.y", 1.5)],
             gauges=[("g", 7)], hist=[("h", 0.01), ("h", 2.0)])
    snap = r.snapshot()
    assert snap["counters"]["a.x"] == 5
    assert snap["counters"]["b.y"] == 1.5
    assert snap["gauges"]["g"] == 7
    assert snap["hists"]["h"]["count"] == 2
    assert snap["hists"]["h"]["sum"] == pytest.approx(2.01)


def test_registry_merge_associative_commutative():
    a = _reg([("x", 1), ("y", 2)], gauges=[("g", 3)], hist=[("h", 0.1)])
    b = _reg([("x", 10)], gauges=[("g", 1)], hist=[("h", 5.0)])
    c = _reg([("y", 7), ("z", 1)], gauges=[("g2", 4)])
    sa, sb, sc = a.snapshot(), b.snapshot(), c.snapshot()
    m = MetricsRegistry.merge
    assert m(m(sa, sb), sc) == m(sa, m(sb, sc))
    assert m(sa, sb) == m(sb, sa)
    out = m(sa, sb)
    assert out["counters"]["x"] == 11
    assert out["gauges"]["g"] == 3          # gauges take the max
    assert out["hists"]["h"]["count"] == 2


def test_registry_delta_and_absorb():
    r = _reg([("x", 5)], gauges=[("g", 2)])
    old = r.snapshot()
    r.counter_add("x", 3)
    r.gauge_set("g", 9)
    d = MetricsRegistry.delta(r.snapshot(), old)
    assert d["counters"]["x"] == 3
    assert d["gauges"]["g"] == 9            # gauges keep the new value

    sink = _reg([("x", 1)])
    sink.absorb(r.snapshot())
    assert sink.snapshot()["counters"]["x"] == 9


def test_stats_view_mapping_semantics():
    r = MetricsRegistry()
    s = r.view("engine", initial={"queries": 0, "filter_s": 0.0})
    s["queries"] += 2
    s["filter_s"] += 0.5
    assert s["queries"] == 2
    assert dict(s) == {"queries": 2, "filter_s": 0.5}
    assert s.get("missing", -1) == -1
    assert "queries" in s and "missing" not in s
    assert set(s) == {"queries", "filter_s"}
    # namespaces are isolated: another view never sees these keys
    other = r.view("sched", initial={"queries": 0})
    assert other["queries"] == 0
    # the numbers live in the registry, fully-qualified
    assert r.snapshot()["counters"]["engine.queries"] == 2


# ---- span ring -------------------------------------------------------------

def test_span_ring_bounded_and_counts_drops():
    rec = SpanRecorder(capacity=8, enabled=True)
    for i in range(20):
        rec.record("s", float(i), float(i) + 0.5)
    assert len(rec) == 8
    assert rec.dropped == 12
    assert [s.t0 for s in rec.spans()] == [float(i) for i in range(12, 20)]
    count, total = rec.aggregate()["s"]
    assert count == 8 and total == pytest.approx(4.0)


def test_span_recorder_disabled_is_noop():
    rec = SpanRecorder(capacity=8, enabled=False)
    rec.record("s", 0.0, 1.0)
    with rec.span("t"):
        pass
    rec.extend([])
    assert len(rec) == 0 and rec.dropped == 0


# ---- export ----------------------------------------------------------------

def test_trace_round_trip(tmp_path):
    obs = Observability(spans=True)
    obs.metrics.counter_add("engine.queries", 3)
    obs.spans.record("filter", 1.0, 1.25, tid="filter-thread", rows=4)
    obs.spans.record("verify", 1.1, 1.2, tid="verify-0", qid=7, gid=12)
    path = str(tmp_path / "t.trace.json")
    obs.export_trace(path)
    obj = load_trace(path)
    validate_trace(obj)
    assert obj["otherData"]["metrics"]["counters"]["engine.queries"] == 3

    back = spans_from_trace(obj)
    assert [s.name for s in back] == ["filter", "verify"]
    f, v = back
    assert f.tid == "filter-thread" and f.args == {"rows": 4}
    assert f.t0 == pytest.approx(1.0) and f.t1 == pytest.approx(1.25)
    assert v.qid == 7 and v.args == {"gid": 12}


def test_validate_trace_rejects_bad_schema():
    with pytest.raises(AssertionError):
        validate_trace({"traceEvents": "nope"})
    with pytest.raises(AssertionError):
        validate_trace({"traceEvents": []})        # no complete events
    ev = to_trace_events([])
    assert ev == []


# ---- on a real engine ------------------------------------------------------

def test_engine_span_nesting_invariants(small_db, flat):
    reqs = _requests(small_db, 10, seed=5, verify=True)
    eng = GraphQueryEngine(flat, backend="numpy",
                           obs=Observability(spans=True))
    out = eng.submit(reqs)
    spans = eng.obs.spans.spans()
    names = {s.name for s in spans}
    assert {"admission", "filter", "query"} <= names
    roots = {s.qid: s for s in spans if s.name == "query"}
    assert len(roots) == len(reqs)
    # every per-query child lies within its root's interval
    for s in spans:
        if s.qid is None or s.name == "query":
            continue
        root = roots[s.qid]
        assert root.t0 <= s.t0 and s.t1 <= root.t1, \
            f"{s.name} span escapes its query root"
    # verify spans carry the pair provenance args
    verifies = [s for s in spans if s.name == "verify"]
    if any(len(r.candidates) for r in out):
        assert verifies
    for s in verifies:
        assert {"gid", "bound", "expansions", "decided"} <= set(s.args)
    # flat sources also record the batched stage spans
    assert {"bucket", "filter_bucket"} <= names


def test_cache_hit_replay_zeroed_timings(small_db, flat):
    eng = GraphQueryEngine(flat, backend="numpy",
                           obs=Observability(spans=True))
    req = _requests(small_db, 1, seed=6, verify=True)[0]
    first = eng.submit([req])[0]
    assert "cache_hit" not in first.stats
    again = eng.submit([GraphQuery(req.graph, req.tau, verify=True)])[0]
    assert again.stats.get("cache_hit") == 1
    assert again.filter_time_s == 0.0
    assert again.verify_time_s == 0.0
    assert again.stats.get("lb_s") == 0.0
    assert again.stats.get("queue_s") == 0.0
    assert again.candidates == first.candidates
    assert again.matches == first.matches
    hits = [s for s in eng.obs.spans.spans()
            if s.name == "query" and s.args.get("cache_hit")]
    assert len(hits) == 1


def test_obs_on_off_parity(small_db, flat):
    reqs = _requests(small_db, 12, seed=9, verify=True)
    off = GraphQueryEngine(flat, backend="numpy",
                           result_cache_size=0).submit(reqs)
    on = GraphQueryEngine(flat, backend="numpy", result_cache_size=0,
                          obs=Observability(spans=True)).submit(reqs)
    for a, b in zip(on, off):
        assert a.candidates == b.candidates
        assert a.matches == b.matches


def test_async_pipeline_queue_and_root_spans(small_db, flat):
    from repro.serve.pipeline import AsyncGraphQueryEngine
    reqs = _requests(small_db, 8, seed=4, verify=True)
    eng = GraphQueryEngine(flat, backend="numpy", result_cache_size=0,
                           obs=Observability(spans=True))
    with AsyncGraphQueryEngine(eng, max_batch=4, num_workers=2) as apipe:
        out = [t.result(timeout=120) for t in apipe.submit_many(reqs)]
    spans = eng.obs.spans.spans()
    queues = [s for s in spans if s.name == "queue"]
    roots = [s for s in spans if s.name == "query"]
    assert len(queues) >= len(reqs)
    assert len(roots) == len(reqs)
    for res in out:
        assert res.stats.get("queue_s", 0.0) >= 0.0
    # the async stats facade still reads like the old dict
    assert apipe.stats["queries"] >= len(reqs)


def test_topk_round_spans_carry_tau(small_db, flat):
    eng = GraphQueryEngine(flat, backend="numpy", result_cache_size=0,
                           obs=Observability(spans=True))
    g = perturb_graph(small_db[0], 1, np.random.default_rng(0),
                      small_db.n_vlabels, small_db.n_elabels)
    res = eng.submit([GraphQuery(g, 3, top_k=2)])[0]
    assert len(res.matches) <= 2
    rounds = [s for s in eng.obs.spans.spans() if s.name == "topk_round"]
    assert rounds, "top-k escalation recorded no round spans"
    for s in rounds:
        assert s.args["tau"] >= 0 and s.args["round"] >= 1


def test_process_pool_astar_slice_spans(small_db):
    from repro.serve.graph_engine import VerifyScheduler
    flat = FlatMSQIndex(small_db)
    reqs = _requests(small_db, 4, seed=11, verify=True)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    obs = Observability(spans=True)
    sched = VerifyScheduler(small_db, executor="process", workers=2,
                            slice_expansions=40, obs=obs)
    try:
        jobs = [sched.add_job(r.graph, r.tau, res.candidates,
                              [0] * len(res.candidates))
                for r, res in zip(reqs, ref)]
        sched.run_until_idle()
    finally:
        sched.close()
        sched.shutdown()
    for job, res in zip(jobs, ref):
        assert sorted(job.matches) == res.matches
    if any(len(r.candidates) for r in ref):
        frags = [s for s in obs.spans.spans() if s.name == "astar_slice"]
        assert frags, "no worker span fragments crossed the pool"
        assert all(s.tid.startswith("ged-pool-") for s in frags)
