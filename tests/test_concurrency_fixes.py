"""Regression tests for the real findings the lint suite surfaced
(DESIGN.md §14): the ``DeviceSlabCache.__len__``/``stats`` reads outside
``_lock``, the ``AsyncGraphQueryEngine.close`` unguarded ``_closed``
write, and the ``ShardedLoader`` reader-thread leak — plus the shutdown
verbs the thread-lifecycle audit standardised (``CheckpointManager.close``,
``ShardedLoader.close``)."""
import threading
import time

import numpy as np
import pytest

from repro.core.device_cache import DeviceSlabCache, bucket_key
from repro.data.pipeline import ShardedLoader, StragglerSimulator, \
    SyntheticLMDataset
from repro.serve.graph_engine import VerifyScheduler


# ---- DeviceSlabCache: len/snapshot race with builders ---------------------

def test_device_cache_len_and_snapshot_under_concurrent_builds():
    cache = DeviceSlabCache(max_entries=8)
    n_threads, n_keys, rounds = 4, 16, 40
    keys = [bucket_key(np.arange(i + 1), 0) for i in range(n_keys)]
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(rounds):
                k = keys[int(rng.integers(0, n_keys))]
                cache.get_or_build(k, "field", lambda: object())
        except Exception as e:          # noqa: BLE001 — surface to main
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    # the racy readers the lint rule flagged: len() and the counters,
    # exercised while builders mutate the entry map
    for _ in range(200):
        assert 0 <= len(cache) <= 8
        snap = cache.snapshot()
        assert snap["entries"] <= 8
        assert snap["hits"] >= 0 and snap["misses"] >= 0
    for t in threads:
        t.join()
    assert not errors
    final = cache.snapshot()
    # every get_or_build is exactly one hit or one miss
    assert final["hits"] + final["misses"] == n_threads * rounds
    assert len(cache) == final["entries"]


def test_device_cache_snapshot_is_a_copy():
    cache = DeviceSlabCache(max_entries=2)
    snap = cache.snapshot()
    snap["hits"] = 999
    assert cache.snapshot()["hits"] == 0


# ---- VerifyScheduler: consistent stats copies -----------------------------

def test_scheduler_stats_snapshot_is_a_consistent_copy():
    class _DB(list):
        pass

    sched = VerifyScheduler(_DB())
    snap = sched.stats_snapshot()
    assert snap == sched.stats
    snap["verified_pairs"] = 123
    assert sched.stats["verified_pairs"] == 0


# ---- AsyncGraphQueryEngine: close publishes _closed under the lock --------

def test_async_engine_close_is_idempotent_and_publishes_closed():
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine
    from repro.serve.pipeline import AsyncGraphQueryEngine

    db = aids_like_db(40, seed=3)
    eng = GraphQueryEngine(FlatMSQIndex(db), backend="numpy")
    apipe = AsyncGraphQueryEngine(eng, max_batch=4, max_delay_s=0.001,
                                  num_workers=2)
    rng = np.random.default_rng(0)
    reqs = [GraphQuery(perturb_graph(db[i], 1, rng, db.n_vlabels,
                                     db.n_elabels), 1)
            for i in range(6)]
    tickets = apipe.submit_many(reqs)
    for t in tickets:
        t.result(timeout=60)
    # stats property takes each lock sequentially — must work while open
    s = apipe.stats
    assert s["queries"] >= 6
    apipe.close()
    with apipe._cv:
        assert apipe._closed
    assert not apipe._filter_thread.is_alive()
    assert not any(w.is_alive() for w in apipe._workers)
    apipe.close()                        # second close: clean no-op
    with pytest.raises(RuntimeError):
        apipe.submit(reqs[0])


# ---- ShardedLoader: readers are tracked and joined ------------------------

def test_sharded_loader_close_joins_readers():
    ds = SyntheticLMDataset(vocab_size=50, seq_len=8, global_batch=4)
    loader = ShardedLoader(ds, straggler_timeout_s=0.05,
                           straggler=StragglerSimulator(slow_every=2,
                                                        delay_s=0.4))
    batches = []
    for i, b in enumerate(loader.iterate()):
        batches.append(b)
        if i >= 3:
            break
    assert loader.reissues >= 1          # the straggler forced re-issue
    loader.close(timeout=5.0)
    assert loader._readers == []         # everything joined / pruned
    loader.close(timeout=5.0)            # idempotent


def test_sharded_loader_context_manager():
    ds = SyntheticLMDataset(vocab_size=50, seq_len=8, global_batch=4)
    with ShardedLoader(ds, straggler_timeout_s=5.0) as loader:
        it = loader.iterate(stop=2)
        got = list(it)
    assert len(got) == 2
    assert loader._readers == []


# ---- CheckpointManager: close() is the standard shutdown verb -------------

def test_checkpoint_manager_close_joins_and_raises(tmp_path):
    from repro.train.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    mgr.save_async(1, {"w": np.ones((4,), np.float32)})
    mgr.close()                          # joins the background writer
    assert mgr.all_steps() == [1]
    assert mgr._thread is None

    # close() surfaces a background-write error like wait() does
    mgr._error = RuntimeError("disk gone")
    with pytest.raises(RuntimeError, match="disk gone"):
        mgr.close()

    with CheckpointManager(str(tmp_path), keep_last=2) as mgr2:
        mgr2.save_async(2, {"w": np.zeros((4,), np.float32)})
    assert 2 in mgr2.all_steps()
