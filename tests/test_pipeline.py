"""AsyncGraphQueryEngine: pipelined parity, streaming, deadlines, shutdown.

The load-bearing invariant (DESIGN.md §12): with no deadlines, every
completed ticket is bit-identical to the synchronous ``submit()`` — same
candidates, same matches, same n_filtered — for every backend x FilterSlab
layout, independent of verifier worker count, batch forming, or A*
timeslicing.  Deadlines only ever produce recall-safe partials (candidates
untouched, ``partial`` flagged), and ``close()`` leaks no threads.
"""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core.search import FlatMSQIndex, MSQIndex
from repro.core.verify import GEDSearch, ged_upto
from repro.serve.errors import FilterStageError
from repro.serve.graph_engine import GraphQuery, GraphQueryEngine
from repro.serve.pipeline import AsyncGraphQueryEngine, as_completed

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def small_db():
    from repro.graphs.generators import aids_like_db
    return aids_like_db(150, seed=7)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


def _requests(db, n, seed, verify=True, tau_hi=3):
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tau = int(rng.integers(1, tau_hi))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        out.append(GraphQuery(h, tau, verify=verify))
    return out


def _assert_same(got, ref):
    for a, b in zip(got, ref):
        assert a.candidates == b.candidates
        assert a.matches == b.matches
        assert a.n_filtered == b.n_filtered


# --------------------------------------------------------------------------
# bit-identical parity across backends x slab layouts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,slab", [
    ("numpy", "dense"), ("numpy", "hot"), ("numpy", "packed"),
    ("jax", "dense"), ("jax", "packed"), ("pallas", "dense")])
def test_async_bit_identical_to_submit(small_db, flat, backend, slab):
    reqs = _requests(small_db, 8, seed=1)
    ref = GraphQueryEngine(flat, backend=backend,
                           slab_layout=slab).submit(reqs)
    eng = GraphQueryEngine(flat, backend=backend, slab_layout=slab)
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2) as apipe:
        out = [t.result(timeout=90)
               for t in apipe.submit_many(reqs)]
    _assert_same(out, ref)


def test_async_over_tree_source(small_db):
    """Tree sources carry no filter bounds (worklist order degrades to
    admission order) — results must still match the sync path."""
    tree = MSQIndex(small_db)
    reqs = _requests(small_db, 6, seed=2)
    ref = GraphQueryEngine(tree).submit(reqs)
    with AsyncGraphQueryEngine(GraphQueryEngine(tree),
                               max_batch=2, num_workers=2) as apipe:
        out = [t.result(timeout=90) for t in apipe.submit_many(reqs)]
    _assert_same(out, ref)


def test_async_deterministic_1_vs_4_workers(small_db, flat):
    """Match sets must not depend on worker count, completion order, or
    A* timeslicing (tiny slices force many resumed runs)."""
    reqs = _requests(small_db, 10, seed=3)
    outs = []
    for workers, slice_exp in ((1, None), (4, None), (4, 3)):
        eng = GraphQueryEngine(flat, backend="numpy")
        with AsyncGraphQueryEngine(eng, max_batch=4, num_workers=workers,
                                   slice_expansions=slice_exp) as apipe:
            outs.append([t.result(timeout=90)
                         for t in apipe.submit_many(reqs)])
        if slice_exp is not None and any(len(r.candidates) for r in outs[-1]):
            assert apipe.stats["resumed_runs"] > 0
    _assert_same(outs[1], outs[0])
    _assert_same(outs[2], outs[0])


def test_process_pool_verifier_parity(small_db, flat):
    """The ProcessPoolExecutor verifier (ROADMAP item: GED off the GIL)
    must be bit-identical to the thread-pool path — pickled GEDSearch
    slices round-trip the frontier, so even resumed searches agree.  A
    pool dispatch failure degrades to in-process slices, never to missing
    matches."""
    reqs = _requests(small_db, 8, seed=11)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)

    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=4, num_workers=2,
                               verify_executor="process",
                               slice_expansions=50) as apipe:
        out = [t.result(timeout=180) for t in apipe.submit_many(reqs)]
    _assert_same(out, ref)
    # the sliced searches really crossed the process boundary and resumed
    if any(len(r.candidates) > 0 for r in ref):
        assert apipe.scheduler.stats["verified_pairs"] > 0


def test_process_pool_scheduler_direct(small_db, flat):
    """VerifyScheduler(executor='process', workers=N) drains a sync
    worklist through the pool with identical match sets."""
    from repro.serve.graph_engine import VerifyScheduler
    reqs = _requests(small_db, 6, seed=12)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    sched = VerifyScheduler(small_db, executor="process", workers=2,
                            slice_expansions=40)
    try:
        jobs = [sched.add_job(r.graph, r.tau, res.candidates,
                              [0] * len(res.candidates))
                for r, res in zip(reqs, ref)]
        sched.run_until_idle()
    finally:
        sched.close()
        sched.shutdown()
    for job, res in zip(jobs, ref):
        assert sorted(job.matches) == res.matches


def test_pool_worker_kill_resumes_at_frontier(small_db, flat, monkeypatch):
    """A worker killed mid-slice re-enqueues the resumable GEDSearch at
    its last frontier — one construction per pair, never a restart —
    and the poisoned pool is rebuilt (DESIGN.md §18)."""
    import repro.serve.graph_engine as ge
    from repro.serve.faults import FaultInjector, FaultSpec
    from repro.serve.graph_engine import VerifyScheduler

    reqs = _requests(small_db, 5, seed=12)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    n_pairs = sum(len(r.candidates) for r in ref)
    assert n_pairs > 3

    made = []
    real = ge.GEDSearch

    def counting_ctor(*a, **kw):
        # a factory, not a subclass: the instance must stay the real
        # (picklable) GEDSearch so the spawn pool can round-trip it
        made.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(ge, "GEDSearch", counting_ctor)
    faults = FaultInjector(
        [FaultSpec("verify.pool", kind="kill_worker", on_calls=(3,))],
        seed=5)
    sched = VerifyScheduler(small_db, executor="process", workers=2,
                            slice_expansions=40, faults=faults)
    try:
        jobs = [sched.add_job(r.graph, r.tau, res.candidates,
                              [0] * len(res.candidates))
                for r, res in zip(reqs, ref)]
        sched.run_until_idle()
    finally:
        sched.close()
        sched.shutdown()
    # completed matches bit-identical to the fault-free run
    for job, res in zip(jobs, ref):
        assert sorted(job.matches) == res.matches
        assert job.unverified == 0       # the struck pair resumed, not died
    ss = sched.stats_snapshot()
    assert ss["error_pairs"] == 0
    assert ss["pool_rebuilds"] >= 1      # poisoned pool was replaced
    assert faults.fired_at("verify.pool"), "kill spec never fired"
    # the frontier-resume invariant: every pair built its search exactly
    # once; interrupted slices re-entered the heap as resumes
    assert len(made) == n_pairs
    assert ss["resumed_runs"] >= 1


def test_scheduler_rejects_unknown_executor(small_db):
    from repro.serve.graph_engine import VerifyScheduler
    with pytest.raises(ValueError):
        VerifyScheduler(small_db, executor="fiber")


# --------------------------------------------------------------------------
# streaming delivery
# --------------------------------------------------------------------------

def test_stream_yields_every_match_then_ends(small_db, flat):
    reqs = _requests(small_db, 6, seed=4)
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2) as apipe:
        tickets = apipe.submit_many(reqs)
        streamed = [list(t.stream(timeout=90)) for t in tickets]
        results = [t.result(timeout=90) for t in tickets]
    for s, r in zip(streamed, results):
        assert sorted(s) == r.matches   # every match streamed exactly once
    # as_completed covers every ticket exactly once
    idxs = sorted(i for i, _ in as_completed(tickets, timeout=5))
    assert idxs == list(range(len(tickets)))


def test_stream_single_worker_cheapest_first(small_db, flat):
    """With one worker the worklist is drained strictly cheapest-bound
    first, so each query's matches stream in nondecreasing bound order;
    here we check the observable contract: streaming beats completion and
    replays exactly the final match set (cache hits included)."""
    reqs = _requests(small_db, 4, seed=5)
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=4, num_workers=1) as apipe:
        t0 = apipe.submit_many(reqs)[0]
        got = list(t0.stream(timeout=90))
        assert sorted(got) == t0.result(timeout=1).matches
        # a repeat of the same request resolves from the result cache and
        # still streams the full match set before ending
        t1 = apipe.submit(reqs[0])
        assert sorted(t1.stream(timeout=90)) == t1.result(timeout=1).matches
        assert t1.result().stats.get("cache_hit") == 1


# --------------------------------------------------------------------------
# deadlines: recall-safe partials; budgeted/resumable A*
# --------------------------------------------------------------------------

def test_deadline_partial_flagged_and_recall_safe(small_db, flat):
    reqs = _requests(small_db, 5, seed=6)
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    expired = [GraphQuery(r.graph, r.tau, verify=True, deadline_s=0.0)
               for r in reqs]
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=5, num_workers=2) as apipe:
        out = [t.result(timeout=90) for t in apipe.submit_many(expired)]
    assert apipe.stats["expired_pairs"] > 0
    for a, b in zip(out, ref):
        assert a.candidates == b.candidates      # never truncated
        assert set(a.matches) <= set(b.matches)  # only confirmed matches
        if a.candidates:
            assert a.stats["partial"] == 1
            assert a.stats["unverified"] + len(a.matches) \
                <= len(a.candidates)
    # partials are not cached: a deadline-free repeat recomputes fully
    with AsyncGraphQueryEngine(eng, max_batch=5, num_workers=2) as apipe2:
        full = [t.result(timeout=90) for t in apipe2.submit_many(reqs)]
    _assert_same(full, ref)


def test_sync_submit_honors_deadline_too(small_db, flat):
    """The sync engine is the one-worker special case of the same
    scheduler, deadlines included."""
    reqs = [GraphQuery(r.graph, r.tau, verify=True, deadline_s=0.0)
            for r in _requests(small_db, 3, seed=7)]
    eng = GraphQueryEngine(flat, backend="numpy")
    out = eng.submit(reqs)
    for r in out:
        if r.candidates:
            assert r.stats["partial"] == 1
            assert r.matches == []
    assert eng.stats["expired_pairs"] > 0


def test_ged_search_budgeted_resume_equals_oneshot(small_db):
    rng = np.random.default_rng(8)
    for _ in range(5):
        g = small_db[int(rng.integers(0, len(small_db)))]
        h = small_db[int(rng.integers(0, len(small_db)))]
        tau = int(rng.integers(1, 4))
        want = ged_upto(g, h, tau)
        s = GEDSearch(g, h, tau)
        hops = 0
        r = None
        while r is None:
            r = s.run(max_expansions=2)
            hops += 1
        assert r == want
        assert s.done and s.min_f() == want
        assert s.run() == want          # running a decided search is a no-op
        if s.expansions > 2:
            assert hops > 1             # the budget actually sliced the run


def test_ged_upto_deadline_returns_none_mid_search(small_db):
    import time
    g, h = small_db[0], small_db[1]
    want = ged_upto(g, h, 3)
    s = GEDSearch(g, h, 3)
    if not s.done:   # an immediate heuristic cutoff can't be interrupted
        assert s.run(deadline=time.perf_counter()) is None
    assert s.run() == want


# --------------------------------------------------------------------------
# shutdown hygiene
# --------------------------------------------------------------------------

def test_close_leaks_no_threads_and_rejects_new_work(small_db, flat):
    before = set(threading.enumerate())
    eng = GraphQueryEngine(flat, backend="numpy")
    apipe = AsyncGraphQueryEngine(eng, max_batch=4, num_workers=3,
                                  name="leakcheck")
    tickets = apipe.submit_many(_requests(small_db, 6, seed=9))
    apipe.close(timeout=90)
    assert all(t.done() for t in tickets)   # close() drains, never drops
    leaked = [t for t in set(threading.enumerate()) - before
              if t.name.startswith("leakcheck") and t.is_alive()]
    assert not leaked
    with pytest.raises(RuntimeError):
        apipe.submit(GraphQuery(small_db[0], 1))
    apipe.close()                           # idempotent


def test_async_sharded_parity_subprocess():
    """The pipelined engine over ShardedGraphQueryEngine's shard_map
    filter path (2-device CPU mesh, subprocess so the main process keeps
    1 device) stays bit-identical to the sync sharded engine."""
    code = """
    import numpy as np
    from repro.core import jax_compat as jc
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db, perturb_graph
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)
    from repro.serve.pipeline import AsyncGraphQueryEngine

    db = aids_like_db(120, seed=11)
    rng = np.random.default_rng(3)
    reqs = []
    for _ in range(8):
        tau = int(rng.integers(1, 3))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=True))
    mesh = jc.make_mesh((2,), ("data",))
    ref = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, k=64,
                                  shard_pad=64).submit(reqs)
    eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, k=64,
                                  shard_pad=64)
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2) as apipe:
        out = [t.result(timeout=120) for t in apipe.submit_many(reqs)]
    for a, b in zip(out, ref):
        assert a.candidates == b.candidates
        assert a.matches == b.matches
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


# --------------------------------------------------------------------------
# result-cache stat replay (satellite fix)
# --------------------------------------------------------------------------

def test_cache_hits_tagged_and_counted(small_db, flat):
    reqs = _requests(small_db, 4, seed=10)
    eng = GraphQueryEngine(flat, backend="numpy")
    first = eng.submit(reqs)
    assert eng.stats["cache_hits"] == 0
    again = eng.submit(reqs)
    assert eng.stats["cache_hits"] == len(reqs)
    for a, b in zip(again, first):
        assert a.candidates == b.candidates
        assert a.matches == b.matches
        assert a.stats.get("cache_hit") == 1
        assert a.filter_time_s == 0.0 and a.verify_time_s == 0.0
        assert b.stats.get("cache_hit") is None   # originals untouched


# --------------------------------------------------------------------------
# review regressions: coalescing vs deadlines, stage-failure containment
# --------------------------------------------------------------------------

def test_deadline_duplicate_not_coalesced_with_deadline_free(small_db, flat):
    """A deadline-free request must never inherit a same-batch duplicate's
    partial result (the coalescing key includes the deadline)."""
    rng = np.random.default_rng(12)
    from repro.graphs.generators import perturb_graph
    g = small_db[int(rng.integers(0, len(small_db)))]
    h = perturb_graph(g, 1, rng, small_db.n_vlabels, small_db.n_elabels)
    want = GraphQueryEngine(flat, backend="numpy").query(h, 2)
    eng = GraphQueryEngine(flat, backend="numpy")
    out = eng.submit([GraphQuery(h, 2, verify=True, deadline_s=0.0),
                      GraphQuery(h, 2, verify=True)])
    assert out[1].matches == want.matches        # full answer, not partial
    assert out[1].stats.get("partial") is None
    if out[0].candidates:
        assert out[0].stats.get("partial") == 1
    # async path shares _admit, so the same holds pipelined
    eng2 = GraphQueryEngine(flat, backend="numpy", result_cache_size=0)
    with AsyncGraphQueryEngine(eng2, max_batch=2, num_workers=2) as apipe:
        t_dead, t_free = apipe.submit_many(
            [GraphQuery(h, 2, verify=True, deadline_s=0.0),
             GraphQuery(h, 2, verify=True)])
        assert t_free.result(timeout=90).matches == want.matches


def test_filter_stage_failure_fails_batch_not_pipeline(small_db, flat):
    """A poisoned request errors its own batch's tickets and leaves the
    pipeline serving later batches."""
    reqs = _requests(small_db, 3, seed=13)
    eng = GraphQueryEngine(flat, backend="numpy")
    ref = GraphQueryEngine(flat, backend="numpy").submit(reqs)
    with AsyncGraphQueryEngine(eng, max_batch=1, num_workers=2) as apipe:
        bad = apipe.submit(GraphQuery(None, 1))       # type: ignore[arg-type]
        # batch failures surface as the typed FilterStageError with the
        # original exception chained (DESIGN.md §18)
        with pytest.raises(FilterStageError) as ei:
            bad.result(timeout=30)
        assert isinstance(ei.value.cause, AttributeError)
        assert ei.value.stage == "filter"
        with pytest.raises(FilterStageError):
            list(bad.stream(timeout=30))
        good = [t.result(timeout=90) for t in apipe.submit_many(reqs)]
    _assert_same(good, ref)


def test_as_completed_timeout_and_error_contract(small_db, flat):
    from repro.serve.pipeline import QueryTicket

    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=1, num_workers=1) as apipe:
        apipe.submit(GraphQuery(small_db[0], 1)).result(timeout=60)
        # an unresolved ticket: as_completed times out with the same
        # exception type as result()/stream()
        stuck = QueryTicket(GraphQuery(small_db[0], 1))
        with pytest.raises(TimeoutError):
            list(as_completed([stuck], timeout=0.05))
        bad = apipe.submit(GraphQuery(None, 1))       # type: ignore[arg-type]
        with pytest.raises(FilterStageError):
            list(as_completed([bad], timeout=30))


# --------------------------------------------------------------------------
# top-k modality: escalation re-entry, cache modality safety, deadline
# partials under both verify executors (DESIGN.md §15)
# --------------------------------------------------------------------------

def _topk_requests(db, n=4, seed=21, cap=4, deadline_s=None):
    from repro.graphs.generators import perturb_graph
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        g = perturb_graph(db[int(rng.integers(0, len(db)))],
                          int(rng.integers(1, 3)), rng, db.n_vlabels,
                          db.n_elabels)
        out.append(GraphQuery(g, cap, top_k=int(rng.integers(1, 5)),
                              deadline_s=deadline_s))
    return out


def test_async_topk_equals_sync_and_never_redecides(small_db, flat):
    """Pipelined top-k (tickets re-entering the batch former per widened-τ
    round) returns exactly the sync engine's k-best, range queries mixed
    in; scheduler stats account for every seen pair once — escalation
    never re-verifies a decided (query, gid) pair."""
    topk = _topk_requests(small_db, 4, seed=21)
    mixed = topk + _requests(small_db, 3, seed=22)
    ref = GraphQueryEngine(flat, backend="numpy",
                           result_cache_size=0).submit(mixed)
    eng = GraphQueryEngine(flat, backend="numpy", result_cache_size=0)
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2,
                               slice_expansions=40) as apipe:
        out = [t.result(timeout=120) for t in apipe.submit_many(mixed)]
    _assert_same(out, ref)
    s = apipe.stats
    assert s["topk_rounds"] > len(topk)       # someone actually escalated
    # every seen pair is decided exactly once: run to completion, pruned
    # by the kth-best cutoff, expired, or pruned by the stage-1.5
    # assignment LB before ever entering the heap (DESIGN.md §16)
    decided = (s["verified_pairs"] + s["pruned_pairs"]
               + s["expired_pairs"] + s["lb_pruned"])
    assert decided == sum(len(r.candidates) for r in out)
    if s["pruned_pairs"]:                     # kth-best cutoff engaged
        assert all([tuple(m) for m in a.matches]
                   == [tuple(m) for m in b.matches]
                   for a, b in zip(out, ref))


def test_async_topk_cache_modality_safe(small_db, flat):
    """A cached range-τ entry must not answer a top-k query at the same
    (graph, τ) and vice versa; repeats within each modality do hit, and
    the cache_hits counter stays exact."""
    g = _topk_requests(small_db, 1, seed=23)[0].graph
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=1, num_workers=2) as apipe:
        r_range = apipe.submit(GraphQuery(g, 4)).result(timeout=120)
        r_topk = apipe.submit(
            GraphQuery(g, 4, top_k=2)).result(timeout=120)
        assert "top_k" not in r_range.stats
        assert r_topk.stats["top_k"] == 2
        assert "cache_hit" not in r_topk.stats    # range entry didn't leak
        hits_before = apipe.stats["cache_hits"]
        again_r = apipe.submit(GraphQuery(g, 4)).result(timeout=120)
        again_k = apipe.submit(
            GraphQuery(g, 4, top_k=2)).result(timeout=120)
        other_k = apipe.submit(
            GraphQuery(g, 4, top_k=3)).result(timeout=120)
    assert again_r.stats.get("cache_hit") == 1
    assert again_k.stats.get("cache_hit") == 1
    assert again_k.matches == r_topk.matches
    assert "cache_hit" not in other_k.stats       # k is part of the key
    assert apipe.stats["cache_hits"] == hits_before + 2


@pytest.mark.parametrize("executor", ["thread", "process"])
def test_topk_deadline_partial_both_executors(small_db, flat, executor):
    """A deadline hit mid-escalation resolves the verified prefix flagged
    ``partial`` — under the thread AND the process verify executor — and
    the partial is never cached: a deadline-free repeat recomputes the
    exact k-best."""
    reqs = _topk_requests(small_db, 3, seed=24, deadline_s=0.0)
    free = [GraphQuery(r.graph, r.tau, top_k=r.top_k) for r in reqs]
    ref = GraphQueryEngine(flat, backend="numpy",
                           result_cache_size=0).submit(free)
    eng = GraphQueryEngine(flat, backend="numpy")
    with AsyncGraphQueryEngine(eng, max_batch=3, num_workers=2,
                               verify_executor=executor,
                               slice_expansions=30) as apipe:
        out = [t.result(timeout=180) for t in apipe.submit_many(reqs)]
        for a, b in zip(out, ref):
            assert a.stats["partial"] == 1
            assert a.stats["top_k"] == b.stats["top_k"]
            # the verified prefix is a prefix of the true k-best list
            assert [tuple(m) for m in a.matches] \
                == [tuple(m) for m in b.matches][:len(a.matches)]
        # never cached: the deadline-free repeat is exact, not a hit
        full = [t.result(timeout=180) for t in apipe.submit_many(free)]
    for a, b in zip(full, ref):
        assert a.matches == b.matches
        assert "partial" not in a.stats
        assert "cache_hit" not in a.stats


def test_topk_escalation_survives_close(small_db, flat):
    """close() immediately after submission: in-flight escalation rounds
    keep the filter stage alive until every top-k ticket resolves."""
    reqs = _topk_requests(small_db, 3, seed=25)
    ref = GraphQueryEngine(flat, backend="numpy",
                           result_cache_size=0).submit(reqs)
    eng = GraphQueryEngine(flat, backend="numpy", result_cache_size=0)
    apipe = AsyncGraphQueryEngine(eng, max_batch=2, num_workers=2,
                                  slice_expansions=30)
    tickets = apipe.submit_many(reqs)
    apipe.close(timeout=120)
    out = [t.result(timeout=1) for t in tickets]   # already resolved
    _assert_same(out, ref)
