"""Offline fallback for ``hypothesis``: deterministic seeded example draws.

The CI container has no network access, so the real hypothesis package can
never be installed there.  This shim implements the tiny subset of the API
the test-suite uses — ``given``, ``settings`` and the ``integers`` /
``sampled_from`` / ``lists`` strategies — by drawing a fixed number of
examples from a PRNG seeded with the test's qualified name.  Runs are fully
deterministic across processes and machines; no shrinking, no example
database.  When hypothesis *is* importable the test modules use it instead
(see the try/except import in each module).
"""
from __future__ import annotations

import functools
import inspect
import os
import zlib
from typing import Any, Callable, List, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 20
_MAX_EXAMPLES_ATTR = "_propshim_max_examples"

# Allow CI to globally scale example counts (e.g. PROPSHIM_EXAMPLE_SCALE=0.5
# halves every test's draw count) without touching the tests.
_SCALE = float(os.environ.get("PROPSHIM_EXAMPLE_SCALE", "1.0"))


class SearchStrategy:
    """Base strategy: subclasses implement ``draw(rng)``."""

    def draw(self, rng: np.random.Generator) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def draw(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.min_value, self.max_value + 1))


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def draw(self, rng: np.random.Generator) -> Any:
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0,
                 max_size: int = 10):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size)

    def draw(self, rng: np.random.Generator) -> List[Any]:
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.draw(rng) for _ in range(n)]


class strategies:
    """Namespace mirror of ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements: SearchStrategy, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        return _Lists(elements, min_size=min_size, max_size=max_size)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording the example budget (deadline etc. are no-ops).

    Works in either stacking order with ``given``: the attribute is read at
    call time by the ``given`` wrapper.
    """

    def deco(fn: Callable) -> Callable:
        setattr(fn, _MAX_EXAMPLES_ATTR, int(max_examples))
        return fn

    return deco


def given(*strats: SearchStrategy):
    """Run the test once per deterministically drawn example tuple."""

    def deco(fn: Callable) -> Callable:
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)]
        # hypothesis semantics: positional strategies bind the RIGHTMOST
        # parameters; anything to their left (e.g. pytest fixtures) is
        # supplied by the caller
        drawn_names = [p.name for p in params[len(params) - len(strats):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _MAX_EXAMPLES_ATTR, None)
            if n is None:
                n = getattr(fn, _MAX_EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES)
            n = max(1, int(round(n * _SCALE)))
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strats)}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example {i}: "
                        f"args={drawn!r}") from e

        # The drawn parameters are filled by the wrapper, not by pytest:
        # hide them so pytest doesn't go looking for same-named fixtures
        # (functools.wraps' __wrapped__ would expose the original signature).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(
            params[:len(params) - len(strats)])
        return wrapper

    return deco


st = strategies
