# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# single CPU device (the 512-device override belongs to launch/dryrun.py
# only).  Multi-device behaviour is tested via subprocesses
# (test_distributed_subprocess.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
