# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# single CPU device (the 512-device override belongs to launch/dryrun.py
# only).  Multi-device behaviour is tested via subprocesses
# (test_distributed_subprocess.py).
import os
import signal
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # tests/_propshim.py fallback


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavy cases excluded from the tier-1 fast run")


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow; use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    """Fail (instead of wedging CI) when a single test exceeds the budget.

    Enabled only when REPRO_TEST_TIMEOUT is set (scripts/check.sh sets it);
    uses SIGALRM, so main-thread only — which is how the suite runs.
    """
    budget = int(os.environ.get("REPRO_TEST_TIMEOUT", "0"))
    if budget <= 0 or os.name != "posix":
        yield
        return

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded REPRO_TEST_TIMEOUT={budget}s: {request.node.nodeid}")

    prev = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(budget)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)
