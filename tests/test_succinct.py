"""Succinct structures (Section 5.2): rank, coders, hybrid blocks."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core.succinct import (BitReader, BitVector, BitWriter,
                                 HybridEncodedArray, delta_length,
                                 encoded_bits_per_entry, gamma_length,
                                 golomb_length, read_delta, read_gamma,
                                 write_delta, write_gamma)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 3000))
def test_bitvector_rank(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    bv = BitVector(bits)
    cum = np.concatenate([[0], np.cumsum(bits)])
    idx = rng.integers(0, n + 1, 32)
    for j in idx:
        assert bv.rank1(int(j)) == cum[j]
    assert np.array_equal(bv.rank1_bulk(idx), cum[idx])
    some = rng.integers(0, n, 16)
    assert np.array_equal(bv.get_bulk(some), bits[some])


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 10 ** 6), min_size=1, max_size=60))
def test_gamma_delta_roundtrip(values):
    bw = BitWriter()
    for v in values:
        write_gamma(bw, v)
    br = BitReader(bw.to_words(), bw.nbits)
    pos = 0
    for v in values:
        got, pos = read_gamma(br, pos)
        assert got == v
    assert pos == sum(gamma_length(v) for v in values)

    bw = BitWriter()
    for v in values:
        write_delta(bw, v)
    br = BitReader(bw.to_words(), bw.nbits)
    pos = 0
    for v in values:
        got, pos = read_delta(br, pos)
        assert got == v
    assert pos == sum(delta_length(v) for v in values)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=1, max_size=300),
       st.sampled_from([4, 8, 16, 32]))
def test_hybrid_array_access(values, block):
    arr = HybridEncodedArray(values, block=block)
    assert arr.decode_all().tolist() == values
    rng = np.random.default_rng(0)
    for j in rng.integers(0, len(values), 20):
        assert arr.access(int(j)) == values[int(j)]


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=16, max_size=400))
def test_hybrid_never_worse_than_components(values):
    """The hybrid scheme's payload is min(fixed, gamma) per block, so its
    average bits/entry is <= both (the Table-2 claim)."""
    h = encoded_bits_per_entry(values, "hybrid")
    f = encoded_bits_per_entry(values, "fixed")
    g = encoded_bits_per_entry(values, "gamma")
    assert h <= f + 1e-9
    assert h <= g + 1e-9


def test_golomb_lengths_sane():
    assert golomb_length(1, 1) == 1
    assert golomb_length(1, 4) == 3  # q=0 stop bit + 2-bit remainder
    for m in (1, 2, 3, 4, 5, 8, 10):
        for x in range(1, 40):
            assert golomb_length(x, m) >= 1


def test_space_bound_section_5_4():
    """|S_X| <= |Psi| * (floor(log b_max) + 1) bits (paper's bound)."""
    rng = np.random.default_rng(2)
    values = rng.integers(1, 40, 700).tolist()
    arr = HybridEncodedArray(values, block=16)
    bmax = max(values)
    bound = len(values) * (int(np.floor(np.log2(bmax))) + 1)
    assert arr.size_bits().s_bits <= bound
