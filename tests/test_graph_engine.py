"""GraphQueryEngine: batched == per-query equivalence and edge cases.

The load-bearing invariant of the batched serving path: for every backend
and both index kinds, the engine's candidate sets and verified matches are
IDENTICAL to the single-query ``MSQIndex.query`` / ``FlatMSQIndex.query``
— bucketing, padding, and worklist ordering must never change answers.
"""
import numpy as np
import pytest

from repro.core.engine import BatchedFilterEval, bucket_queries
from repro.core.search import FlatMSQIndex, MSQIndex
from repro.graphs.generators import aids_like_db, graphgen_db, perturb_graph
from repro.graphs.graph import Graph
from repro.serve.graph_engine import GraphQuery, GraphQueryEngine


@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(180, seed=7)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


@pytest.fixture(scope="module")
def tree(small_db):
    return MSQIndex(small_db)


def _requests(db, n, seed, verify=False, tau_hi=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tau = int(rng.integers(1, tau_hi))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        out.append(GraphQuery(h, tau, verify=verify))
    return out


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_batched_equals_per_query_flat(small_db, flat, backend):
    reqs = _requests(small_db, 16, seed=1)
    eng = GraphQueryEngine(flat, backend=backend)
    out = eng.submit(reqs)
    for r, got in zip(reqs, out):
        assert got.candidates == flat.candidates(r.graph, r.tau)


def test_batched_equals_per_query_tree(small_db, tree):
    reqs = _requests(small_db, 12, seed=2)
    out = GraphQueryEngine(tree).submit(reqs)
    for r, got in zip(reqs, out):
        assert got.candidates == tree.candidates(r.graph, r.tau)[0]


def test_batched_matches_equal_per_query(small_db, flat):
    reqs = _requests(small_db, 6, seed=3, verify=True, tau_hi=3)
    out = GraphQueryEngine(flat).submit(reqs)
    for r, got in zip(reqs, out):
        ref = flat.query(r.graph, r.tau)
        assert got.candidates == ref.candidates
        assert got.matches == ref.matches


def test_other_dbs_and_taus(tmp_path):
    """Equivalence across a second generator family and the full tau sweep."""
    db = graphgen_db(90, num_edges=12, density=0.5, n_vlabels=4,
                     n_elabels=2, seed=13)
    flat = FlatMSQIndex(db)
    eng = GraphQueryEngine(flat)
    rng = np.random.default_rng(4)
    for tau in (0, 1, 2, 4, 6):
        h = perturb_graph(db[int(rng.integers(0, len(db)))], max(tau, 1),
                          rng, db.n_vlabels, db.n_elabels)
        got = eng.query(h, tau, verify=False)
        assert got.candidates == flat.candidates(h, tau)


def test_empty_batch(flat):
    assert GraphQueryEngine(flat).submit([]) == []


def test_empty_region_query(small_db, flat, tree):
    """A query far outside every populated region must return cleanly."""
    giant = Graph(n=500, vlabels=np.zeros(500, np.int32),
                  edges=np.array([(i, i + 1) for i in range(499)], np.int64),
                  elabels=np.zeros(499, np.int32))
    for eng in (GraphQueryEngine(flat), GraphQueryEngine(tree)):
        res = eng.query(giant, 1)
        assert res.candidates == []
        assert res.matches == []
        assert res.n_filtered == len(small_db)


def test_result_cache_and_duplicates(small_db, flat):
    reqs = _requests(small_db, 4, seed=5)
    dup = [reqs[0], reqs[1], reqs[0], reqs[2], reqs[0], reqs[3]]
    eng = GraphQueryEngine(flat)
    out1 = eng.submit(dup)
    assert out1[0].candidates == out1[2].candidates == out1[4].candidates
    # a second submit of the same batch is served from the result cache
    before = eng.cache_info["result_hits"]
    out2 = eng.submit(dup)
    assert eng.cache_info["result_hits"] > before
    for a, b in zip(out1, out2):
        assert a.candidates == b.candidates


def test_bucketing_groups_equal_rectangles(small_db, flat):
    reqs = _requests(small_db, 20, seed=6)
    graphs = [r.graph for r in reqs]
    taus = [r.tau for r in reqs]
    buckets = bucket_queries(flat.partition, graphs, taus)
    assert sorted(qi for qis in buckets.values() for qi in qis) \
        == list(range(len(reqs)))
    for (i1, i2, j1, j2), qis in buckets.items():
        for qi in qis:
            assert flat.partition.query_region(
                graphs[qi].n, graphs[qi].m, taus[qi]) == (i1, i2, j1, j2)


def test_filter_eval_reused_across_batches(flat):
    ev1 = flat.filter_eval("numpy")
    ev2 = flat.filter_eval("numpy")
    assert ev1 is ev2
    assert isinstance(ev1, BatchedFilterEval)


# ---- stage-1.5 assignment lower bound (DESIGN.md §16) ----------------------

@pytest.fixture(scope="module")
def lb_db():
    # label-poor on purpose: the q-gram filter admits candidates whose GED
    # is far above tau, so the LB stage actually prunes here instead of
    # riding along inert
    return graphgen_db(120, num_edges=12, density=0.5, n_vlabels=3,
                       n_elabels=2, seed=3)


@pytest.mark.parametrize("backend,slab", [
    ("numpy", "dense"), ("numpy", "hot"), ("numpy", "packed"),
    ("jax", "dense"), ("pallas", "dense"),
])
def test_assign_lb_match_parity(lb_db, backend, slab):
    """The recall-safety invariant: candidates AND verified matches are
    bit-identical with the LB stage off / on / on+Hungarian — the bound
    only moves verification work, never answers."""
    flat = FlatMSQIndex(lb_db)
    rng = np.random.default_rng(11)
    reqs = [GraphQuery(perturb_graph(lb_db[int(rng.integers(0, len(lb_db)))],
                                     2, rng, lb_db.n_vlabels,
                                     lb_db.n_elabels), 4, verify=True)
            for _ in range(6)]
    base = GraphQueryEngine(flat, backend=backend, slab_layout=slab,
                            assign_lb=False).submit(reqs)
    for lb_hungarian in (0, 4):
        eng = GraphQueryEngine(flat, backend=backend, slab_layout=slab,
                               assign_lb=True, lb_hungarian=lb_hungarian)
        out = eng.submit(reqs)
        for a, b in zip(out, base):
            assert a.candidates == b.candidates
            assert a.matches == b.matches
        if lb_hungarian == 0:
            # the stage must actually fire on this workload, not pass
            # vacuously
            assert eng.stats["lb_pruned"] > 0
            assert eng.stats["lb_pruned"] + eng.stats["verified_pairs"] > 0


# ---- top-k modality (adaptive-τ escalation, DESIGN.md §15) -----------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st


@pytest.fixture(scope="module")
def topk_db():
    # small enough that the k-smallest-GED brute-force oracle is cheap
    return aids_like_db(100, seed=9)


@pytest.fixture(scope="module")
def topk_flat(topk_db):
    return FlatMSQIndex(topk_db)


def _topk_queries(db, n=4, seed=21):
    rng = np.random.default_rng(seed)
    return [perturb_graph(db[int(rng.integers(0, len(db)))],
                          int(rng.integers(1, 3)), rng, db.n_vlabels,
                          db.n_elabels) for _ in range(n)]


def _oracle_topk(db, g, k, cap):
    """Brute-force k smallest GEDs over the whole db, tie rule (ged, gid)
    — independent of every filter/index/scheduler code path."""
    from repro.core.verify import ged_upto
    ds = sorted((ged_upto(g, h, cap), gid) for gid, h in enumerate(db))
    return [(gid, d) for d, gid in ds if d <= cap][:k]


@pytest.fixture(scope="module")
def topk_oracle(topk_db):
    """One oracle evaluation shared across the backend x layout matrix."""
    qs = _topk_queries(topk_db)
    return qs, {(i, k, cap): _oracle_topk(topk_db, g, k, cap)
                for i, g in enumerate(qs)
                for k, cap in ((1, 3), (3, 4), (5, 4))}


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("slab", ["dense", "hot", "packed"])
def test_topk_equals_oracle_backend_layout_matrix(topk_db, topk_flat,
                                                  topk_oracle, backend,
                                                  slab):
    """Engine top-k is bit-identical to the brute-force k-smallest-GED
    oracle for every backend x FilterSlab layout, and the escalation
    never decides a (query, gid) pair twice (scheduler stats account for
    every seen candidate exactly once: verified, pruned, or expired)."""
    qs, oracle = topk_oracle
    eng = GraphQueryEngine(topk_flat, backend=backend, slab_layout=slab,
                           hot_d=8, result_cache_size=0)
    reqs, want = [], []
    for i, g in enumerate(qs):
        for k, cap in ((1, 3), (3, 4), (5, 4)):
            reqs.append(GraphQuery(g, cap, top_k=k))
            want.append(oracle[(i, k, cap)])
    out = eng.submit(reqs)
    for r, got, ref in zip(reqs, out, want):
        assert [tuple(m) for m in got.matches] == ref, \
            (backend, slab, r.top_k, r.tau)
        assert got.stats["top_k"] == r.top_k
    decided = (eng.stats["verified_pairs"] + eng.stats["pruned_pairs"]
               + eng.stats["expired_pairs"])
    assert decided == sum(len(r.candidates) for r in out), \
        "a decided (query, gid) pair was re-verified across escalation"
    assert eng.stats["expired_pairs"] == 0


def test_topk_escalates_and_stops_early(topk_db, topk_flat):
    """k hits inside a small τ: escalation stops once the kth-best bound
    proves no wider τ helps (final τ < cap), and stats record rounds."""
    g = topk_db[5]                       # exact member: d(g, 5) = 0
    eng = GraphQueryEngine(topk_flat, backend="numpy",
                           result_cache_size=0)
    res = eng.query_topk(g, k=1, cap=6)
    assert [tuple(m) for m in res.matches] == [(5, 0)]
    assert res.stats["topk_rounds"] >= 1
    assert res.stats["topk_tau_final"] < 6   # kth-best (0) ended it early
    assert "partial" not in res.stats


def test_topk_exhausted_when_k_exceeds_cap_ball(topk_db, topk_flat):
    """Fewer than k graphs within the cap: every one is returned, the
    result is flagged exhausted, never partial."""
    qs = _topk_queries(topk_db, n=2, seed=33)
    eng = GraphQueryEngine(topk_flat, backend="numpy",
                           result_cache_size=0)
    for g in qs:
        want = _oracle_topk(topk_db, g, len(topk_db), 1)
        res = eng.query_topk(g, k=len(topk_db), cap=1)
        assert [tuple(m) for m in res.matches] == want
        assert res.stats["topk_exhausted"] == 1
        assert "partial" not in res.stats


def test_topk_mixed_batch_matches_solo(topk_db, topk_flat):
    """Top-k and range queries share one submit(): same answers as when
    issued alone (the split paths must not interfere)."""
    qs = _topk_queries(topk_db, n=3, seed=44)
    mixed = [GraphQuery(qs[0], 4, top_k=2), GraphQuery(qs[1], 2),
             GraphQuery(qs[2], 4, top_k=4), GraphQuery(qs[0], 1),
             GraphQuery(qs[1], 3, top_k=1)]
    eng = GraphQueryEngine(topk_flat, backend="numpy",
                           result_cache_size=0)
    out = eng.submit(mixed)
    solo = GraphQueryEngine(topk_flat, backend="numpy",
                            result_cache_size=0)
    for r, got in zip(mixed, out):
        ref = solo.submit([r])[0]
        assert got.matches == ref.matches
        assert got.candidates == ref.candidates


def test_topk_validation(topk_db):
    with pytest.raises(ValueError, match="top_k"):
        GraphQuery(topk_db[0], 3, top_k=0)
    with pytest.raises(ValueError, match="verify"):
        GraphQuery(topk_db[0], 3, top_k=2, verify=False)


def test_topk_result_cache_is_modality_safe(topk_db, topk_flat):
    """A cached range-τ result must never answer a top-k query at the
    same (graph, τ) — and vice versa; repeats within a modality hit."""
    g = _topk_queries(topk_db, n=1, seed=55)[0]
    eng = GraphQueryEngine(topk_flat, backend="numpy")
    r_range = eng.query(g, 4)
    r_topk = eng.query_topk(g, k=2, cap=4)
    assert "top_k" not in r_range.stats
    assert r_topk.stats["top_k"] == 2
    # same modality repeats are cache hits with identical payloads
    again_r = eng.query(g, 4)
    again_k = eng.query_topk(g, k=2, cap=4)
    assert again_r.stats.get("cache_hit") == 1
    assert again_k.stats.get("cache_hit") == 1
    assert again_r.matches == r_range.matches
    assert again_k.matches == r_topk.matches
    # distinct k at the same (graph, τ) is a distinct entry
    r_k3 = eng.query_topk(g, k=3, cap=4)
    assert "cache_hit" not in r_k3.stats
    assert len(r_k3.matches) >= len(r_topk.matches)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_topk_property_random_k_cap(topk_db, topk_flat, k, cap, seed):
    """Property: for random (k, cap, query) draws the engine's top-k list
    equals the oracle's k-smallest (ged, gid) — including sort order."""
    rng = np.random.default_rng(seed)
    g = perturb_graph(topk_db[int(rng.integers(0, len(topk_db)))],
                      int(rng.integers(1, 3)), rng, topk_db.n_vlabels,
                      topk_db.n_elabels)
    eng = GraphQueryEngine(topk_flat, backend="numpy",
                           result_cache_size=0)
    res = eng.query_topk(g, k=k, cap=cap)
    assert [tuple(m) for m in res.matches] == _oracle_topk(
        topk_db, g, k, cap)
