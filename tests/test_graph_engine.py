"""GraphQueryEngine: batched == per-query equivalence and edge cases.

The load-bearing invariant of the batched serving path: for every backend
and both index kinds, the engine's candidate sets and verified matches are
IDENTICAL to the single-query ``MSQIndex.query`` / ``FlatMSQIndex.query``
— bucketing, padding, and worklist ordering must never change answers.
"""
import numpy as np
import pytest

from repro.core.engine import BatchedFilterEval, bucket_queries
from repro.core.search import FlatMSQIndex, MSQIndex
from repro.graphs.generators import aids_like_db, graphgen_db, perturb_graph
from repro.graphs.graph import Graph
from repro.serve.graph_engine import GraphQuery, GraphQueryEngine


@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(180, seed=7)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


@pytest.fixture(scope="module")
def tree(small_db):
    return MSQIndex(small_db)


def _requests(db, n, seed, verify=False, tau_hi=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tau = int(rng.integers(1, tau_hi))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        out.append(GraphQuery(h, tau, verify=verify))
    return out


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_batched_equals_per_query_flat(small_db, flat, backend):
    reqs = _requests(small_db, 16, seed=1)
    eng = GraphQueryEngine(flat, backend=backend)
    out = eng.submit(reqs)
    for r, got in zip(reqs, out):
        assert got.candidates == flat.candidates(r.graph, r.tau)


def test_batched_equals_per_query_tree(small_db, tree):
    reqs = _requests(small_db, 12, seed=2)
    out = GraphQueryEngine(tree).submit(reqs)
    for r, got in zip(reqs, out):
        assert got.candidates == tree.candidates(r.graph, r.tau)[0]


def test_batched_matches_equal_per_query(small_db, flat):
    reqs = _requests(small_db, 6, seed=3, verify=True, tau_hi=3)
    out = GraphQueryEngine(flat).submit(reqs)
    for r, got in zip(reqs, out):
        ref = flat.query(r.graph, r.tau)
        assert got.candidates == ref.candidates
        assert got.matches == ref.matches


def test_other_dbs_and_taus(tmp_path):
    """Equivalence across a second generator family and the full tau sweep."""
    db = graphgen_db(90, num_edges=12, density=0.5, n_vlabels=4,
                     n_elabels=2, seed=13)
    flat = FlatMSQIndex(db)
    eng = GraphQueryEngine(flat)
    rng = np.random.default_rng(4)
    for tau in (0, 1, 2, 4, 6):
        h = perturb_graph(db[int(rng.integers(0, len(db)))], max(tau, 1),
                          rng, db.n_vlabels, db.n_elabels)
        got = eng.query(h, tau, verify=False)
        assert got.candidates == flat.candidates(h, tau)


def test_empty_batch(flat):
    assert GraphQueryEngine(flat).submit([]) == []


def test_empty_region_query(small_db, flat, tree):
    """A query far outside every populated region must return cleanly."""
    giant = Graph(n=500, vlabels=np.zeros(500, np.int32),
                  edges=np.array([(i, i + 1) for i in range(499)], np.int64),
                  elabels=np.zeros(499, np.int32))
    for eng in (GraphQueryEngine(flat), GraphQueryEngine(tree)):
        res = eng.query(giant, 1)
        assert res.candidates == []
        assert res.matches == []
        assert res.n_filtered == len(small_db)


def test_result_cache_and_duplicates(small_db, flat):
    reqs = _requests(small_db, 4, seed=5)
    dup = [reqs[0], reqs[1], reqs[0], reqs[2], reqs[0], reqs[3]]
    eng = GraphQueryEngine(flat)
    out1 = eng.submit(dup)
    assert out1[0].candidates == out1[2].candidates == out1[4].candidates
    # a second submit of the same batch is served from the result cache
    before = eng.cache_info["result_hits"]
    out2 = eng.submit(dup)
    assert eng.cache_info["result_hits"] > before
    for a, b in zip(out1, out2):
        assert a.candidates == b.candidates


def test_bucketing_groups_equal_rectangles(small_db, flat):
    reqs = _requests(small_db, 20, seed=6)
    graphs = [r.graph for r in reqs]
    taus = [r.tau for r in reqs]
    buckets = bucket_queries(flat.partition, graphs, taus)
    assert sorted(qi for qis in buckets.values() for qi in qis) \
        == list(range(len(reqs)))
    for (i1, i2, j1, j2), qis in buckets.items():
        for qi in qis:
            assert flat.partition.query_region(
                graphs[qi].n, graphs[qi].m, taus[qi]) == (i1, i2, j1, j2)


def test_filter_eval_reused_across_batches(flat):
    ev1 = flat.filter_eval("numpy")
    ev2 = flat.filter_eval("numpy")
    assert ev1 is ev2
    assert isinstance(ev1, BatchedFilterEval)
