"""Exact-GED verification tests: A* vs brute force + metric properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core.verify import ged_bruteforce, ged_exact, ged_upto
from repro.graphs.generators import perturb_graph, random_graph
from repro.graphs.graph import Graph

NV, NE = 3, 2


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_astar_equals_bruteforce(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    assert ged_exact(g, h) == ged_bruteforce(g, h)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_ged_symmetry_and_identity(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 4)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 4)),
                     NV, NE, connected=False)
    assert ged_exact(g, g) == 0
    assert ged_exact(g, h) == ged_exact(h, g)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_ged_triangle_inequality(seed):
    rng = np.random.default_rng(seed)
    gs = [random_graph(rng, int(rng.integers(1, 4)), int(rng.integers(0, 3)),
                       NV, NE, connected=False) for _ in range(3)]
    d01 = ged_exact(gs[0], gs[1])
    d12 = ged_exact(gs[1], gs[2])
    d02 = ged_exact(gs[0], gs[2])
    assert d02 <= d01 + d12


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(0, 3))
def test_perturbation_upper_bound(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(2, 6)), int(rng.integers(1, 6)),
                     NV, NE)
    h = perturb_graph(g, k, rng, NV, NE)
    assert ged_upto(g, h, k) <= k


def test_ged_upto_cutoff_semantics():
    rng = np.random.default_rng(7)
    g = random_graph(rng, 4, 4, NV, NE)
    h = perturb_graph(g, 6, rng, NV, NE)
    true = ged_exact(g, h)
    for tau in range(0, true + 2):
        r = ged_upto(g, h, tau)
        if tau >= true:
            assert r == true
        else:
            assert r == tau + 1


def test_isomorphic_relabeling_is_zero():
    rng = np.random.default_rng(9)
    g = random_graph(rng, 6, 8, NV, NE)
    perm = rng.permutation(6)
    assert ged_exact(g, g.relabel_vertices(perm)) == 0


# --------------------------------------------------------------------------
# escalation invariants (DESIGN.md §15): decisions made at a narrow filter
# τ stay valid at every wider τ, and a cap-cutoff GEDSearch sliced across
# escalation rounds decides exactly like a one-shot run
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 5))
def test_decided_pair_valid_across_tau_widening(seed, cap):
    """The no-recompute premise of adaptive-τ top-k: once ``ged_upto(g, h,
    cap)`` decides a pair, re-asking at any admission τ' changes nothing
    — a decided exact d <= cap is the same d for every cutoff >= d."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(2, 5)), int(rng.integers(1, 5)),
                     NV, NE, connected=False)
    h = perturb_graph(g, int(rng.integers(0, cap + 2)), rng, NV, NE)
    d = ged_upto(g, h, cap)
    if d <= cap:                         # decided: exact at cutoff cap
        for wider in range(d, cap + 3):
            assert ged_upto(g, h, wider) == d
    else:                                # undecided at cap: only > cap known
        assert d == cap + 1
        assert ged_upto(g, h, cap + 2) > cap


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 4), st.integers(1, 6))
def test_ged_search_resume_across_rounds_equals_oneshot(seed, cap, budget):
    """Top-k escalation parks an undecided ``GEDSearch`` (cutoff = the
    query cap) and resumes it in a later round: arbitrary slicing of the
    same search object must reproduce the one-shot decision and frontier
    bound exactly."""
    from repro.core.verify import GEDSearch
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(2, 5)), int(rng.integers(1, 5)),
                     NV, NE, connected=False)
    h = perturb_graph(g, int(rng.integers(0, cap + 2)), rng, NV, NE)
    want = ged_upto(g, h, cap)
    s = GEDSearch(g, h, cap)
    rounds = 0
    r = None
    while r is None:
        r = s.run(max_expansions=budget)   # one escalation round's slice
        rounds += 1
        assert rounds < 10_000
    assert r == want
    assert s.done and s.min_f() == want
    # a decided search re-entered by a later round is a no-op, not a redo
    exp_before = s.expansions
    assert s.run(max_expansions=budget) == want
    assert s.expansions == exp_before
