"""Exact-GED verification tests: A* vs brute force + metric properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.core.verify import ged_bruteforce, ged_exact, ged_upto
from repro.graphs.generators import perturb_graph, random_graph
from repro.graphs.graph import Graph

NV, NE = 3, 2


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_astar_equals_bruteforce(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 5)),
                     NV, NE, connected=False)
    assert ged_exact(g, h) == ged_bruteforce(g, h)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_ged_symmetry_and_identity(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 4)),
                     NV, NE, connected=False)
    h = random_graph(rng, int(rng.integers(1, 5)), int(rng.integers(0, 4)),
                     NV, NE, connected=False)
    assert ged_exact(g, g) == 0
    assert ged_exact(g, h) == ged_exact(h, g)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_ged_triangle_inequality(seed):
    rng = np.random.default_rng(seed)
    gs = [random_graph(rng, int(rng.integers(1, 4)), int(rng.integers(0, 3)),
                       NV, NE, connected=False) for _ in range(3)]
    d01 = ged_exact(gs[0], gs[1])
    d12 = ged_exact(gs[1], gs[2])
    d02 = ged_exact(gs[0], gs[2])
    assert d02 <= d01 + d12


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(0, 3))
def test_perturbation_upper_bound(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(2, 6)), int(rng.integers(1, 6)),
                     NV, NE)
    h = perturb_graph(g, k, rng, NV, NE)
    assert ged_upto(g, h, k) <= k


def test_ged_upto_cutoff_semantics():
    rng = np.random.default_rng(7)
    g = random_graph(rng, 4, 4, NV, NE)
    h = perturb_graph(g, 6, rng, NV, NE)
    true = ged_exact(g, h)
    for tau in range(0, true + 2):
        r = ged_upto(g, h, tau)
        if tau >= true:
            assert r == true
        else:
            assert r == tau + 1


def test_isomorphic_relabeling_is_zero():
    rng = np.random.default_rng(9)
    g = random_graph(rng, 6, 8, NV, NE)
    perm = rng.permutation(6)
    assert ged_exact(g, g.relabel_vertices(perm)) == 0
