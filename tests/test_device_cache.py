"""DeviceSlabCache + query-batched pallas engine path (DESIGN.md §13).

Three invariants:

* the batched pallas `_bounds_pallas` (one kernel launch per bucket)
  matches the numpy backend bit-for-bit across layouts and ragged
  (Q, B) shapes — and is invariant to the (qb, bb, bu) tile choice;
* repeated batches hit the device cache (no re-gather / re-upload), and
  eviction/invalidation never changes results;
* invalidation hooks fire: ``rebuild_slab`` and ``set_filter_eval``
  empty the cache of a replaced slab.
"""
import json
import os

import numpy as np
import pytest

from repro.core.engine import BatchedFilterEval, sparse_query_fd
from repro.core.device_cache import DeviceSlabCache, bucket_key
from repro.core.search import FlatMSQIndex
from repro.graphs.generators import aids_like_db, perturb_graph


@pytest.fixture(scope="module")
def small_db():
    return aids_like_db(140, seed=5)


@pytest.fixture(scope="module")
def flat(small_db):
    return FlatMSQIndex(small_db)


def _queries(db, ev, n, seed=1):
    rng = np.random.default_rng(seed)
    qs, taus = [], []
    for _ in range(n):
        tau = int(rng.integers(1, 4))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        qs.append(ev.query_arrays(h, tau))
        taus.append(tau)
    return qs, taus


# --------------------------------------------------------------------------
# batched pallas parity vs numpy, layouts x ragged shapes x tiles
# --------------------------------------------------------------------------

@pytest.mark.parametrize("slab", ["dense", "hot", "packed"])
@pytest.mark.parametrize("Q,N", [(1, 17), (5, 140), (11, 97)])
def test_batched_pallas_matches_numpy(flat, small_db, slab, Q, N):
    ev_np = flat.filter_eval("numpy", slab=slab, hot_d=24)
    ev_pl = flat.filter_eval("pallas", slab=slab, hot_d=24)
    qs, _ = _queries(small_db, ev_np, Q, seed=Q * 100 + N)
    idx = np.sort(np.random.default_rng(N).choice(
        len(small_db), size=N, replace=False))
    want = ev_np.bounds(idx, qs)
    got = ev_pl.bounds(idx, qs)
    assert np.array_equal(np.asarray(got, np.int64),
                          np.asarray(want, np.int64))


def test_batched_pallas_tile_invariance(flat, small_db):
    from repro.kernels.qgram_filter.autotune import TileTable
    ev_np = flat.filter_eval("numpy", slab="dense")
    qs, _ = _queries(small_db, ev_np, 6, seed=9)
    idx = np.arange(len(small_db))
    want = np.asarray(ev_np.bounds(idx, qs), np.int64)
    for tiles in [(4, 32, 128), (8, 128, 512), (16, 64, 256)]:
        ev = BatchedFilterEval(flat.db, flat.enc, flat.partition, "pallas",
                               tile_table=TileTable(default=tiles))
        got = ev.bounds(idx, qs)
        assert np.array_equal(np.asarray(got, np.int64), want), tiles


# --------------------------------------------------------------------------
# cache behaviour
# --------------------------------------------------------------------------

def test_cache_hits_on_repeat_and_results_stable(flat, small_db):
    ev = BatchedFilterEval(flat.db, flat.enc, flat.partition, "pallas")
    qs, _ = _queries(small_db, ev, 4, seed=2)
    idx = np.arange(len(small_db))
    first = ev.bounds(idx, qs)
    misses = ev.device_cache.stats["misses"]
    assert misses > 0 and ev.device_cache.stats["hits"] == 0
    again = ev.bounds(idx, qs)
    assert ev.device_cache.stats["misses"] == misses   # all fields reused
    assert ev.device_cache.stats["hits"] > 0
    assert np.array_equal(first, again)


def test_cache_invalidated_on_rebuild_slab(flat, small_db):
    ev = BatchedFilterEval(flat.db, flat.enc, flat.partition, "numpy",
                           slab="dense")
    qs, _ = _queries(small_db, ev, 3, seed=3)
    idx = np.arange(len(small_db))
    want = np.asarray(ev.bounds(idx, qs))
    assert len(ev.device_cache) > 0
    ev.rebuild_slab(layout="hot", hot_d=16)
    assert len(ev.device_cache) == 0           # stale uploads dropped
    assert ev.slab_layout == "hot"
    got = np.asarray(ev.bounds(idx, qs))       # rebuilt slab, same bounds
    assert np.array_equal(got, want)
    assert ev.device_cache.stats["invalidations"] == 1


def test_set_filter_eval_invalidates_replaced_evaluator(small_db):
    flat = FlatMSQIndex(small_db)
    ev1 = flat.filter_eval("numpy")
    qs, _ = _queries(small_db, ev1, 2, seed=4)
    ev1.bounds(np.arange(len(small_db)), qs)
    assert len(ev1.device_cache) > 0
    ev2 = BatchedFilterEval(flat.db, flat.enc, flat.partition, "numpy")
    flat.set_filter_eval("numpy", ev2)
    assert len(ev1.device_cache) == 0
    # re-registering the same evaluator must NOT clear its cache
    ev2.bounds(np.arange(len(small_db)), qs)
    n = len(ev2.device_cache)
    flat.set_filter_eval("numpy", ev2)
    assert len(ev2.device_cache) == n


def test_cache_lru_eviction_bounded():
    cache = DeviceSlabCache(max_entries=2)
    for i in range(5):
        cache.get_or_build(("k", i), "f", lambda i=i: i)
    assert len(cache) == 2
    assert cache.stats["evictions"] == 3
    # survivors are the most recent keys
    assert cache.get_or_build(("k", 4), "f", lambda: -1) == 4


def test_bucket_key_exact_identity():
    a = np.array([1, 2, 3], np.int64)
    b = np.array([1, 2, 4], np.int64)
    assert bucket_key(a, 8) == bucket_key(a.copy(), 8)
    assert bucket_key(a, 8) != bucket_key(b, 8)
    assert bucket_key(a, 8) != bucket_key(a, 16)


# --------------------------------------------------------------------------
# sparse query C_D helper + autotune table
# --------------------------------------------------------------------------

def test_sparse_query_fd_roundtrip():
    rng = np.random.default_rng(0)
    qfd = rng.integers(0, 3, (5, 40)).astype(np.int32)
    ids, cnt = sparse_query_fd(qfd, pad=8)
    assert ids.shape == cnt.shape and ids.shape[1] % 8 == 0
    dense = np.zeros_like(qfd)
    for r in range(5):
        np.add.at(dense[r], ids[r], cnt[r])   # id-0 pads carry count 0
    assert np.array_equal(dense, qfd)


def test_autotune_roundtrip(flat, tmp_path):
    """The sweep on a tiny slab persists a table that loads back and
    resolves real shapes; unknown shapes fall back to the defaults."""
    from repro.kernels.qgram_filter import autotune
    ev = BatchedFilterEval(flat.db, flat.enc, flat.partition, "pallas")
    path = os.path.join(tmp_path, "tiles.json")
    table = ev.autotune_tiles(qs=(4,), save_path=path, repeats=1,
                              candidates=[(4, 64, 128), (8, 128, 256)])
    assert len(table) > 0
    doc = json.load(open(path))
    assert doc["entries"] and doc["timed_on"]
    loaded = autotune.load_tile_table(path)
    key = next(iter(loaded.entries))
    q, b, u = (int(x) for x in key.split("x"))
    assert loaded.lookup(q, b, u) == tuple(loaded.entries[key])
    assert loaded.lookup(10 ** 6, 10 ** 6, 10 ** 6) == loaded.default
    autotune.load_tile_table.cache_clear()


def test_load_tile_table_missing_file_is_default():
    from repro.kernels.qgram_filter.autotune import (DEFAULT_TILES,
                                                     load_tile_table)
    t = load_tile_table("/nonexistent/qgram_filter.json")
    assert t.lookup(8, 512, 1024) == DEFAULT_TILES
    load_tile_table.cache_clear()


def test_save_table_never_downgrades_tpu_entries(tmp_path):
    """A CPU-interpret sweep must not clobber TPU-timed tiles — the one
    provenance that actually tunes anything."""
    from repro.kernels.qgram_filter import autotune
    path = os.path.join(tmp_path, "t.json")
    with open(path, "w") as f:
        json.dump({"version": 1, "timed_on": "tpu",
                   "entries": {"8x512x1024": {"tiles": [16, 256, 512],
                                              "us": 5.0,
                                              "timed_on": "tpu"}}}, f)
    table = autotune.save_table(
        {"8x512x1024": {"tiles": [4, 64, 128], "us": 1.0},
         "8x64x128": {"tiles": [4, 64, 128], "us": 1.0}}, path)
    doc = json.load(open(path))
    assert doc["entries"]["8x512x1024"]["tiles"] == [16, 256, 512]  # kept
    assert "8x64x128" in doc["entries"]                # new keys merge in
    assert doc["timed_on"] == "tpu"
    assert table.entries["8x512x1024"] == (16, 256, 512)
    autotune.load_tile_table.cache_clear()


def test_engine_tile_table_plumbs_to_evaluator(flat, small_db):
    """GraphQueryEngine(tile_table=...) must reach the pallas evaluator —
    the config knob is real, not decorative."""
    from repro.kernels.qgram_filter.autotune import TileTable
    from repro.serve.graph_engine import GraphQuery, GraphQueryEngine
    table = TileTable(default=(4, 64, 128))
    eng = GraphQueryEngine(flat, backend="pallas", result_cache_size=0,
                           tile_table=table)
    qs, taus = _queries(small_db, flat.filter_eval("numpy"), 2, seed=8)
    rng = np.random.default_rng(0)
    g = perturb_graph(small_db[0], 1, rng, small_db.n_vlabels,
                      small_db.n_elabels)
    eng.submit([GraphQuery(g, 2, verify=False)])
    assert flat.filter_eval("pallas").tile_table is table
