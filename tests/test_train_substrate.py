"""Optimizers, checkpointing (atomic/async/elastic), trainer fault
tolerance, gradient compression, data pipeline."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic fallback (tests/_propshim.py)
    from _propshim import given, settings, strategies as st

from repro.data import ShardedLoader, StragglerSimulator, SyntheticLMDataset
from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         compressed_gradient, cosine_schedule, global_norm,
                         int8_dequantize, int8_quantize)
from repro.train import (CheckpointManager, FailureInjector, Trainer,
                         TrainerConfig, make_train_step)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lambda s: 0.05, weight_decay=0.0),
    lambda: adafactor(lambda s: 0.5),
])
def test_optimizer_decreases_quadratic(make_opt):
    opt_init, opt_update = make_opt()
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)),
                               jnp.float32),
              "b": jnp.ones((4,), jnp.float32)}
    target = jax.tree.map(lambda p: p * 0.0, params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    state = opt_init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt_update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_memory_factored():
    _, _ = adafactor(lambda s: 1e-3)
    opt_init, _ = adafactor(lambda s: 1e-3)
    p = {"m": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    st_ = opt_init(p)
    assert st_.inner["m"]["r"].shape == (64,)
    assert st_.inner["m"]["c"].shape == (32,)
    assert st_.inner["v"]["v"].shape == (16,)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6
    assert float(lr(jnp.int32(55))) < float(lr(jnp.int32(20)))


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_error_feedback_preserves_mass(seed):
    """dense + residual_new == g + residual_old (nothing lost, only delayed)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    dense, new_res = compressed_gradient(g, res, k_frac=0.1)
    np.testing.assert_allclose(np.asarray(dense + new_res),
                               np.asarray(g + res), atol=1e-6)
    assert int((np.asarray(dense) != 0).sum()) <= 7  # ~10% of 64, top-k


def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, scale = int8_quantize(g)
    back = int8_dequantize(q, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) / 2 + 1e-7


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32)},
            "opt": {"m": jnp.zeros((6, 3)), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s)
    restored, step = mgr.restore(s)
    assert step == 3
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_incomplete(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    # simulate a crashed writer: directory without _COMPLETE
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save_async(step, _state(step))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_restore_latest_of_many(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    for step in (2, 5, 9):
        mgr.save(step, _state(step))
    _, step = mgr.restore(_state())
    assert step == 9


# --------------------------------------------------------------------------
# trainer fault tolerance
# --------------------------------------------------------------------------

def _tiny_training(tmp_path, fail_steps=()):
    from repro.configs import get_config, reduced
    from repro.models import build_params
    cfg = reduced(get_config("qwen3-1.7b")).replace(n_units=1)
    params = build_params(cfg, jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(cosine_schedule(1e-3, 2, 30))
    step = jax.jit(make_train_step(cfg, opt_update))
    ds = SyntheticLMDataset(cfg.vocab_size, 16, 4)
    loader = ShardedLoader(ds)
    trainer = Trainer(step, params, opt_init(params), loader,
                      TrainerConfig(total_steps=12, checkpoint_every=4,
                                    checkpoint_dir=str(tmp_path),
                                    log_every=1),
                      failure_injector=FailureInjector(fail_steps))
    return trainer


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _tiny_training(tmp_path)
    out = tr.run()
    assert out["final_step"] == 12
    assert out["restarts"] == 0
    assert tr.ckpt.latest_step() == 12
    losses = [m["loss"] for m in out["metrics"]]
    # per-batch loss on synthetic data is noisy; compare windowed means
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_trainer_recovers_from_failures(tmp_path):
    tr = _tiny_training(tmp_path, fail_steps=(5, 9))
    out = tr.run()
    assert out["final_step"] == 12
    assert out["restarts"] == 2


def test_trainer_gives_up_after_max_retries(tmp_path):
    tr = _tiny_training(tmp_path)
    tr.inject = FailureInjector(())

    class AlwaysFail:
        remaining = None
        def check(self, step):
            if step == 3:
                raise RuntimeError("permanent failure")
    tr.inject = AlwaysFail()
    tr.tcfg.max_retries = 2
    with pytest.raises(RuntimeError):
        tr.run()


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_dataset_deterministic_and_sharded():
    a = SyntheticLMDataset(100, 8, 16, n_shards=4, shard_id=1, seed=3)
    b = SyntheticLMDataset(100, 8, 16, n_shards=4, shard_id=1, seed=3)
    c = SyntheticLMDataset(100, 8, 16, n_shards=4, shard_id=2, seed=3)
    np.testing.assert_array_equal(a.batch(5)["inputs"], b.batch(5)["inputs"])
    assert not np.array_equal(a.batch(5)["inputs"], c.batch(5)["inputs"])
    assert a.batch(0)["inputs"].shape == (4, 8)


def test_straggler_speculative_reissue():
    ds = SyntheticLMDataset(50, 4, 2, seed=0)
    loader = ShardedLoader(ds, straggler_timeout_s=0.05,
                           straggler=StragglerSimulator(slow_every=3,
                                                        delay_s=0.5))
    batches = []
    for i, b in enumerate(loader.iterate(0, 6)):
        batches.append(b)
    assert len(batches) == 6
    assert loader.reissues >= 1
    # reissued batches are identical to what the slow worker would produce
    np.testing.assert_array_equal(batches[2]["inputs"], ds.batch(2)["inputs"])
