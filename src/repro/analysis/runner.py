"""repro-lint runner: load targets, run rules, apply suppressions and
the baseline, report (DESIGN.md §14).

CLI (via ``scripts/lint.py`` / ``make lint``):

    python scripts/lint.py                 # whole suite, exit 1 on new
    python scripts/lint.py --select LCK    # one family (prefix match)
    python scripts/lint.py --select DOC    # == make check-docs
    python scripts/lint.py --list-rules
    python scripts/lint.py --update-baseline

Pure stdlib — safe as the first CI gate before any heavy import.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import docs, jax_rules, locks, pallas_rules, serve_rules
from repro.analysis.core import (FileCtx, Finding, Rule, filter_suppressed,
                                 load_baseline, new_findings, write_baseline)
from repro.analysis.targets import targets_for

# family prefix -> rule classes
FAMILIES: Dict[str, Tuple[type, ...]] = {
    "LCK": locks.RULES,
    "JAX": jax_rules.RULES,
    "PLC": pallas_rules.RULES,
    "DOC": docs.RULES,
    "SRV": serve_rules.RULES,
}

DEFAULT_BASELINE = "scripts/lint_baseline.json"


def all_rules() -> List[Tuple[str, Rule]]:
    out = []
    for fam, classes in FAMILIES.items():
        for cls in classes:
            out.append((fam, cls()))
    return out


def _load_ctxs(root: str, paths: Iterable[str]) -> Dict[str, FileCtx]:
    ctxs: Dict[str, FileCtx] = {}
    for rel in paths:
        if rel in ctxs:
            continue
        abspath = os.path.join(root, rel)
        try:
            ctxs[rel] = FileCtx.load(abspath, rel)
        except (OSError, SyntaxError) as e:
            raise SystemExit(f"lint: cannot parse {rel}: {e}")
    return ctxs


def run_lint(root: str, select: Optional[str] = None,
             files: Optional[Sequence[str]] = None
             ) -> Tuple[List[Finding], Dict[str, FileCtx]]:
    """All unsuppressed findings for the selected families."""
    fam_targets = targets_for(root)
    findings: List[Finding] = []
    ctx_cache: Dict[str, FileCtx] = {}
    for fam, rule in all_rules():
        if select and not any(c.startswith(select.upper())
                              for c in rule.codes):
            continue
        paths = list(files) if files is not None else fam_targets[fam]
        missing = [p for p in paths if p not in ctx_cache]
        ctx_cache.update(_load_ctxs(root, missing))
        ctxs = [ctx_cache[p] for p in paths]
        findings.extend(rule.run_project(ctxs, root))
    return filter_suppressed(findings, ctx_cache), ctx_cache


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="lint.py",
        description="repro-lint: lock discipline, JAX hygiene, Pallas "
                    "contracts, doc citations (DESIGN.md §14)")
    ap.add_argument("--root", default=".", help="repo root")
    ap.add_argument("--select", default=None, metavar="PREFIX",
                    help="only codes starting with PREFIX (LCK/JAX/PLC/DOC "
                         "or a full code like LCK001)")
    ap.add_argument("--files", nargs="*", default=None,
                    help="override target files (repo-relative)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline path (default {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline with current findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for fam, rule in all_rules():
            print(f"{','.join(rule.codes):24s} {rule.name}")
        return 0

    root = os.path.abspath(args.root)
    baseline_path = os.path.join(
        root, args.baseline or DEFAULT_BASELINE)

    findings, _ = run_lint(root, select=args.select, files=args.files)

    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"lint: baseline updated with {len(findings)} finding(s)")
        return 0

    if args.no_baseline:
        fresh = findings
    else:
        fresh = new_findings(findings, load_baseline(baseline_path))

    for f in fresh:
        print(f.render())
    n_base = len(findings) - len(fresh)
    if fresh:
        print(f"lint: {len(fresh)} new finding(s)"
              + (f" ({n_base} baselined)" if n_base else ""))
        return 1
    print("lint: clean"
          + (f" ({n_base} baselined finding(s) tolerated)" if n_base else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
