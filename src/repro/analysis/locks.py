"""Lock-discipline rules (LCK family, DESIGN.md §14).

The serving stack's shared mutable state (the verification worklist, the
async pipeline's inbox/counters, the device slab cache) is protected by
per-object locks, and the protection is *declared in the source*: a field
assignment carrying a ``# guarded_by: self._lock`` comment makes the
invariant checkable.  Rules:

* **LCK001** — a read/write of a ``guarded_by``-annotated field outside a
  ``with`` block on the declared lock.  ``__init__`` is exempt (no other
  thread can hold a reference yet).  A helper method whose *caller* holds
  the lock declares it with the same comment on its ``def`` line.
  Nested functions/lambdas reset the held-lock set: a closure created
  under a lock usually runs after it was released.
* **LCK002** — ``Condition.wait()`` outside a ``while`` predicate loop
  (wakeups are spurious and racy by contract; an ``if`` is not enough).
* **LCK003** — a class that starts ``threading.Thread`` workers but has
  no ``join()`` path anywhere (no ``close()``/``wait()``-style shutdown
  method), i.e. a structural thread leak.
* **LCK004** — lock-order inversion: the directed graph of "acquired B
  while holding A" edges (nested ``with`` blocks, plus calls made while
  holding a lock into scanned methods that themselves take a lock) has a
  cycle.  Edges are reported at their acquisition/call sites.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import FileCtx, Finding, Rule, dotted_name

GUARDED_RE = re.compile(r"#\s*guarded_by:\s*(self\.[A-Za-z_]\w*)")

# attribute-call names too generic to resolve to a scanned class's method
# when building cross-class lock-order edges (dict/list/queue/threading
# vocabulary would otherwise alias container calls onto scanned methods)
_GENERIC_METHODS = frozenset({
    "get", "put", "pop", "popitem", "setdefault", "move_to_end", "append",
    "appendleft", "popleft", "extend", "clear", "update", "copy", "items",
    "keys", "values", "wait", "notify", "notify_all", "acquire", "release",
    "join", "start", "is_alive", "set", "is_set", "result", "add",
    "remove", "discard", "get_nowait", "put_nowait", "sort", "index",
    "count", "submit", "cancel",
})

_THREAD_CTORS = ("Thread",)
_CONDITION_CTORS = ("Condition",)


def _comment_annotation(ctx: FileCtx, lo: int, hi: int) -> Optional[str]:
    """First ``# guarded_by:`` lock expression on source lines lo..hi."""
    for ln in range(lo, hi + 1):
        m = GUARDED_RE.search(ctx.line_text(ln))
        if m:
            return m.group(1)
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for a ``self.x`` attribute node."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class ClassModel:
    """Everything the lock rules need to know about one class."""

    def __init__(self, ctx: FileCtx, node: ast.ClassDef):
        self.ctx = ctx
        self.node = node
        self.name = node.name
        self.methods: List[ast.FunctionDef] = [
            n for n in node.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        self.guarded: Dict[str, str] = {}       # field -> "self._lock"
        self.held_by_method: Dict[str, str] = {}  # method -> lock expr
        self.condition_attrs: Set[str] = set()  # threading.Condition fields
        self._collect()

    def _collect(self) -> None:
        for meth in self.methods:
            held = _comment_annotation(
                self.ctx, meth.lineno,
                meth.body[0].lineno - 1 if meth.body else meth.lineno)
            if held:
                self.held_by_method[meth.name] = held
            for stmt in ast.walk(meth):
                if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    continue
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                attrs = [a for a in map(_self_attr, targets) if a]
                if not attrs:
                    continue
                ann = _comment_annotation(
                    self.ctx, stmt.lineno,
                    getattr(stmt, "end_lineno", stmt.lineno))
                value = getattr(stmt, "value", None)
                for attr in attrs:
                    if ann and attr not in self.guarded:
                        self.guarded[attr] = ann
                    if (isinstance(value, ast.Call)
                            and _ctor_match(value, _CONDITION_CTORS)):
                        self.condition_attrs.add(attr)


def _ctor_match(call: ast.Call, names: Tuple[str, ...]) -> bool:
    d = dotted_name(call.func)
    return bool(d) and d.rsplit(".", 1)[-1] in names


def _with_locks(stmt: ast.With) -> Set[str]:
    """Lock expressions acquired by one ``with`` statement (self.* only)."""
    out = set()
    for item in stmt.items:
        expr = item.context_expr
        # unwrap `with self._lock:` and e.g. `with self._cv` alike; also
        # accept `self._lock.acquire()`-style context managers
        d = dotted_name(expr)
        if d and d.startswith("self."):
            out.add(d)
    return out


class GuardedFieldRule(Rule):
    """LCK001: annotated fields only under their declared lock."""

    codes = ("LCK001",)
    name = "guarded-field"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                model = ClassModel(ctx, node)
                if model.guarded:
                    yield from self._check_class(ctx, model)

    def _check_class(self, ctx: FileCtx,
                     model: ClassModel) -> Iterable[Finding]:
        for meth in model.methods:
            if meth.name == "__init__":
                continue
            held: Set[str] = set()
            if meth.name in model.held_by_method:
                held = {model.held_by_method[meth.name]}
            yield from self._walk(ctx, model, list(meth.body), held)

    def _walk(self, ctx: FileCtx, model: ClassModel,
              body: List[ast.stmt], held: Set[str]) -> Iterable[Finding]:
        for stmt in body:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                got = _with_locks(stmt)
                for item in stmt.items:
                    yield from self._scan_expr(ctx, model,
                                               item.context_expr, held)
                yield from self._walk(ctx, model, stmt.body, held | got)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a nested def may run on another thread after the lock
                # is gone: reset the held set (unless annotated)
                inner = _comment_annotation(
                    ctx, stmt.lineno,
                    stmt.body[0].lineno - 1 if stmt.body else stmt.lineno)
                yield from self._walk(ctx, model, stmt.body,
                                      {inner} if inner else set())
            else:
                for field, value in ast.iter_fields(stmt):
                    vals = value if isinstance(value, list) else [value]
                    for v in vals:
                        if isinstance(v, ast.stmt):
                            yield from self._walk(ctx, model, [v], held)
                        elif isinstance(v, ast.expr):
                            yield from self._scan_expr(ctx, model, v, held)

    def _scan_expr(self, ctx: FileCtx, model: ClassModel, expr: ast.expr,
                   held: Set[str]) -> Iterable[Finding]:
        for node in ast.walk(expr):
            if isinstance(node, ast.Lambda):
                continue    # closure body: conservatively checked as a
                            # lock-free context would over-flag captured
                            # reads that callers lock; walk() resets defs
            attr = _self_attr(node)
            if attr and attr in model.guarded:
                lock = model.guarded[attr]
                if lock not in held:
                    yield ctx.finding(
                        node, "LCK001",
                        f"{model.name}.{attr} is guarded_by {lock} but "
                        f"accessed without holding it")


class ConditionWaitRule(Rule):
    """LCK002: Condition.wait() must sit inside a while predicate loop."""

    codes = ("LCK002",)
    name = "condition-wait"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                model = ClassModel(ctx, node)
                if model.condition_attrs:
                    yield from self._check_class(ctx, model)

    def _check_class(self, ctx: FileCtx,
                     model: ClassModel) -> Iterable[Finding]:
        for meth in model.methods:
            yield from self._walk(meth.body, ctx, model, in_while=False)

    def _walk(self, body: List[ast.stmt], ctx: FileCtx, model: ClassModel,
              in_while: bool) -> Iterable[Finding]:
        for stmt in body:
            inner = in_while or isinstance(stmt, ast.While)
            for node in ast.iter_child_nodes(stmt):
                if isinstance(node, ast.stmt):
                    continue
                yield from self._scan_expr(node, ctx, model, inner)
            for field, value in ast.iter_fields(stmt):
                vals = value if isinstance(value, list) else [value]
                stmts = [v for v in vals if isinstance(v, ast.stmt)]
                if stmts:
                    yield from self._walk(stmts, ctx, model, inner)

    def _scan_expr(self, expr: ast.AST, ctx: FileCtx, model: ClassModel,
                   in_while: bool) -> Iterable[Finding]:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if (isinstance(func, ast.Attribute) and func.attr == "wait"
                    and _self_attr(func.value) in model.condition_attrs
                    and not in_while):
                yield ctx.finding(
                    node, "LCK002",
                    f"{model.name}: Condition {dotted_name(func.value)}"
                    f".wait() outside a while predicate loop (spurious "
                    f"wakeups make an if-guard racy)")


class ThreadLeakRule(Rule):
    """LCK003: classes that start threads need a join path."""

    codes = ("LCK003",)
    name = "thread-leak"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            first_ctor: Optional[ast.Call] = None
            has_join = False
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    if _ctor_match(sub, _THREAD_CTORS) and first_ctor is None:
                        first_ctor = sub
                    if (isinstance(sub.func, ast.Attribute)
                            and sub.func.attr == "join"):
                        has_join = True
            if first_ctor is not None and not has_join:
                yield ctx.finding(
                    first_ctor, "LCK003",
                    f"{node.name} starts threads but defines no "
                    f"join()/close() shutdown path")


class LockOrderRule(Rule):
    """LCK004: cycle detection over the acquired-while-holding graph."""

    codes = ("LCK004",)
    name = "lock-order"

    def run_project(self, ctxs: Sequence[FileCtx],
                    root: str) -> Iterable[Finding]:
        models: List[ClassModel] = []
        for ctx in ctxs:
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.ClassDef):
                    models.append(ClassModel(ctx, node))

        # pass 1: per (class, method), the locks it acquires directly
        acquires: Dict[str, List[Tuple[str, Set[str]]]] = {}
        for model in models:
            for meth in model.methods:
                locks = set()
                for sub in ast.walk(meth):
                    if isinstance(sub, (ast.With, ast.AsyncWith)):
                        locks |= {self._qual(model, l)
                                  for l in _with_locks(sub)}
                if locks:
                    acquires.setdefault(meth.name, []).append(
                        (model.name, locks))

        # method names that resolve unambiguously to one scanned class
        resolvable = {name: infos[0][1]
                      for name, infos in acquires.items()
                      if len(infos) == 1 and name not in _GENERIC_METHODS}

        # pass 2: edges (held -> acquired) with their sites
        edges: Dict[Tuple[str, str], Tuple[FileCtx, int]] = {}
        for model in models:
            for meth in model.methods:
                self._edges(model, list(meth.body), set(),
                            resolvable, edges)

        graph: Dict[str, Set[str]] = {}
        for (a, b) in edges:
            graph.setdefault(a, set()).add(b)
        bad = [(a, b) for (a, b) in edges if self._reaches(graph, b, a)]
        for (a, b) in sorted(bad):
            ctx, line = edges[(a, b)]
            yield ctx.finding(
                line, "LCK004",
                f"lock-order inversion: acquires {b} while holding {a}, "
                f"but {b} -> {a} is also taken elsewhere")

    def _qual(self, model: ClassModel, lock_expr: str) -> str:
        return f"{model.name}.{lock_expr[len('self.'):]}"

    def _edges(self, model: ClassModel, body: List[ast.stmt],
               held: Set[str], resolvable: Dict[str, Set[str]],
               edges: Dict) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                got = {self._qual(model, l) for l in _with_locks(stmt)}
                for g in got:
                    for h in held:
                        if h != g:
                            edges.setdefault(
                                (h, g), (model.ctx, stmt.lineno))
                self._edges(model, stmt.body, held | got, resolvable, edges)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._edges(model, stmt.body, set(), resolvable, edges)
            else:
                if held:
                    for node in ast.walk(stmt):
                        if not isinstance(node, ast.Call):
                            continue
                        func = node.func
                        if not isinstance(func, ast.Attribute):
                            continue
                        target_locks = resolvable.get(func.attr)
                        if not target_locks:
                            continue
                        for g in target_locks:
                            for h in held:
                                if h != g:
                                    edges.setdefault(
                                        (h, g), (model.ctx, node.lineno))
                for field, value in ast.iter_fields(stmt):
                    vals = value if isinstance(value, list) else [value]
                    stmts = [v for v in vals if isinstance(v, ast.stmt)]
                    if stmts:
                        self._edges(model, stmts, held, resolvable, edges)

    def _reaches(self, graph: Dict[str, Set[str]], src: str,
                 dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(graph.get(n, ()))
        return False


RULES = (GuardedFieldRule, ConditionWaitRule, ThreadLeakRule, LockOrderRule)
