"""JAX retrace/host-sync hygiene rules (JAX family, DESIGN.md §14).

Scope: functions *reachable from a jit/shard_map entry point* in a
module.  Roots are

* functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``,
* functions passed to ``jax.jit(...)`` / ``shard_map(...)`` /
  ``pl.pallas_call(...)`` (as a bare name, lambda, or
  ``functools.partial(fn, ...)``),

and reachability closes over same-module calls/references from there.

Inside that closure the rules reason about *taint*: which local names
hold traced values.  A root's parameters are tainted except for
``static_argnames`` entries and kwargs bound by ``functools.partial``;
taint flows through assignments and same-module calls (per-argument,
via a worklist).  Shape arithmetic is the big sanitizer: ``x.shape`` /
``.ndim`` / ``.dtype`` / ``.size``, ``len()``/``range()`` and
``pl.num_programs``/``pl.program_id`` results, and ``is``/``is not``
comparisons are host values, never traced.

* **JAX101** — Python ``if``/``while`` (or ``for`` over) a traced value:
  inside jit these either crash (ConcretizationTypeError) or silently
  specialize, and on CPU-interpret paths they hide retraces.
* **JAX102** — host syncs on traced values: ``.item()``,
  ``np.asarray``/``np.array``, ``bool()``/``int()``/``float()``.
* **JAX103** — ``static_argnames`` naming a parameter the wrapped
  function doesn't have, or a static parameter with a mutable default
  (unhashable -> TypeError on first call).
* **JAX104** — constructing a jitted callable (``jax.jit``,
  ``functools.partial(jax.jit, ...)``, ``shard_map``) inside a
  ``for``/``while`` body: every construction is a fresh cache entry, the
  retrace hazard the shape-bucket ladder exists to kill.

The taint pass is a single forward walk per function body (no fixpoint
for loops) — deliberately cheap, tuned to this repo's code shape.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import FileCtx, Finding, Rule, dotted_name, last_name

_JIT_NAMES = frozenset({"jit"})
_ROOT_WRAPPERS = frozenset({"jit", "shard_map", "pallas_call", "vmap",
                            "pmap", "grad", "value_and_grad"})
_SANITIZER_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})
_SANITIZER_CALLS = frozenset({"len", "range", "enumerate", "num_programs",
                              "program_id", "isinstance", "hasattr",
                              "getattr", "zip", "min", "max", "tuple",
                              "list", "sorted"})
_HOST_SYNC_CASTS = frozenset({"bool", "int", "float"})
_NP_SYNC = frozenset({"np.asarray", "np.array", "numpy.asarray",
                      "numpy.array", "onp.asarray", "onp.array"})


def _is_partial(call: ast.Call) -> bool:
    return last_name(call.func) == "partial"


def _static_argnames(call: ast.Call) -> Optional[Set[str]]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            if kw.arg == "static_argnums":
                return None  # positional statics: handled as unknown
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                out = set()
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and isinstance(
                            elt.value, str):
                        out.add(elt.value)
                return out
    return set()


class _Root:
    """One jit/shard_map entry point: target function + static info."""

    def __init__(self, fn: ast.AST, statics: Set[str],
                 bound_kwargs: Set[str], site: ast.AST):
        self.fn = fn                     # FunctionDef | Lambda
        self.statics = statics
        self.bound_kwargs = bound_kwargs
        self.site = site


class ModuleModel:
    """Module-level defs, jit roots, and the reachable-call closure."""

    def __init__(self, ctx: FileCtx):
        self.ctx = ctx
        self.defs: Dict[str, ast.FunctionDef] = {}
        for node in ctx.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[node.name] = node
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        self.defs.setdefault(f"{node.name}.{sub.name}", sub)
        # kwargs bound by any functools.partial(fn, kw=...) in the module
        # (kernels pass config this way; those params are never traced)
        self.partial_bound: Dict[str, Set[str]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and _is_partial(node) \
                    and node.args and isinstance(node.args[0], ast.Name):
                self.partial_bound.setdefault(
                    node.args[0].id, set()).update(
                        kw.arg for kw in node.keywords if kw.arg)
        self.roots: List[_Root] = []
        self._find_roots()
        self.reachable: Set[ast.AST] = set()
        self._close()

    # -- root discovery -----------------------------------------------------
    def _find_roots(self) -> None:
        for name, fn in self.defs.items():
            for dec in getattr(fn, "decorator_list", ()):
                statics = set()
                hit = False
                if last_name(dec) in _JIT_NAMES:
                    hit = True
                elif isinstance(dec, ast.Call):
                    if last_name(dec.func) in _JIT_NAMES:
                        hit = True
                        statics = _static_argnames(dec) or set()
                    elif _is_partial(dec) and dec.args and last_name(
                            dec.args[0]) in _JIT_NAMES:
                        hit = True
                        statics = _static_argnames(dec) or set()
                if hit:
                    self.roots.append(_Root(fn, statics, set(), dec))
        for node in ast.walk(self.ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if last_name(node.func) not in _ROOT_WRAPPERS:
                continue
            statics = _static_argnames(node) or set()
            for arg in node.args[:1] + [kw.value for kw in node.keywords
                                        if kw.arg in ("f", "fun", "kernel")]:
                self._add_root_target(arg, statics, node)

    def _add_root_target(self, arg: ast.AST, statics: Set[str],
                         site: ast.AST) -> None:
        if isinstance(arg, ast.Lambda):
            self.roots.append(_Root(arg, statics, set(), site))
        elif isinstance(arg, ast.Name) and arg.id in self.defs:
            self.roots.append(_Root(self.defs[arg.id], statics, set(), site))
        elif isinstance(arg, ast.Call) and _is_partial(arg) and arg.args:
            inner = arg.args[0]
            bound = {kw.arg for kw in arg.keywords if kw.arg}
            if isinstance(inner, ast.Name) and inner.id in self.defs:
                self.roots.append(
                    _Root(self.defs[inner.id], statics, bound, site))
            elif isinstance(inner, ast.Lambda):
                self.roots.append(_Root(inner, statics, bound, site))

    # -- reachability closure ------------------------------------------------
    def _close(self) -> None:
        work = [r.fn for r in self.roots]
        by_short = {}
        for name, fn in self.defs.items():
            by_short.setdefault(name.rsplit(".", 1)[-1], fn)
        while work:
            fn = work.pop()
            if fn in self.reachable:
                continue
            self.reachable.add(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Name):
                    target = self.defs.get(node.id) or by_short.get(node.id)
                    if target is not None and target not in self.reachable:
                        work.append(target)


def _params(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class _TaintPass:
    """Per-function taint of local names; emits JAX101/JAX102."""

    def __init__(self, ctx: FileCtx, model: ModuleModel):
        self.ctx = ctx
        self.model = model
        self.findings: List[Finding] = []
        # fn -> per-param taint (True = traced); refined by the worklist
        self.param_taint: Dict[ast.AST, List[bool]] = {}
        # return-taint machinery (per-element for tuple returns)
        self._ret_memo: Dict[Tuple, Tuple[bool, Optional[List[bool]]]] = {}
        self._ret_stack: Set[ast.AST] = set()
        self._sink: Optional[List] = None

    def run(self) -> List[Finding]:
        # seed: roots taint all params except statics/partial-bound kwargs
        work: List[ast.AST] = []
        for root in self.model.roots:
            names = _params(root.fn)
            skip = root.statics | root.bound_kwargs
            taint = [n not in skip for n in names]
            if self._merge(root.fn, taint):
                work.append(root.fn)
        # non-root reachable fns referenced (not directly called) get
        # all-params-tainted conservatively once we see such a reference;
        # directly-called fns get per-arg taint from call sites below.
        called_directly: Set[ast.AST] = set()
        for fn in self.model.reachable:
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and isinstance(
                        node.func, ast.Name):
                    t = self.model.defs.get(node.func.id)
                    if t is not None:
                        called_directly.add(t)
        for fn in self.model.reachable:
            if fn in self.param_taint or fn in called_directly:
                continue
            names = _params(fn)
            kwonly = {p.arg for p in fn.args.kwonlyargs}
            bound = self.model.partial_bound.get(
                getattr(fn, "name", ""), set())
            taint = [not (n in kwonly and n in bound) for n in names]
            if self._merge(fn, taint):
                work.append(fn)

        # worklist: propagate per-arg taint through direct calls
        seen_rounds = 0
        while work and seen_rounds < 1000:
            seen_rounds += 1
            fn = work.pop()
            env = dict(zip(_params(fn), self.param_taint[fn]))
            for callee, taints in self._flow(fn, env, emit=False):
                if self._merge(callee, taints):
                    work.append(callee)

        # final pass: emit findings with converged param taint
        for fn in self.model.reachable:
            taint = self.param_taint.get(fn, [False] * len(_params(fn)))
            env = dict(zip(_params(fn), taint))
            list(self._flow(fn, env, emit=True))
        return self.findings

    def _merge(self, fn: ast.AST, taint: List[bool]) -> bool:
        cur = self.param_taint.get(fn)
        if cur is None:
            self.param_taint[fn] = list(taint)
            return True
        changed = False
        for i, t in enumerate(taint):
            if i < len(cur) and t and not cur[i]:
                cur[i] = True
                changed = True
        return changed

    # -- expression taint ---------------------------------------------------
    def _tainted(self, node: ast.AST, env: Dict[str, bool]) -> bool:
        if isinstance(node, ast.Name):
            return env.get(node.id, False)
        if isinstance(node, ast.Attribute):
            if node.attr in _SANITIZER_ATTRS:
                return False
            return self._tainted(node.value, env)
        if isinstance(node, ast.Subscript):
            return self._tainted(node.value, env)
        if isinstance(node, ast.Call):
            fname = last_name(node.func)
            if fname in _SANITIZER_CALLS:
                return False
            if isinstance(node.func, ast.Attribute):
                if node.func.attr in ("astype", "reshape", "sum", "min",
                                      "max", "dot", "transpose", "at"):
                    return self._tainted(node.func.value, env)
            # same-module calls: use the callee's return-taint summary
            # (e.g. shape-arithmetic helpers return host ints even when
            # fed traced arrays)
            if isinstance(node.func, ast.Name):
                callee = self.model.defs.get(node.func.id)
                if callee is not None:
                    scalar, _ = self._result_taint(callee, node, env)
                    return scalar
            # jnp/lax/pl calls over tainted args stay tainted; calls over
            # clean args produce traced values too when they're jnp ctors,
            # but flagging `if jnp.zeros(...)` style is out of scope
            return any(self._tainted(a, env) for a in node.args) or any(
                self._tainted(kw.value, env) for kw in node.keywords)
        if isinstance(node, ast.BinOp):
            return (self._tainted(node.left, env)
                    or self._tainted(node.right, env))
        if isinstance(node, ast.UnaryOp):
            return self._tainted(node.operand, env)
        if isinstance(node, ast.BoolOp):
            return any(self._tainted(v, env) for v in node.values)
        if isinstance(node, ast.Compare):
            ops = node.ops
            if all(isinstance(o, (ast.Is, ast.IsNot)) for o in ops):
                return False  # identity tests never trace
            return self._tainted(node.left, env) or any(
                self._tainted(c, env) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._tainted(e, env) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self._tainted(node.body, env)
                    or self._tainted(node.orelse, env))
        if isinstance(node, ast.Starred):
            return self._tainted(node.value, env)
        return False

    def _result_taint(self, callee: ast.AST, call: ast.Call,
                      env: Dict[str, bool]
                      ) -> Tuple[bool, Optional[List[bool]]]:
        """(scalar, per-tuple-element) return taint of a same-module call
        with this call site's argument taints.  Conservative (True, None)
        on recursion or depth blowup."""
        names = _params(callee)
        taints = [False] * len(names)
        for i, a in enumerate(call.args):
            if i < len(taints):
                taints[i] = self._tainted(a, env)
        for kw in call.keywords:
            if kw.arg in names:
                taints[names.index(kw.arg)] = self._tainted(kw.value, env)
        key = (callee, tuple(taints))
        if key in self._ret_memo:
            return self._ret_memo[key]
        if callee in self._ret_stack or len(self._ret_stack) > 4:
            return (True, None)
        self._ret_stack.add(callee)
        try:
            if isinstance(callee, ast.Lambda):
                inner = dict(zip(names, taints))
                result = (self._tainted(callee.body, inner), None)
            else:
                sink: List = []
                prev, self._sink = self._sink, sink
                try:
                    inner = dict(zip(names, taints))
                    for _ in self._stmts(callee.body, inner, emit=False):
                        pass
                finally:
                    self._sink = prev
                scalar, elems = False, None
                saw_tuple = saw_other = False
                for val, renv in sink:
                    if val is None:
                        continue
                    if isinstance(val, ast.Tuple):
                        et = [self._tainted(e, renv) for e in val.elts]
                        scalar = scalar or any(et)
                        if not saw_tuple:
                            elems = et
                        elif elems is not None and len(elems) == len(et):
                            elems = [a or b for a, b in zip(elems, et)]
                        else:
                            elems = None
                        saw_tuple = True
                    else:
                        scalar = scalar or self._tainted(val, renv)
                        saw_other = True
                result = (scalar, None if saw_other else elems)
        finally:
            self._ret_stack.discard(callee)
        self._ret_memo[key] = result
        return result

    # -- statement walk -----------------------------------------------------
    def _flow(self, fn: ast.AST, env: Dict[str, bool],
              emit: bool) -> Iterable[Tuple[ast.AST, List[bool]]]:
        body = fn.body if isinstance(body_attr := getattr(fn, "body", None),
                                     list) else [body_attr]
        yield from self._stmts(body, env, emit)

    def _stmts(self, body: List[ast.AST], env: Dict[str, bool],
               emit: bool) -> Iterable[Tuple[ast.AST, List[bool]]]:
        for stmt in body:
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = getattr(stmt, "value", None)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                elems = None
                if (len(targets) == 1
                        and isinstance(targets[0], (ast.Tuple, ast.List))
                        and isinstance(value, ast.Call)
                        and isinstance(value.func, ast.Name)
                        and value.func.id in self.model.defs):
                    # tuple unpack of a same-module call: per-element taint
                    # (e.g. `x, pad = _pad_axis(q, ...)` — pad is host int)
                    _, elems = self._result_taint(
                        self.model.defs[value.func.id], value, env)
                if elems is not None and len(elems) == len(targets[0].elts):
                    for e, et in zip(targets[0].elts, elems):
                        self._bind(e, et, env)
                else:
                    t = (self._tainted(value, env)
                         if value is not None else False)
                    for tgt in targets:
                        self._bind(tgt, t, env)
                if value is not None:
                    yield from self._calls(value, env, emit)
            elif isinstance(stmt, ast.If):
                if emit and self._tainted(stmt.test, env):
                    self._emit(stmt.test, "JAX101",
                               "Python `if` on a traced value inside a "
                               "jit-reachable function (concretization "
                               "error or silent specialization)")
                yield from self._calls(stmt.test, env, emit)
                yield from self._stmts(stmt.body, env, emit)
                yield from self._stmts(stmt.orelse, env, emit)
            elif isinstance(stmt, ast.While):
                if emit and self._tainted(stmt.test, env):
                    self._emit(stmt.test, "JAX101",
                               "Python `while` on a traced value inside a "
                               "jit-reachable function (use lax.while_loop)")
                yield from self._calls(stmt.test, env, emit)
                yield from self._stmts(stmt.body, env, emit)
            elif isinstance(stmt, ast.For):
                if emit and self._tainted(stmt.iter, env):
                    self._emit(stmt.iter, "JAX101",
                               "Python `for` over a traced value inside a "
                               "jit-reachable function (use lax.fori_loop "
                               "or lax.scan)")
                yield from self._calls(stmt.iter, env, emit)
                self._bind(stmt.target, False, env)  # range-style iteration
                yield from self._stmts(stmt.body, env, emit)
                yield from self._stmts(stmt.orelse, env, emit)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner_env = dict(env)
                for p in _params(stmt):
                    inner_env.setdefault(p, False)
                yield from self._stmts(stmt.body, inner_env, emit)
            elif isinstance(stmt, ast.Return):
                if self._sink is not None:
                    self._sink.append((stmt.value, dict(env)))
                if stmt.value is not None:
                    yield from self._calls(stmt.value, env, emit)
            elif isinstance(stmt, ast.Expr):
                yield from self._calls(stmt.value, env, emit)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    yield from self._calls(item.context_expr, env, emit)
                yield from self._stmts(stmt.body, env, emit)
            elif isinstance(stmt, ast.Try):
                yield from self._stmts(stmt.body, env, emit)
                for h in stmt.handlers:
                    yield from self._stmts(h.body, env, emit)
                yield from self._stmts(stmt.orelse, env, emit)
                yield from self._stmts(stmt.finalbody, env, emit)
            elif isinstance(stmt, (ast.Raise, ast.Assert)):
                for v in ast.iter_child_nodes(stmt):
                    if isinstance(v, ast.expr):
                        yield from self._calls(v, env, emit)

    def _bind(self, target: ast.AST, tainted: bool,
              env: Dict[str, bool]) -> None:
        if isinstance(target, ast.Name):
            env[target.id] = tainted
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, tainted, env)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted, env)

    def _calls(self, expr: ast.AST, env: Dict[str, bool],
               emit: bool) -> Iterable[Tuple[ast.AST, List[bool]]]:
        """Host-sync detection + per-arg taint propagation to callees."""
        for node in ast.walk(expr):
            if isinstance(node, ast.Lambda):
                continue
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func) or ""
            short = fname.rsplit(".", 1)[-1]
            # JAX102: host syncs on traced values
            if emit:
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"
                        and self._tainted(node.func.value, env)):
                    self._emit(node, "JAX102",
                               ".item() on a traced value inside a "
                               "jit-reachable function (host sync)")
                elif fname in _NP_SYNC and node.args and self._tainted(
                        node.args[0], env):
                    self._emit(node, "JAX102",
                               f"{fname}() on a traced value inside a "
                               f"jit-reachable function (device->host "
                               f"transfer)")
                elif (isinstance(node.func, ast.Name)
                      and node.func.id in _HOST_SYNC_CASTS
                      and node.args and self._tainted(node.args[0], env)):
                    self._emit(node, "JAX102",
                               f"{node.func.id}() on a traced value inside "
                               f"a jit-reachable function (implicit "
                               f"concretization)")
            # per-arg propagation to same-module direct calls
            if isinstance(node.func, ast.Name):
                callee = self.model.defs.get(node.func.id)
                if callee is not None and callee in self.model.reachable:
                    names = _params(callee)
                    taints = [False] * len(names)
                    for i, a in enumerate(node.args):
                        if i < len(taints):
                            taints[i] = self._tainted(a, env)
                    for kw in node.keywords:
                        if kw.arg in names:
                            taints[names.index(kw.arg)] = self._tainted(
                                kw.value, env)
                    yield (callee, taints)

    def _emit(self, node: ast.AST, code: str, msg: str) -> None:
        self.findings.append(self.ctx.finding(node, code, msg))


class JaxTracerRule(Rule):
    """JAX101 + JAX102 via the reachability/taint pass."""

    codes = ("JAX101", "JAX102")
    name = "jax-tracer"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        model = ModuleModel(ctx)
        if not model.roots:
            return
        yield from _TaintPass(ctx, model).run()


class JaxStaticArgsRule(Rule):
    """JAX103: static_argnames must name real params; mutable defaults
    on static params are unhashable at call time."""

    codes = ("JAX103",)
    name = "jax-static-args"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        model = ModuleModel(ctx)
        for root in model.roots:
            if not isinstance(root.fn, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                continue
            names = set(_params(root.fn))
            for s in sorted(root.statics):
                if s not in names:
                    yield ctx.finding(
                        root.site, "JAX103",
                        f"static_argnames entry '{s}' does not match any "
                        f"parameter of {root.fn.name}()")
            a = root.fn.args
            pos = a.posonlyargs + a.args
            defaults = a.defaults
            offset = len(pos) - len(defaults)
            for i, d in enumerate(defaults):
                pname = pos[offset + i].arg
                if pname in root.statics and isinstance(
                        d, (ast.List, ast.Dict, ast.Set)):
                    yield ctx.finding(
                        d, "JAX103",
                        f"static parameter '{pname}' of {root.fn.name}() "
                        f"has a mutable (unhashable) default")
            for kw, d in zip(a.kwonlyargs, a.kw_defaults):
                if d is not None and kw.arg in root.statics and isinstance(
                        d, (ast.List, ast.Dict, ast.Set)):
                    yield ctx.finding(
                        d, "JAX103",
                        f"static parameter '{kw.arg}' of {root.fn.name}() "
                        f"has a mutable (unhashable) default")


class JitInLoopRule(Rule):
    """JAX104: jit/shard_map construction inside a loop body retraces."""

    codes = ("JAX104",)
    name = "jit-in-loop"

    _CTORS = frozenset({"jit", "shard_map", "pmap"})

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        yield from self._walk(ctx.tree.body, ctx, in_loop=False)

    def _walk(self, body: List[ast.AST], ctx: FileCtx,
              in_loop: bool) -> Iterable[Finding]:
        for stmt in body:
            inner = in_loop or isinstance(stmt, (ast.For, ast.While))
            if inner:
                for node in ast.walk(stmt) if isinstance(
                        stmt, (ast.For, ast.While)) else ():
                    if isinstance(node, ast.Call):
                        hit = last_name(node.func) in self._CTORS
                        if (not hit and _is_partial(node) and node.args
                                and last_name(node.args[0]) in self._CTORS):
                            hit = True
                        if hit:
                            yield ctx.finding(
                                node, "JAX104",
                                f"jitted callable constructed inside a "
                                f"loop body (fresh trace cache entry per "
                                f"iteration — hoist or use a shape bucket)")
                if isinstance(stmt, (ast.For, ast.While)):
                    continue  # already walked the whole subtree
            for field, value in ast.iter_fields(stmt):
                vals = value if isinstance(value, list) else [value]
                stmts = [v for v in vals if isinstance(v, ast.stmt)]
                if stmts:
                    yield from self._walk(stmts, ctx, inner)


RULES = (JaxTracerRule, JaxStaticArgsRule, JitInLoopRule)
