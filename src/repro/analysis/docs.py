"""DESIGN.md citation gate as a lint rule (DOC family, DESIGN.md §14).

Port of the old ``scripts/check_docs.py``: every ``DESIGN.md §N``
citation in source/docs must resolve to a real ``§N`` heading in
DESIGN.md, so design references can't silently dangle as the doc grows.

* **DOC400** — DESIGN.md missing or contains no ``§N`` headings.
* **DOC401** — a citation to a section DESIGN.md doesn't define.

This is a project rule (it scans text, not ASTs, and includes files the
Python-rule walker never loads: markdown, shell, configs).
"""
from __future__ import annotations

import os
import re
from typing import Iterable, List, Sequence, Set, Tuple

from repro.analysis.core import FileCtx, Finding, Rule

CITE_RE = re.compile(r"DESIGN\.md\s*§(\d+)")
HEADING_RE = re.compile(r"^#{1,3}\s*§(\d+)\b")

SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "scripts")
SCAN_EXTS = (".py", ".md", ".sh", ".txt", ".toml", ".cfg", ".yml", ".yaml")
# files that *define or discuss* the citation syntax itself
SKIP_NAMES = {"check_docs.py", "docs.py"}
SKIP_DIR_PARTS = {"fixtures", "__pycache__"}


def collect_headings(design_path: str) -> Set[str]:
    if not os.path.exists(design_path):
        return set()
    out = set()
    with open(design_path, encoding="utf-8") as f:
        for line in f:
            m = HEADING_RE.match(line)
            if m:
                out.add(m.group(1))
    return out


def iter_files(root: str) -> Iterable[str]:
    yield os.path.join(root, "README.md")
    yield os.path.join(root, "ROADMAP.md")
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x not in SKIP_DIR_PARTS]
            for name in sorted(filenames):
                if name in SKIP_NAMES:
                    continue
                if os.path.splitext(name)[1] in SCAN_EXTS:
                    yield os.path.join(dirpath, name)


def check_citations(root: str) -> List[Tuple[str, int, str, str]]:
    """(relpath, line, code, message) tuples — shared with the legacy
    check_docs entry point."""
    design = os.path.join(root, "DESIGN.md")
    headings = collect_headings(design)
    out: List[Tuple[str, int, str, str]] = []
    if not headings:
        out.append(("DESIGN.md", 1, "DOC400",
                    "DESIGN.md is missing or defines no §N headings"))
        return out
    for path in iter_files(root):
        if not os.path.exists(path):
            continue
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                lines = f.readlines()
        except (OSError, UnicodeDecodeError):
            continue
        for i, line in enumerate(lines, 1):
            for m in CITE_RE.finditer(line):
                if m.group(1) not in headings:
                    out.append((rel, i, "DOC401",
                                f"dangling citation DESIGN.md §{m.group(1)} "
                                f"(no such heading in DESIGN.md)"))
    return out


class DocCitationRule(Rule):
    codes = ("DOC400", "DOC401")
    name = "doc-citations"

    def run_project(self, ctxs: Sequence[FileCtx],
                    root: str) -> Iterable[Finding]:
        for rel, line, code, msg in check_citations(root):
            yield Finding(path=rel, line=line, code=code, message=msg)


RULES = (DocCitationRule,)
