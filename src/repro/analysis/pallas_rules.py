"""Pallas kernel-contract rules (PLC family, DESIGN.md §14).

Every ``pl.pallas_call(...)`` site in ``kernels/`` makes promises the
Python type system can't see: the kernel's positional refs must line up
one-to-one with ``in_specs`` + outputs + ``scratch_shapes``; each
BlockSpec index map must take exactly one argument per grid axis; SMEM
blocks hold scalars and may only be read with scalar indices; and what
the kernel stores must match the ``out_shape`` dtype.  Drift in any of
these surfaces as an opaque Mosaic/XLA error (or worse, silent
miscompilation under ``interpret=True``) far from the edit that caused
it.  These rules re-check the contract on every lint run:

* **PLC301** — kernel arity mismatch: positional params != len(in_specs)
  + n_outputs + len(scratch_shapes).  Kwonly params bound by
  ``functools.partial`` are excluded; an *unbound* kwonly param without a
  default is its own finding.  Also: out_specs/out_shape count mismatch.
* **PLC302** — a BlockSpec ``index_map`` lambda whose non-default arity
  differs from the grid rank.
* **PLC303** — an SMEM-spec'd kernel ref subscripted with a slice or
  ``...`` (SMEM is scalar-access only on TPU).
* **PLC304** — the kernel stores ``.astype(jnp.X)`` into an output ref
  whose ``ShapeDtypeStruct`` declares ``jnp.Y``.

Resolution is best-effort and local: specs/grids given as literals or as
module/function-local ``name = (...)`` assignments resolve; anything
dynamic is skipped rather than guessed.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.core import FileCtx, Finding, Rule, dotted_name, last_name


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


class _Resolver:
    """Resolve Name references to literal assignments (module scope plus
    the local scope enclosing the pallas_call)."""

    def __init__(self, ctx: FileCtx, site: ast.AST):
        self.env: Dict[str, ast.AST] = {}
        scopes: List[ast.AST] = [ctx.tree]
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(node):
                    if sub is site:
                        scopes.append(node)
                        break
        for scope in scopes:
            for stmt in ast.walk(scope):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt = stmt.targets[0]
                    if isinstance(tgt, ast.Name):
                        self.env[tgt.id] = stmt.value

    def resolve(self, node: Optional[ast.AST],
                depth: int = 0) -> Optional[ast.AST]:
        while isinstance(node, ast.Name) and depth < 8:
            nxt = self.env.get(node.id)
            if nxt is None or nxt is node:
                return node
            node = nxt
            depth += 1
        return node


def _as_elements(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    """Elements of a tuple/list literal; a single non-sequence literal is
    a 1-element spec; None if unresolvable."""
    if node is None:
        return None
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return [node]


def _kernel_fn(ctx: FileCtx, arg: ast.AST,
               resolver: _Resolver) -> Tuple[Optional[ast.AST], set]:
    """(FunctionDef|Lambda, kwargs bound via functools.partial)."""
    arg = resolver.resolve(arg)
    bound: set = set()
    if isinstance(arg, ast.Call) and last_name(arg.func) == "partial":
        bound = {kw.arg for kw in arg.keywords if kw.arg}
        if arg.args:
            arg = resolver.resolve(arg.args[0])
    if isinstance(arg, ast.Lambda):
        return arg, bound
    if isinstance(arg, ast.Name):
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == arg.id:
                return node, bound
    if isinstance(arg, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return arg, bound
    return None, bound


def _dtype_of(node: Optional[ast.AST]) -> Optional[str]:
    """'int32' from jnp.int32 / 'int32' literals; None if dynamic."""
    if node is None:
        return None
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Call) and last_name(node.func) == "dtype":
        return _dtype_of(node.args[0]) if node.args else None
    return None


def _is_smem(spec: ast.AST) -> bool:
    for node in ast.walk(spec):
        d = dotted_name(node)
        if d and d.rsplit(".", 1)[-1] == "SMEM":
            return True
    return False


class PallasContractRule(Rule):
    codes = ("PLC301", "PLC302", "PLC303", "PLC304")
    name = "pallas-contract"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and last_name(node.func) == "pallas_call":
                yield from self._check_site(ctx, node)

    def _check_site(self, ctx: FileCtx,
                    call: ast.Call) -> Iterable[Finding]:
        resolver = _Resolver(ctx, call)
        kernel_arg = call.args[0] if call.args else _kw(call, "kernel")
        if kernel_arg is None:
            return
        kernel, bound_kwargs = _kernel_fn(ctx, kernel_arg, resolver)

        in_specs = _as_elements(resolver.resolve(_kw(call, "in_specs")))
        out_specs = _as_elements(resolver.resolve(_kw(call, "out_specs")))
        out_shape = _as_elements(resolver.resolve(_kw(call, "out_shape")))
        scratch = _as_elements(resolver.resolve(_kw(call, "scratch_shapes")))
        grid = resolver.resolve(_kw(call, "grid"))
        grid_rank = (len(grid.elts) if isinstance(grid, (ast.Tuple, ast.List))
                     else (1 if isinstance(grid, ast.Constant) else None))

        n_in = len(in_specs) if in_specs is not None else None
        n_out = len(out_shape) if out_shape is not None else (
            len(out_specs) if out_specs is not None else None)
        n_scratch = len(scratch) if scratch is not None else 0

        # PLC301: arity
        if kernel is not None and n_in is not None and n_out is not None:
            a = kernel.args
            pos = len(a.posonlyargs) + len(a.args)
            unbound_kwonly = [
                kw.arg for kw, d in zip(a.kwonlyargs, a.kw_defaults)
                if kw.arg not in bound_kwargs and d is None]
            expected = n_in + n_out + n_scratch
            if pos != expected:
                kname = getattr(kernel, "name", "<lambda>")
                yield ctx.finding(
                    call, "PLC301",
                    f"kernel {kname} takes {pos} positional refs but "
                    f"pallas_call provides {expected} "
                    f"({n_in} in_specs + {n_out} outputs + "
                    f"{n_scratch} scratch)")
            for kwname in unbound_kwonly:
                kname = getattr(kernel, "name", "<lambda>")
                yield ctx.finding(
                    call, "PLC301",
                    f"kernel {kname} keyword-only param '{kwname}' is "
                    f"neither partial-bound nor defaulted")
        if (out_specs is not None and out_shape is not None
                and len(out_specs) != len(out_shape)):
            yield ctx.finding(
                call, "PLC301",
                f"out_specs has {len(out_specs)} entries but out_shape "
                f"declares {len(out_shape)} outputs")

        # PLC302: index-map arity vs grid rank
        if grid_rank is not None:
            specs = (in_specs or []) + (out_specs or [])
            for spec in specs:
                spec = resolver.resolve(spec)
                if not isinstance(spec, ast.Call):
                    continue
                imap = (spec.args[1] if len(spec.args) > 1
                        else _kw(spec, "index_map"))
                imap = resolver.resolve(imap)
                if isinstance(imap, ast.Lambda):
                    la = imap.args
                    required = (len(la.posonlyargs) + len(la.args)
                                - len(la.defaults))
                    if required != grid_rank:
                        yield ctx.finding(
                            spec, "PLC302",
                            f"BlockSpec index_map takes {required} grid "
                            f"indices but the grid has rank {grid_rank}")

        # PLC303: SMEM refs only scalar-indexed
        if kernel is not None and in_specs is not None \
                and isinstance(kernel, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
            a = kernel.args
            pos_params = [p.arg for p in a.posonlyargs + a.args]
            for i, spec in enumerate(in_specs):
                spec_r = resolver.resolve(spec)
                if spec_r is None or not _is_smem(spec_r):
                    continue
                if i >= len(pos_params):
                    continue
                ref = pos_params[i]
                for sub in ast.walk(kernel):
                    if (isinstance(sub, ast.Subscript)
                            and isinstance(sub.value, ast.Name)
                            and sub.value.id == ref
                            and self._nonscalar_index(sub.slice)):
                        yield ctx.finding(
                            sub, "PLC303",
                            f"SMEM ref '{ref}' read with a non-scalar "
                            f"index (SMEM is scalar-access only)")

        # PLC304: stored dtype vs out_shape dtype
        if kernel is not None and out_shape is not None \
                and isinstance(kernel, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
            a = kernel.args
            pos_params = [p.arg for p in a.posonlyargs + a.args]
            n_in_eff = n_in if n_in is not None else 0
            for j, shape_decl in enumerate(out_shape):
                shape_decl = resolver.resolve(shape_decl)
                declared = None
                if isinstance(shape_decl, ast.Call) and last_name(
                        shape_decl.func) == "ShapeDtypeStruct":
                    dnode = (shape_decl.args[1] if len(shape_decl.args) > 1
                             else _kw(shape_decl, "dtype"))
                    declared = _dtype_of(resolver.resolve(dnode))
                if declared is None:
                    continue
                idx = n_in_eff + j
                if idx >= len(pos_params):
                    continue
                ref = pos_params[idx]
                for sub in ast.walk(kernel):
                    if not (isinstance(sub, ast.Assign)
                            and len(sub.targets) == 1
                            and isinstance(sub.targets[0], ast.Subscript)
                            and isinstance(sub.targets[0].value, ast.Name)
                            and sub.targets[0].value.id == ref):
                        continue
                    v = sub.value
                    if isinstance(v, ast.Call) and isinstance(
                            v.func, ast.Attribute) \
                            and v.func.attr == "astype":
                        stored = _dtype_of(v.args[0] if v.args
                                           else _kw(v, "dtype"))
                        if stored is not None and stored != declared:
                            yield ctx.finding(
                                sub, "PLC304",
                                f"kernel stores .astype({stored}) into "
                                f"'{ref}' but out_shape declares {declared}")

    def _nonscalar_index(self, idx: ast.AST) -> bool:
        if isinstance(idx, (ast.Slice,)):
            return True
        if isinstance(idx, ast.Constant) and idx.value is Ellipsis:
            return True
        if isinstance(idx, ast.Tuple):
            return any(self._nonscalar_index(e) for e in idx.elts)
        return False


RULES = (PallasContractRule,)
