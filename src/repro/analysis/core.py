"""repro-lint core: findings, per-file context, suppressions, baseline.

The analysis framework (DESIGN.md §14) is pure stdlib ``ast`` — it must
run before any heavy dependency imports (CI runs it as the first gate),
so nothing in ``repro.analysis`` may import numpy/jax.

Concepts:

* **Finding** — one diagnosed violation: a short code (``LCK001``), the
  repo-relative path, line, and message.  The *fingerprint* (code, path,
  message — deliberately no line number, so unrelated edits above a
  baselined finding don't resurrect it) is what the baseline stores.
* **FileCtx** — parsed source + comment-derived metadata: inline
  ``# lint: disable=CODE[,CODE...]`` suppressions and the raw line text
  rules need for their own annotations (``# guarded_by: self._lock``).
* **Rule** — pluggable check.  Per-file rules implement ``run(ctx)``;
  cross-file rules (lock-order inversion, doc citations) override
  ``run_project(ctxs, root)``.
* **Baseline** — a checked-in JSON list of fingerprints.  ``make lint``
  fails only on findings *not* covered by the baseline, so pre-existing
  debt is frozen (it can't silently grow) while new violations always
  block.  The shipped baseline is empty: every real finding the three
  rule families surfaced was fixed in the PR that introduced them.
"""
from __future__ import annotations

import ast
import json
import re
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_*,\s]+)")


@dataclass(frozen=True, order=True)
class Finding:
    """One diagnosed violation at a source location."""

    path: str          # repo-relative, forward slashes
    line: int
    code: str
    message: str

    def fingerprint(self) -> Tuple[str, str, str]:
        """Baseline identity: line numbers drift with unrelated edits,
        so they are deliberately not part of it."""
        return (self.code, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


class FileCtx:
    """One parsed source file plus its comment-level metadata."""

    def __init__(self, abspath: str, rel: str, source: str):
        self.abspath = abspath
        self.rel = rel.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self._suppressions: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, 1):
            m = SUPPRESS_RE.search(line)
            if m:
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                self._suppressions[i] = codes

    @classmethod
    def load(cls, abspath: str, rel: str) -> "FileCtx":
        with open(abspath, encoding="utf-8") as f:
            return cls(abspath, rel, f.read())

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppressed(self, lineno: int, code: str) -> bool:
        codes = self._suppressions.get(lineno)
        return bool(codes) and ("*" in codes or code in codes)

    def finding(self, node_or_line, code: str, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Finding(path=self.rel, line=line, code=code, message=message)


class Rule:
    """A pluggable check.  ``codes`` lists every finding code the rule
    can emit (used for ``--select`` and the docs)."""

    codes: Tuple[str, ...] = ()
    name: str = "rule"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        return ()

    def run_project(self, ctxs: Sequence[FileCtx],
                    root: str) -> Iterable[Finding]:
        """Cross-file rules override this; the default just loops."""
        for ctx in ctxs:
            yield from self.run(ctx)


def filter_suppressed(findings: Iterable[Finding],
                      ctxs: Dict[str, FileCtx]) -> List[Finding]:
    """Drop findings whose source line carries a matching
    ``# lint: disable=`` comment.  Findings on files without a loaded
    ctx (cross-file rules scanning extra files) are checked lazily."""
    out = []
    for f in findings:
        ctx = ctxs.get(f.path)
        if ctx is not None and ctx.suppressed(f.line, f.code):
            continue
        out.append(f)
    return sorted(out)


# ---- baseline -------------------------------------------------------------

def load_baseline(path: str) -> Counter:
    """Fingerprint multiset from the checked-in baseline file (missing
    file = empty baseline)."""
    try:
        with open(path, encoding="utf-8") as f:
            entries = json.load(f)
    except FileNotFoundError:
        return Counter()
    return Counter((e["code"], e["path"], e["message"]) for e in entries)


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = [{"code": f.code, "path": f.path, "message": f.message}
               for f in sorted(findings)]
    with open(path, "w", encoding="utf-8") as f:
        json.dump(entries, f, indent=1)
        f.write("\n")


def new_findings(findings: Sequence[Finding],
                 baseline: Counter) -> List[Finding]:
    """Findings not covered by the baseline.  Multiplicity-aware: a
    baselined fingerprint tolerates as many occurrences as were
    baselined — the N+1th is new and blocks."""
    budget = Counter(baseline)
    out = []
    for f in sorted(findings):
        if budget[f.fingerprint()] > 0:
            budget[f.fingerprint()] -= 1
        else:
            out.append(f)
    return out


# ---- shared AST helpers ---------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def last_name(node: ast.AST) -> Optional[str]:
    """Trailing attribute ('jit' for jax.jit), or the bare name."""
    d = dotted_name(node)
    return d.rsplit(".", 1)[-1] if d else None
