"""Serving fault-containment rules (SRV family, DESIGN.md §18).

The serving stack's failure-domain invariant is that every exception
resolves to a *typed outcome* on the owning ticket — a result, a
partial, or a ``QueryError`` — never a silent swallow that leaves a
future hanging.  Containment handlers are therefore only legitimate
when their body visibly propagates the failure: re-raising, failing the
owning ticket/job, or counting it into the error accounting.  Rules:

* **SRV001** — a bare ``except:`` or broad ``except Exception /
  BaseException`` in ``src/repro/serve/`` whose handler neither
  re-raises, references the bound exception, fails/resolves/finishes a
  ticket, nor records the failure into error/fallback accounting.  Such
  a handler swallows faults invisibly — the exact anti-pattern the
  chaos suite exists to catch.  Deliberate last-resort guards (e.g. a
  user callback that raised *after* its ticket resolved) carry an
  inline ``# lint: disable=SRV001`` with a justification comment.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.core import FileCtx, Finding, Rule, dotted_name

_BROAD = ("Exception", "BaseException")

# handler-body evidence that the failure is propagated, not swallowed:
# a called name containing one of these retires/fails the owning ticket
_PROPAGATING_CALLS = ("finish", "resolve", "fail", "record", "abort",
                      "retire", "reject", "log", "warn")
# ...or an assignment target containing one of these feeds the error
# accounting the stats/chaos assertions read
_ACCOUNTING_NAMES = ("error", "unverified", "fallback", "fail", "shed",
                     "reject", "skip", "drop")


def _is_broad(expr: Optional[ast.expr]) -> bool:
    if expr is None:                       # bare except:
        return True
    if isinstance(expr, ast.Tuple):
        return any(_is_broad(e) for e in expr.elts)
    name = dotted_name(expr)
    return name is not None and name.split(".")[-1] in _BROAD


def _target_text(node: ast.expr) -> str:
    """Lowercased identifier soup of an assignment target — attribute
    names, subscript string keys, plain names."""
    parts = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            parts.append(n.id)
        elif isinstance(n, ast.Attribute):
            parts.append(n.attr)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            parts.append(n.value)
    return " ".join(parts).lower()


def _propagates(handler: ast.ExceptHandler) -> bool:
    bound = handler.name
    for stmt in handler.body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Raise):
                return True
            if bound and isinstance(node, ast.Name) and node.id == bound:
                return True                # error object is used somewhere
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                leaf = (name or "").split(".")[-1].lower()
                if any(k in leaf for k in _PROPAGATING_CALLS):
                    return True
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets
                           if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    text = _target_text(t)
                    if any(k in text for k in _ACCOUNTING_NAMES):
                        return True
    return False


class SwallowedExceptRule(Rule):
    """SRV001: broad except that swallows the failure silently."""

    codes = ("SRV001",)
    name = "serve-swallowed-except"

    def run(self, ctx: FileCtx) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node.type):
                continue
            if _propagates(node):
                continue
            yield ctx.finding(
                node, "SRV001",
                "broad except swallows the failure: re-raise, fail the "
                "owning ticket, or count it into error accounting "
                "(DESIGN.md §18)")


RULES = (SwallowedExceptRule,)
