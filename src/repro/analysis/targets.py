"""Which files each rule family scans (DESIGN.md §14).

Rules are domain-specific, so they run where their domain lives rather
than blanket-scanning the tree:

* LCK — the threaded serving/training/data surface.
* JAX — the jit/shard_map modules (plus kernel op wrappers).
* PLC — every module under ``kernels/``.
* DOC — project-wide text scan (handled inside the rule itself).
* SRV — fault containment, every module under ``serve/``.

``extra_roots`` lets tests point the runner at fixture trees instead.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Tuple

LOCK_FILES = (
    "src/repro/serve/graph_engine.py",
    "src/repro/serve/pipeline.py",
    "src/repro/core/device_cache.py",
    "src/repro/train/checkpoint.py",
    "src/repro/data/pipeline.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/spans.py",
)

JAX_FILES = (
    "src/repro/core/engine.py",
    "src/repro/core/distributed.py",
)

KERNEL_DIR = "src/repro/kernels"


def _glob_py(root: str, subdir: str) -> List[str]:
    base = os.path.join(root, subdir)
    out = []
    if os.path.isdir(base):
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    out.append(os.path.relpath(
                        os.path.join(dirpath, name), root))
    return [p.replace(os.sep, "/") for p in out]


def targets_for(root: str) -> Dict[str, List[str]]:
    """family -> repo-relative paths (existing files only)."""
    kernels = _glob_py(root, KERNEL_DIR)
    fam = {
        "LCK": [p for p in LOCK_FILES
                if os.path.exists(os.path.join(root, p))],
        "JAX": [p for p in JAX_FILES
                if os.path.exists(os.path.join(root, p))] + kernels,
        "PLC": kernels,
        "DOC": [],  # the doc rule walks the tree itself
        "SRV": _glob_py(root, "src/repro/serve"),
    }
    return fam
