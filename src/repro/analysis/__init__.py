"""repro-lint: domain-specific static analysis (DESIGN.md §14).

Pure stdlib ``ast`` — importing this package must never pull in
numpy/jax, so the lint gate can run before the heavy deps in CI.

Rule families:
  LCK  lock discipline (guarded_by annotations, Condition.wait loops,
       thread lifecycle, lock-order inversion)
  JAX  jit/shard_map hygiene (tracer branches, host syncs, static args,
       jit-in-loop retraces)
  PLC  Pallas kernel contracts (arity, index-map/grid rank, SMEM scalar
       access, out_shape dtypes)
  DOC  DESIGN.md citation gate
"""
from repro.analysis.core import (FileCtx, Finding, Rule, filter_suppressed,
                                 load_baseline, new_findings, write_baseline)

__all__ = ["FileCtx", "Finding", "Rule", "filter_suppressed",
           "load_baseline", "new_findings", "write_baseline"]
