"""Gradient compression for cross-pod data parallelism.

At 2+ pods the DP all-reduce crosses the inter-pod links (the slowest hop),
so we provide the standard toolkit:

* top-k sparsification with error feedback (memory = one residual tree) —
  provably convergent SGD-style compression; the all-reduce payload drops
  from |g| to 2k (values + indices).
* int8 linear quantization (per-tensor scale) — 4x payload reduction, used
  for the pod-axis psum in train_step when enabled.

Both are pure-jnp and composable with shard_map (see train.make_train_step's
``compress_pod_axis`` option).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # error-feedback accumulator, same tree as grads


def init_compression_state(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))


def topk_compress(g: jax.Array, k_frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the top k_frac fraction (by magnitude); returns (values, idx)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * k_frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return jnp.take(flat, idx), idx


def topk_decompress(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return flat.at[idx].set(values).reshape(shape)


def compressed_gradient(g: jax.Array, residual: jax.Array, k_frac: float
                        ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback top-k: returns (sparse-but-dense gradient to reduce,
    new residual).  The dense representation keeps the collective a plain
    psum (payload reduction is realised by the int8/sparse wire format on
    real hardware; here we model the semantics + measure the error)."""
    acc = g.astype(jnp.float32) + residual
    vals, idx = topk_compress(acc, k_frac)
    dense = topk_decompress(vals, idx, acc.shape)
    return dense, acc - dense


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
