"""Optimizers: AdamW and Adafactor (factored states for the 1T MoE).

Hand-rolled (no optax dependency) as functional (init, update) pairs over
arbitrary param pytrees.  States live in the same sharding as the params
(the launch layer shards them identically), so optimizer memory scales
down with model parallelism.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32):
    """Returns (init, update).  update(grads, state, params) -> (new_params,
    new_state)."""

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"m": jax.tree.map(zeros, params),
                               "v": jax.tree.map(zeros, params)})

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * g * g)
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * upd).astype(p.dtype),
                    m.astype(state_dtype), v.astype(state_dtype))

        flat_out = jax.tree.map(upd, grads, state.inner["m"],
                                state.inner["v"], params)
        new_params = jax.tree.map(lambda o: o[0], flat_out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], flat_out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], flat_out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, inner={"m": new_m, "v": new_v})

    return init, update


# --------------------------------------------------------------------------
# Adafactor (factored second moment; the 1T-param MoE default)
# --------------------------------------------------------------------------

def adafactor(lr: Callable, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              state_dtype=jnp.float32):
    """Factored Adafactor: matrices store row+col second-moment factors
    (O(n+m) memory instead of O(nm)); vectors store full v."""

    def init(params) -> OptState:
        def one(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], state_dtype),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], state_dtype)}
            return {"v": jnp.zeros(p.shape, state_dtype)}
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(one, params))

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                r = beta * s["r"].astype(jnp.float32) + (1 - beta) * g2.mean(-1)
                c = beta * s["c"].astype(jnp.float32) + (1 - beta) * g2.mean(-2)
                denom = (r[..., None] * c[..., None, :]
                         / jnp.maximum(r.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"r": r.astype(state_dtype), "c": c.astype(state_dtype)}
            else:
                v = beta * s["v"].astype(jnp.float32) + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v.astype(state_dtype)}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            newp = (p.astype(jnp.float32) * (1 - lr_t * weight_decay)
                    - lr_t * u)
            return newp.astype(p.dtype), new_s

        is_state_leaf = lambda x: isinstance(x, dict) and ("r" in x or "v" in x)
        out = jax.tree.map(upd, grads, state.inner, params,
                           is_leaf=lambda x: is_state_leaf(x))
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_inner = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, inner=new_inner)

    return init, update
