from repro.optim.optimizers import (adamw, adafactor, OptState,
                                    cosine_schedule, global_norm, clip_by_global_norm)
from repro.optim.compression import (topk_compress, topk_decompress,
                                     int8_quantize, int8_dequantize,
                                     CompressionState, compressed_gradient)

__all__ = [
    "adamw", "adafactor", "OptState", "cosine_schedule", "global_norm",
    "clip_by_global_norm", "topk_compress", "topk_decompress",
    "int8_quantize", "int8_dequantize", "CompressionState",
    "compressed_gradient",
]
