"""Expert-parallel MoE execution context (shard_map inside pjit).

GSPMD handles every dense layer well, but MoE dispatch must be explicit:
``EPParallel.moe`` wraps ``blocks.moe_apply_ep`` in a shard_map whose
in_specs mirror the parameter shardings, gathers any FSDP-sharded
(non-expert-axis) dims locally, and runs fixed-capacity all_to_all expert
dispatch over the 'model' axis.  Threaded through the model as the ``par``
argument.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import jax_compat as jc
from repro.models import blocks as B
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class EPParallel:
    """Parallel execution context threaded through the model as ``par``.

    Besides the MoE shard_map, it carries optional activation-sharding
    hints used by the §Perf hillclimbs:
      * attn_seq_shard — context parallelism: q is sharded over 'model'
        along the query-sequence dim inside self-attention (archs whose
        head count does not divide the model axis, e.g. yi-34b's 56 heads,
        otherwise replicate their attention work 16x);
      * act_seq_shard — the scan-carry activations (the remat stash) are
        sharded over 'model' along sequence, trading one gather per layer
        for a 16x smaller checkpoint footprint (enables fewer microbatches
        on the 1T MoE).
    """

    mesh: Mesh
    dp_axes: Tuple[str, ...]
    rules: Dict[str, Any]
    ep_axis: str = "model"
    attn_seq_shard: bool = False
    act_seq_shard: bool = False

    def _spec(self, axes: Tuple[Optional[str], ...]) -> P:
        from repro.launch.shardings import spec_from_axes
        return spec_from_axes(axes, self.rules)

    def constrain(self, x, spec: P):
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def shard_attn_q(self, q):
        """(B, H, S, D) -> S sharded over 'model' (context parallelism)."""
        if not self.attn_seq_shard or q.shape[2] < 2:
            return q
        return self.constrain(q, P(self.dp_axes, None, "model", None))

    def shard_attn_kv(self, k, v):
        """Split the k/v projections by sequence too (they are gathered
        back for the attention itself, but the matmuls stop replicating)."""
        if not self.attn_seq_shard or k.shape[2] < 2:
            return k, v
        spec = P(self.dp_axes, None, "model", None)
        return self.constrain(k, spec), self.constrain(v, spec)

    def shard_attn_out(self, o):
        if not self.attn_seq_shard or o.shape[2] < 2:
            return o
        return self.constrain(o, P(self.dp_axes, None, "model", None))

    def shard_act(self, x):
        """(B, S, d) scan carry -> S sharded over 'model'."""
        if not self.act_seq_shard or x.shape[1] < 2:
            return x
        return self.constrain(x, P(self.dp_axes, "model", None))

    def moe(self, params, x, cfg: ModelConfig) -> jax.Array:
        assert cfg.shared_experts == 0, "EP path: shared experts unsupported"
        from repro.models.blocks import moe_spec
        from repro.models.layers import logical_axes
        axes = logical_axes(moe_spec(cfg))
        p_specs = jax.tree.map(self._spec, axes,
                               is_leaf=lambda t: isinstance(t, tuple))
        # when the unit activations are sequence-sharded over the EP axis
        # (act_seq_shard), hand the MoE body its local token slice directly
        # — no entry re-gather, no exit all_gather (§Perf-B7)
        pre_sharded = self.act_seq_shard
        if pre_sharded:
            x_spec = P(self.dp_axes, self.ep_axis, None)
        else:
            x_spec = P(self.dp_axes, None, None)
        ep = self.ep_axis

        def body(prm, xl):
            # gather FSDP/non-EP dims so each device holds its full local
            # experts; the EP (expert) dim stays sharded.
            def gather(arr, spec):
                for dim, ax in enumerate(tuple(spec)):
                    if ax is None:
                        continue
                    axes_t = ax if isinstance(ax, tuple) else (ax,)
                    for a in axes_t:
                        if a != ep:
                            arr = jax.lax.all_gather(arr, a, axis=dim,
                                                     tiled=True)
                return arr

            gathered = jax.tree.map(gather, prm, p_specs,
                                    is_leaf=lambda t: isinstance(t, P))
            # router must see all experts
            rspec = tuple(p_specs["router"])
            if len(rspec) > 1 and rspec[1] == ep:
                gathered["router"] = jax.lax.all_gather(
                    gathered["router"], ep, axis=1, tiled=True)
            return B.moe_apply_ep(gathered, xl, cfg, ep,
                                  pre_sharded=pre_sharded)

        fn = jc.shard_map(body, mesh=self.mesh, in_specs=(p_specs, x_spec),
                          out_specs=x_spec)
        return fn(params, x)


def make_parallel(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any],
                  attn_seq_shard: bool = False,
                  act_seq_shard: bool = False) -> Optional[EPParallel]:
    """Build the parallel ctx (None when neither MoE expert-parallelism nor
    an activation-sharding flag needs it)."""
    if "model" not in mesh.axis_names:
        return None
    if cfg.n_experts == 0 and not (attn_seq_shard or act_seq_shard):
        return None
    from repro.launch.mesh import dp_axes_of
    return EPParallel(mesh=mesh, dp_axes=dp_axes_of(mesh), rules=rules,
                      attn_seq_shard=attn_seq_shard,
                      act_seq_shard=act_seq_shard)
