import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * compile success of the WHOLE jitted step (scan-over-layers) with full
    production shardings — the deliverable (e);
  * memory_analysis() — bytes per device (args/temp/peak: proves it fits);
  * cost_analysis() raw (XLA counts scan bodies ONCE — kept for reference);
  * SEGMENT-accurate roofline terms: the scanned unit (fwd and fwd+bwd),
    embed and head segments are compiled separately under the same
    shardings; totals = n_units * unit + segments.  This sidesteps the
    while-loop undercount exactly (DESIGN.md §7);
  * collective bytes parsed from each compiled segment's HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
    scaled by trip counts, converted to seconds with the bidirectional-ring
    model on the v5e constants.

NOTE: XLA_FLAGS is set above, before any jax import, because jax locks the
device count on first init.  Do not import this module from test code.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_costs
from repro.launch.ep import make_parallel
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, dp_axes_of,
                               make_production_mesh)
from repro.launch.shapes import SHAPES, cell_supported, decode_specs, token_specs
from repro.launch.shardings import (opt_state_shardings, param_shardings,
                                    rules_for, spec_from_axes)
from repro.models.config import ModelConfig
from repro.models.layers import shapes_of
from repro.models.transformer import model_spec
from repro.optim.optimizers import adafactor, adamw, cosine_schedule
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# --------------------------------------------------------------------------
# per-arch launch policy (microbatches, optimizer) — baseline values;
# hillclimb overrides live in artifacts/perf/*.json experiments.
# --------------------------------------------------------------------------

def launch_policy(cfg: ModelConfig) -> Dict[str, Any]:
    big = cfg.param_count()
    return {
        "optimizer": "adafactor" if big > 1e11 else "adamw",
        "microbatches": (8 if big > 1e11 else
                         4 if big > 2e10 else 1),
    }


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# sharding helpers for batches and caches
# --------------------------------------------------------------------------

def batch_shardings(cfg, mesh, specs):
    dp = dp_axes_of(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))

    def one(s):
        b = s.shape[0]
        lead = dp if b % dp_total == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(s.shape) - 1))))

    return jax.tree.map(one, specs)


def cache_shardings(cfg, mesh, cache_specs_tree, shard_seq_over_data=False):
    """batch dim -> dp when divisible; last dim -> model when divisible;
    optionally the KV seq dim -> data (long-context mode)."""
    dp = dp_axes_of(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def one(s):
        nd = len(s.shape)
        spec = [None] * nd
        # batch dim: first dim of size B for prefix leaves, second for
        # stacked-unit leaves — detect by rank convention (stacked leaves
        # gained a leading n_units dim)
        for cand in (0, 1):
            if cand < nd and s.shape[cand] % dp_total == 0 and \
                    s.shape[cand] >= dp_total:
                spec[cand] = dp
                bdim = cand
                break
        else:
            bdim = -1
        if nd >= 2 and s.shape[-1] % msize == 0:
            spec[-1] = "model"
        if shard_seq_over_data and nd >= 4 and bdim != 1:
            # KV cache (units, B, H, L, D): shard L over data when batch
            # could not use it (long_500k)
            ldim = nd - 2
            if s.shape[ldim] % dsize == 0 and spec[ldim] is None:
                spec[ldim] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_specs_tree)


# --------------------------------------------------------------------------
# cell lowering
# --------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               policy_overrides: Optional[Dict[str, Any]] = None,
               do_segments: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch).replace(attn_impl="xla")
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "status": "skipped", "reason": reason,
    }
    if not ok:
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(cfg, mesh, overrides=overrides)
    policy = launch_policy(cfg)
    if policy_overrides:
        policy.update(policy_overrides)
    if "capacity_factor" in policy:
        cfg = cfg.replace(capacity_factor=policy["capacity_factor"])
    if "remat_policy" in policy:
        cfg = cfg.replace(remat_policy=policy["remat_policy"])
    p_sh = param_shardings(cfg, mesh, overrides=overrides)
    p_shapes = shapes_of(model_spec(cfg), _dt(cfg))
    par = make_parallel(cfg, mesh, rules,
                        attn_seq_shard=policy.get("attn_seq_shard", False),
                        act_seq_shard=policy.get("act_seq_shard", False))

    t0 = time.time()
    seg = {}
    if shape.kind == "train":
        opt_kind = policy["optimizer"]
        lr = cosine_schedule(3e-4, 100, 10000)
        opt_init, opt_update = (adafactor(lr) if opt_kind == "adafactor"
                                else adamw(lr))
        o_shapes = jax.eval_shape(opt_init, p_shapes)
        o_sh = opt_state_shardings(opt_kind, cfg, mesh, p_sh)
        b_specs = token_specs(cfg, shape.global_batch, shape.seq_len)
        b_sh = batch_shardings(cfg, mesh, b_specs)
        step = make_train_step(cfg, opt_update, par=par,
                               microbatches=policy["microbatches"])
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None)
                          ).lower(p_shapes, o_shapes, b_specs)
        compiled = lowered.compile()
        result["optimizer"] = opt_kind
        result["microbatches"] = policy["microbatches"]
        if do_segments:
            seg = hlo_costs.train_segments(cfg, mesh, rules, p_sh, p_shapes,
                                           shape, par,
                                           microbatches=policy["microbatches"])
    elif shape.kind == "prefill":
        b_specs = token_specs(cfg, shape.global_batch, shape.seq_len)
        b_sh = batch_shardings(cfg, mesh, b_specs)
        stepfn = make_prefill_step(cfg, par=par)
        lowered = jax.jit(stepfn, in_shardings=(p_sh, b_sh)
                          ).lower(p_shapes, b_specs)
        compiled = lowered.compile()
        if do_segments:
            seg = hlo_costs.fwd_segments(cfg, mesh, rules, p_sh, p_shapes,
                                         shape, par, batch=shape.global_batch,
                                         seq=shape.seq_len)
    else:  # decode
        dspec = decode_specs(cfg, shape.global_batch, shape.seq_len)
        long_ctx = shape_name == "long_500k"
        c_sh = cache_shardings(cfg, mesh, dspec["cache"],
                               shard_seq_over_data=long_ctx)
        tok_sh = batch_shardings(cfg, mesh, {"t": dspec["token"]})["t"]
        stepfn = make_decode_step(cfg, par=par)
        args = [p_shapes, dspec["token"], dspec["cache"], dspec["pos"]]
        in_sh = [p_sh, tok_sh, c_sh, NamedSharding(mesh, P())]
        if cfg.is_encdec:
            args.append(dspec["enc_out"])
            in_sh.append(batch_shardings(cfg, mesh, {"e": dspec["enc_out"]})["e"])
        lowered = jax.jit(stepfn, in_shardings=tuple(in_sh),
                          out_shardings=(None, c_sh)).lower(*args)
        compiled = lowered.compile()
        if do_segments:
            seg = hlo_costs.decode_segments(cfg, mesh, rules, p_sh, p_shapes,
                                            shape, par, c_sh, dspec)

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = hlo_costs.collective_bytes(compiled.as_text(),
                                      loop_trip_count=cfg.n_units)

    result.update({
        "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "n_chips": n_chips,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops", -1.0),
            "bytes_accessed": ca.get("bytes accessed", -1.0),
            "note": "XLA counts scan bodies once; see segments for "
                    "trip-count-corrected totals",
        },
        "collectives_whole_graph": coll,
        "segments": seg,
        "model_params": cfg.param_count(),
        "model_params_active": cfg.active_param_count(),
        "rules": {str(k): str(v) for k, v in
                  rules_for(cfg, mesh, overrides=overrides).items()},
    })
    result.update(hlo_costs.roofline_terms(result, cfg, shape, n_chips, mesh))
    return result


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def artifact_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def run_cell(arch: str, shape: str, multi_pod: bool, force: bool = False,
             tag: str = "", **kw) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    path = artifact_path(arch, shape, mesh_name, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        res = lower_cell(arch, shape, multi_pod, **kw)
    except Exception as e:  # a failing cell is a bug — record it loudly
        res = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}"}
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-segments", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                res = run_cell(arch, shape, mp, force=args.force,
                               do_segments=not args.no_segments)
                status = res.get("status")
                extra = ""
                if status == "ok":
                    mem_gb = res["memory"]["argument_bytes"] / 2 ** 30
                    dom = res.get("roofline", {}).get("dominant", "?")
                    extra = f"args/dev={mem_gb:.2f}GiB dominant={dom}"
                elif status == "error":
                    extra = res.get("error", "")[:160]
                else:
                    extra = res.get("reason", "")[:80]
                print(f"[{time.time() - t0:7.1f}s] {arch:24s} {shape:12s} "
                      f"{'multi' if mp else 'single':6s} {status:8s} {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
