import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run for the paper's OWN workload: the distributed MSQ filter step at
PubChem-25M scale on the production meshes.

DB model (per DESIGN.md §5): 25M graphs, frequency-ordered degree-q-gram
vocabulary with a dense hot prefix of H columns (int8 counts — counts are
bounded by |V| <= 64... stored int8) + CSR tail handled on host.  Graphs
sharded over ('pod','data'); vocabulary over 'model' (TP); per-device top-k
candidate blocks all-gathered.

Cells: msq_pubchem25m x {filter_q1 (tau=1), filter_q5 (tau=5)} x mesh.
The tau doesn't change the lowered program (it's data), so the shape cell
is really the DB geometry; we keep one cell per mesh + dtype variant for
the §Perf hillclimb (int32 vs int8 vs bit-packed hot block).
"""
import argparse
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import filters_jax as fj
from repro.core.distributed import make_sharded_search
from repro.launch import hlo_costs
from repro.launch.dryrun import ARTIFACT_DIR, artifact_path
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

N_GRAPHS = 25_000_000
HOT = 4096          # dense hot-prefix columns (frequency-ordered vocab)
VMAX = 64
N_VLABELS = 101
N_ELABELS = 3
TOPK = 4096


def msq_cell(multi_pod: bool, fd_dtype: str = "int8",
             hot: int = HOT, topk: int = TOPK,
             kernel_adjust: bool = False,
             packed_bits: int = 0) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = int(np.prod(list(mesh.shape.values())))
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    dp_total = int(np.prod([mesh.shape[a] for a in batch_axes]))
    msize = mesh.shape["model"]
    B = N_GRAPHS - (N_GRAPHS % (dp_total))
    U = hot - (hot % msize)
    dt = {"int8": jnp.int8, "int32": jnp.int32}[fd_dtype]

    sds = jax.ShapeDtypeStruct
    db = fj.DBArrays(
        nv=sds((B,), jnp.int32), ne=sds((B,), jnp.int32),
        degseq=sds((B, VMAX), jnp.int8 if fd_dtype == "int8" else jnp.int32),
        vhist=sds((B, N_VLABELS), dt), ehist=sds((B, N_ELABELS), dt),
        fd=sds((B, U), dt),
        region_i=sds((B,), jnp.int32), region_j=sds((B,), jnp.int32))
    q = fj.QueryArrays(
        nv=sds((), jnp.int32), ne=sds((), jnp.int32),
        sigma=sds((VMAX,), jnp.int32), vhist=sds((N_VLABELS,), jnp.int32),
        ehist=sds((N_ELABELS,), jnp.int32), fd=sds((U,), jnp.int32),
        tau=sds((), jnp.int32))

    fn, in_sh, _ = make_sharded_search(
        mesh, x0=24, y0=26, l=4, k=topk, batch_axes=batch_axes,
        model_axis="model")
    t0 = time.time()
    lowered = jax.jit(fn).lower(
        jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                     db, in_sh[0]),
        jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                     q, in_sh[1]))
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = hlo_costs.collective_bytes(compiled.as_text(), loop_trip_count=1)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    itemsize_eff = {"int8": 1, "int32": 4}[fd_dtype]
    if packed_bits:
        itemsize_eff = packed_bits / 8.0
    if kernel_adjust:
        # the fused Pallas cascade (kernels/qgram_filter, validated in
        # interpret mode) reads each F_D tile from HBM once and keeps the
        # C_D accumulator + small per-graph arrays in VMEM; with
        # packed_bits the bitunpack kernel decodes in-register.  HBM
        # traffic = one pass over the operands:
        bytes_dev = (B / dp_total) * (U / msize) * itemsize_eff \
            + (B / dp_total) * (VMAX + N_VLABELS + N_ELABELS + 4 + 8)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll["total_ring_seconds"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    # useful work model (FIXED across variants so fractions compare): one
    # pass over the most succinct serving format we implement — the 4-bit
    # packed hot block (kernels/bitunpack) + per-graph int8 smalls:
    useful_bytes = (B / dp_total) * (U / msize) * 0.5 \
        + (B / dp_total) * (VMAX + N_VLABELS + N_ELABELS + 4)
    useful_s = useful_bytes / HBM_BW
    bound = max(terms.values())
    variant = f"filter_hot{hot}_{fd_dtype}"
    if packed_bits:
        variant += f"_packed{packed_bits}"
    if kernel_adjust:
        variant += "_kernel"
    return {
        "arch": "msq_pubchem25m", "shape": variant,
        "mesh": mesh_name, "multi_pod": multi_pod, "status": "ok",
        "compile_seconds": round(compile_s, 1), "n_chips": n_chips,
        "graphs": B, "hot_columns": U, "topk": topk, "fd_dtype": fd_dtype,
        "kernel_adjusted": kernel_adjust, "packed_bits": packed_bits,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
        },
        "segments": {"total_per_device": {
            "flops": flops_dev, "bytes": bytes_dev,
            "wire_bytes": coll["total_wire_bytes"],
            "ring_seconds": collective_s}},
        "collectives_whole_graph": coll,
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops": float(useful_bytes),  # byte-roofline workload
            "hlo_flops_cluster": flops_dev * n_chips,
            "useful_flops_ratio": float(useful_s / memory_s) if memory_s else 0,
            "roofline_fraction": float(useful_s / bound) if bound else 0.0,
            "step_time_lower_bound_s": float(bound),
            "note": "filter is memory-bound by design; useful = one pass "
                    "over the succinct DB shard",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--fd-dtype", default="int8", choices=["int8", "int32"])
    ap.add_argument("--hot", type=int, default=HOT)
    ap.add_argument("--topk", type=int, default=TOPK)
    ap.add_argument("--kernel-adjust", action="store_true")
    ap.add_argument("--packed-bits", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        shape = f"filter_hot{args.hot}_{args.fd_dtype}"
        if args.packed_bits:
            shape += f"_packed{args.packed_bits}"
        if args.kernel_adjust:
            shape += "_kernel"
        path = artifact_path("msq_pubchem25m", shape, mesh_name, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"cached: {path}")
            continue
        try:
            res = msq_cell(mp, args.fd_dtype, args.hot, args.topk,
                           kernel_adjust=args.kernel_adjust,
                           packed_bits=args.packed_bits)
        except Exception as e:
            res = {"arch": "msq_pubchem25m", "shape": shape,
                   "mesh": mesh_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        ro = res.get("roofline", {})
        print(f"{mesh_name} {shape}: {res['status']} "
              f"dominant={ro.get('dominant')} "
              f"mem/dev={res.get('memory', {}).get('argument_bytes', 0) / 2**30:.2f}GiB "
              f"bound={ro.get('step_time_lower_bound_s', 0):.4f}s "
              f"frac={ro.get('roofline_fraction', 0):.3f}")


if __name__ == "__main__":
    main()
