"""Production meshes.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state): single pod = (data=16, model=16) — 256 chips; multi-pod =
(pod=2, data=16, model=16) — 512 chips.  TPU v5e constants for the
roofline live here too.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from repro.core import jax_compat as jc

# ---- TPU v5e hardware constants (assignment-specified) ---------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (one direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jc.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jc.make_mesh(shape, axes)


def make_serving_mesh(model_parallel: int = 1, devices: Optional[int] = None):
    """Mesh over the host's visible devices for the sharded query engine
    (DESIGN.md §10): ('data', 'model') when the vocab-sharded layout needs
    a model axis, plain ('data',) otherwise."""
    n = devices if devices is not None else len(jax.devices())
    if model_parallel <= 1:
        return jc.make_mesh((n,), ("data",))
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by "
                         f"model_parallel={model_parallel}")
    return jc.make_mesh((n // model_parallel, model_parallel),
                        ("data", "model"))


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes for a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_of(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None
