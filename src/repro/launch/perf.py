import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: tagged dry-run variants of the three selected
cells (worst-fraction / most-collective-bound / paper-representative), each
implementing one hypothesis from EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.perf [--only A2,B1,...]
"""
import argparse
import json
from typing import Any, Dict, Optional

from repro.launch.dryrun import artifact_path, run_cell

# (id, arch, shape, tag, rules_overrides, policy_overrides, hypothesis)
EXPERIMENTS = [
    ("A2", "yi-34b", "prefill_32k", "ctxpar", None,
     {"attn_seq_shard": True},
     "56 heads don't divide model=16 so attention work replicates 16x; "
     "context-parallel q (seq over 'model') should cut the attention "
     "compute+score terms ~16x for one gather per layer"),
    ("A4", "yi-34b", "prefill_32k", "ctxpar-act", None,
     {"attn_seq_shard": True, "act_seq_shard": True},
     "remaining 5.2s compute: FFN/projection replication along 'model'; "
     "seq-sharding the unit activations should split all per-token matmuls"),
    ("A6", "yi-34b", "prefill_32k", "actonly", None,
     {"act_seq_shard": True},
     "attn q/k/v constraints are redundant once the unit activations are "
     "seq-sharded (propagation covers the projections); dropping them "
     "should remove duplicate re-gathers"),
    ("B1", "kimi-k2-1t-a32b", "train_4k", "cf10", None,
     {"capacity_factor": 1.0},
     "capacity factor 1.25->1.0 cuts A2A payload and EP einsum slots 20%"),
    ("B2", "kimi-k2-1t-a32b", "train_4k", "mb4-actshard", None,
     {"act_seq_shard": True, "microbatches": 4},
     "seq-sharding the remat stash over 'model' shrinks it 16x, letting "
     "microbatches drop 8->4; FSDP weight re-gathers (21s of the 59.6s "
     "collective term) halve"),
    ("B3", "kimi-k2-1t-a32b", "train_4k", "mb2-actshard", None,
     {"act_seq_shard": True, "microbatches": 2},
     "same, microbatches 8->2: weight re-gathers quarter; watch peak HBM"),
    ("B4", "kimi-k2-1t-a32b", "train_4k", "mb2-cf10", None,
     {"act_seq_shard": True, "microbatches": 2, "capacity_factor": 1.0},
     "compose B1+B3"),
    ("B6", "kimi-k2-1t-a32b", "train_4k", "mb1-cf10", None,
     {"act_seq_shard": True, "microbatches": 1, "capacity_factor": 1.0},
     "with the stash seq-sharded, microbatches=1 fits (est 11.6GiB): "
     "weight re-gathers drop another 2x"),
    ("B7", "kimi-k2-1t-a32b", "train_4k", "mb1-cf10-preshard", None,
     {"act_seq_shard": True, "microbatches": 1, "capacity_factor": 1.0},
     "act_seq_shard now hands the MoE its local token slice (in_spec "
     "P(dp,'model',None)): the entry re-gather and exit all_gather of y "
     "(~4x0.94GB/layer) disappear"),
    ("B8", "kimi-k2-1t-a32b", "train_4k", "mb1-cf10-preshard-dots", None,
     {"act_seq_shard": True, "microbatches": 1, "capacity_factor": 1.0,
      "remat_policy": "dots"},
     "memory now dominates (22.3s): checkpoint_dots keeps matmul outputs "
     "instead of recomputing the whole unit in bwd — the remat re-read of "
     "gathered expert weights (~6.3GB/layer) should drop to ~2/3"),
]


def summarize(rec: Dict[str, Any]) -> str:
    if rec.get("status") != "ok":
        return f"{rec.get('status')}: {rec.get('error', rec.get('reason', ''))[:120]}"
    ro = rec.get("roofline_kernel") or rec.get("roofline", {})
    mem = rec["memory"]
    return (f"bound={ro.get('step_time_lower_bound_s', 0):8.3f}s "
            f"dom={ro.get('dominant', '?'):12s} "
            f"[c={ro.get('compute_s', 0):7.3f} m={ro.get('memory_s', 0):7.3f} "
            f"x={ro.get('collective_s', 0):7.3f}] "
            f"frac={ro.get('roofline_fraction', 0):.4f} "
            f"peak={mem.get('peak_bytes', 0) / 2**30:.1f}GiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    for exp_id, arch, shape, tag, rules_ov, pol_ov, hyp in EXPERIMENTS:
        if only and exp_id not in only:
            continue
        base_path = artifact_path(arch, shape, "pod16x16")
        base = json.load(open(base_path)) if os.path.exists(base_path) else {}
        print(f"\n=== {exp_id} {arch} x {shape} [{tag}] ===")
        print(f"hypothesis: {hyp}")
        if base:
            print(f"baseline:  {summarize(base)}")
        rec = run_cell(arch, shape, multi_pod=False, force=args.force,
                       tag=tag, overrides=rules_ov, policy_overrides=pol_ov)
        print(f"variant:   {summarize(rec)}")


if __name__ == "__main__":
    main()
