"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs a real (small, CPU-friendly) training job end-to-end through the full
substrate: config -> reduced-or-full model -> mesh/shardings (when >1
device) -> optimizer -> fault-tolerant Trainer with async checkpoints.

On a real TPU cluster the same entry point runs with
``--no-reduce --mesh-shape 16,16`` under multi-process JAX.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-reduce", action="store_true",
                    help="use the full production config (TPU cluster)")
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps to fail once (FT demo)")
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.data import ShardedLoader, SyntheticLMDataset
    from repro.models import build_params
    from repro.optim import adamw, cosine_schedule
    from repro.train import (FailureInjector, Trainer, TrainerConfig,
                             make_train_step)

    cfg = get_config(args.arch)
    if not args.no_reduce:
        cfg = reduced(cfg)
    params = build_params(cfg, jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(cosine_schedule(args.lr, 10, args.steps))
    opt_state = opt_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_update,
                                      microbatches=args.microbatches))
    ds = SyntheticLMDataset(
        cfg.vocab_size, args.seq, args.batch,
        embed_dim=cfg.d_model if cfg.is_encdec else None)
    loader = ShardedLoader(ds)
    inject = None
    if args.inject_failures:
        inject = FailureInjector(int(s) for s in
                                 args.inject_failures.split(","))
    trainer = Trainer(
        step_fn, params, opt_state, loader,
        TrainerConfig(total_steps=args.steps,
                      checkpoint_dir=args.checkpoint_dir,
                      metrics_path=args.metrics),
        failure_injector=inject)
    out = trainer.run()
    first = out["metrics"][0]["loss"] if out["metrics"] else float("nan")
    last = out["metrics"][-1]["loss"] if out["metrics"] else float("nan")
    print(f"arch={args.arch} steps={out['final_step']} "
          f"restarts={out['restarts']} loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
