"""Logical-axis -> mesh-axis sharding rules (MaxText-style), per arch.

Rules are computed from the config so that every sharded dim divides its
mesh axis (GSPMD requirement):

  vocab   -> 'model' when divisible (vocab-parallel embedding/head)
  mlp     -> 'model' (column-parallel FFN / expert hidden)
  heads   -> 'model' when n_heads divides (head-parallel attention)
  kv_heads-> 'model' when divisible (else replicated KV: GQA kv=8 < 16)
  experts -> 'model' (expert parallelism)
  embed   -> 'data' when fsdp=True (FSDP parameter sharding; gathered at use)
  layers / head_dim / None -> replicated

Overridable per hillclimb experiment via the ``overrides`` argument.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import logical_axes


def rules_for(cfg: ModelConfig, mesh: Mesh, *, fsdp: Optional[bool] = None,
              overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    if fsdp is None:
        # FSDP on for >= ~8B params (replicated copies would not fit HBM)
        fsdp = cfg.param_count() >= 8e9

    def div(n: int) -> bool:
        return n > 0 and n % msize == 0

    mlp_dims = [d for d in (cfg.d_ff, cfg.expert_d_ff, 2 * cfg.d_model,
                            4 * cfg.d_model, cfg.lru_width or 0) if d]
    rules: Dict[str, Any] = {
        "vocab": "model" if div(cfg.vocab_size) else None,
        "mlp": "model" if all(d % msize == 0 for d in mlp_dims) else None,
        "heads": "model" if div(cfg.n_heads) else None,
        "kv_heads": "model" if div(cfg.n_kv_heads) else None,
        "experts": "model" if div(cfg.n_experts) else None,
        "embed": ("data" if (fsdp and cfg.d_model % dsize == 0) else None),
        "head_dim": None,
        "layers": None,
        None: None,
    }
    if overrides:
        rules.update(overrides)
    return rules


def spec_from_axes(axes: Tuple[Optional[str], ...], rules: Dict[str, Any]) -> P:
    """Map one param's logical axes to a PartitionSpec, dropping duplicate
    mesh axes (a mesh axis may shard at most one dim)."""
    used = set()
    out = []
    for a in axes:
        m = rules.get(a)
        if m is None or m in used:
            out.append(None)
        else:
            out.append(m)
            used.add(m)
    return P(*out)


def param_shardings(cfg: ModelConfig, mesh: Mesh, *,
                    fsdp: Optional[bool] = None,
                    overrides: Optional[Dict[str, Any]] = None):
    """NamedSharding tree mirroring the model params."""
    rules = rules_for(cfg, mesh, fsdp=fsdp, overrides=overrides)
    from repro.models.transformer import param_logical_axes
    axes_tree = param_logical_axes(cfg)
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, spec_from_axes(axes, rules)),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def opt_state_shardings(kind: str, cfg: ModelConfig, mesh: Mesh,
                        param_sh_tree):
    """Optimizer-state shardings (states shard exactly like their params;
    adafactor's factored r/c keep the matching prefix/split of the spec)."""
    from repro.optim.optimizers import OptState

    scalar = NamedSharding(mesh, P())
    if kind == "adamw":
        return OptState(step=scalar,
                        inner={"m": param_sh_tree, "v": param_sh_tree})
    if kind == "adafactor":
        def one(sh: NamedSharding):
            spec = tuple(sh.spec)
            if len(spec) >= 2:
                return {"r": NamedSharding(mesh, P(*spec[:-1])),
                        "c": NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))}
            return {"v": sh}
        inner = jax.tree.map(one, param_sh_tree,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
        return OptState(step=scalar, inner=inner)
    raise ValueError(kind)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """Batch sharded over ('pod','data'); remaining dims replicated."""
    from repro.launch.mesh import dp_axes_of
    return P(dp_axes_of(mesh), *([None] * extra_dims))


def logits_spec(mesh: Mesh) -> P:
    from repro.launch.mesh import dp_axes_of
    return P(dp_axes_of(mesh), None, "model" if "model" in mesh.axis_names
             else None)


def serving_specs(mesh: Mesh, layout: str = "graph", slab: str = "dense"):
    """NamedSharding trees for the sharded GraphQueryEngine's arrays
    (DESIGN.md §10): (db, query-block, candidate-block, slab-extras) for
    the DB slab shards, the replicated stacked (Q, ...) query block, the
    all-gathered per-device top-k candidate blocks, and the FilterSlab
    layout's extra operands (DESIGN.md §11: () for dense, the tail
    correction for hot, packed words/sb/widths rows for packed)."""
    from repro.core import distributed as dist
    db_spec, q_spec, out_spec, extra_spec = dist.multi_search_specs(
        *dist.layout_axes(mesh, layout), slab=slab)

    def named(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    return named(db_spec), named(q_spec), named(out_spec), named(extra_spec)
