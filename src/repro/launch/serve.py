"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Serves a reduced-config model with batched requests through the
prefill/decode engine (the full-config path is exercised by the dry-run).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.models import build_params
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_config(args.arch))
    params = build_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    eng = ServeEngine(cfg, params, batch_size=args.batch,
                      max_len=args.prompt_len + args.new_tokens + 8)
    eng.run(reqs)
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"arch={args.arch} served {done}/{len(reqs)} requests, "
          f"{toks} tokens; prefill {eng.stats['prefill_s']:.2f}s "
          f"decode {eng.stats['decode_s']:.2f}s")


if __name__ == "__main__":
    main()
