# Launch layer: production meshes, sharding rules, EP context, dry-run,
# train/serve CLIs.  NOTE: repro.launch.dryrun sets XLA_FLAGS at import —
# never import it from test code (tests and benches must see 1 device).
