"""HLO cost extraction: segment compiles, collective parsing, roofline.

Why segments: XLA's ``cost_analysis`` counts a ``while`` body ONCE, so the
scan-over-layers whole graph underreports FLOPs by ~n_units.  We therefore
compile the scanned unit (and embed/head segments) separately under the
SAME shardings and compose:

    total = embed + n_units * unit + prefix + head

All numbers are PER DEVICE (XLA reports post-SPMD).  Collective payloads
are parsed from each compiled segment's HLO text and costed with a
bidirectional-ring model on the v5e ICI constants.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, dp_axes_of
from repro.models.config import ModelConfig
from repro.models.layers import logical_axes, shapes_of
from repro.models.transformer import (_apply_unit, _dt, block_spec,
                                      model_spec)

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-model wire factors: wire_bytes = factor(n) * result_bytes
RING_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: float(n - 1),     # result is 1/n of input
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


# --------------------------------------------------------------------------
# HLO text parsing
# --------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in a result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def collective_bytes(hlo_text: str, loop_trip_count: int = 1
                     ) -> Dict[str, Any]:
    """Per-op-kind result bytes + ring-model seconds from compiled HLO.

    Collectives inside while-loop bodies are multiplied by
    ``loop_trip_count`` (scan-over-layers; an approximation when several
    loops of different trip counts nest — the segment path avoids this).
    """
    # map computation name -> its collective (kind, result_bytes, group) list
    comp = "__entry__"
    per_comp: Dict[str, List[Tuple[str, int, int]]] = {comp: []}
    comp_header = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
    while_bodies: set = set()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = comp_header.match(ls)
        if m and ls.endswith("{"):
            comp = m.group(1)
            per_comp.setdefault(comp, [])
            continue
        if " while(" in ls or ls.startswith("while("):
            for attr in re.findall(r"body=%?([\w\.\-_]+)", ls):
                while_bodies.add(attr)
        for kind in COLLECTIVES:
            token = f" {kind}("
            token2 = f" {kind}-start("
            if token in ls or token2 in ls:
                lhs = ls.split("=", 1)[0] if "=" in ls else ""
                rhs = ls.split("=", 1)[1] if "=" in ls else ls
                shape_txt = rhs.split(kind)[0]
                b = _shape_bytes(shape_txt)
                per_comp[comp].append((kind, b, _group_size(ls)))
                break

    totals: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0,
            "ring_seconds": 0.0} for k in COLLECTIVES}
    for cname, items in per_comp.items():
        mult = loop_trip_count if cname in while_bodies else 1
        for kind, b, n in items:
            wire = RING_FACTOR[kind](n) * b
            totals[kind]["count"] += mult
            totals[kind]["result_bytes"] += float(b) * mult
            totals[kind]["wire_bytes"] += wire * mult
            # bidirectional ring: 2 links active per chip
            totals[kind]["ring_seconds"] += wire * mult / (2 * ICI_BW)
    summary = {
        "total_wire_bytes": sum(t["wire_bytes"] for t in totals.values()),
        "total_ring_seconds": sum(t["ring_seconds"] for t in totals.values()),
        "by_kind": {k: v for k, v in totals.items() if v["count"]},
    }
    return summary


# --------------------------------------------------------------------------
# segment compiles
# --------------------------------------------------------------------------

def _unit_spec_tree(cfg: ModelConfig):
    return {f"b{i}": block_spec(cfg, b, cross=cfg.is_encdec)
            for i, b in enumerate(cfg.pattern)}


def _unit_shardings(cfg: ModelConfig, mesh, rules):
    from repro.launch.shardings import spec_from_axes
    axes = logical_axes(_unit_spec_tree(cfg))
    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_from_axes(a, rules)), axes,
        is_leaf=lambda t: isinstance(t, tuple))


def _cost(compiled, trip: int = 1) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text(), loop_trip_count=trip)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire_bytes": coll["total_wire_bytes"],
        "ring_seconds": coll["total_ring_seconds"],
        "collectives": coll["by_kind"],
    }


def _scaled(c: Dict[str, float], k: float) -> Dict[str, float]:
    return {kk: (v * k if isinstance(v, (int, float)) else v)
            for kk, v in c.items()}


def _added(*cs: Dict[str, float]) -> Dict[str, float]:
    out = {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0, "ring_seconds": 0.0}
    for c in cs:
        for k in out:
            out[k] += c.get(k, 0.0)
    return out


def _slstm_analytic(cfg: ModelConfig, batch: int, seq: int, train: bool
                    ) -> Dict[str, float]:
    """Per-device analytic correction for sLSTM blocks (their per-step scan
    is undercounted by cost_analysis; weights stream from HBM each step)."""
    n_slstm = cfg.n_units * sum(1 for b in cfg.pattern if b[0] == "slstm")
    if n_slstm == 0:
        return {"flops": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
                "ring_seconds": 0.0}
    d = cfg.d_model
    flops_step = 2 * batch * d * 8 * d + 2 * batch * d * d  # gates + down
    bytes_step = (8 * d * d + d * d) * 2                    # weight stream
    mult = 3 if train else 1                                # fwd+bwd approx
    return {"flops": float(n_slstm * seq * flops_step * mult),
            "bytes": float(n_slstm * seq * bytes_step * mult),
            "wire_bytes": 0.0, "ring_seconds": 0.0}


def _attn_analytics(cfg: ModelConfig, seq: int):
    """(visible_fraction, io_elems_per_token) averaged over the unit's
    attention blocks.  visible_fraction = share of the dense S^2 score
    matrix the Pallas kernel actually computes (causal block-skip /
    sliding window); io = q,k,v,o HBM elements per token (the kernel's
    VMEM-resident replacement for score materialisation)."""
    fracs = []
    io = 0
    for mixer, _ in cfg.pattern:
        if mixer == "ga":
            fracs.append(0.5)
        elif mixer == "la":
            w = min(cfg.local_window, seq)
            fracs.append(min(w * seq - w * w / 2, seq * seq / 2)
                         / (seq * seq))
        else:
            continue
        io += cfg.hd * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
    if not fracs:
        return 0.0, 0
    return float(np.mean(fracs)), io


def _kernel_adjusted(cfg: ModelConfig, c_unit: Dict, c_skip: Dict,
                     b_loc: int, seq: int, train: bool) -> Dict[str, float]:
    """Replace the XLA dense-attention cost delta with the flash kernel's
    analytic cost: flops scaled by the visible fraction, score
    materialisation traffic replaced by q/k/v/o streaming."""
    frac, io_per_tok = _attn_analytics(cfg, seq)
    d_flops = max(c_unit["flops"] - c_skip["flops"], 0.0)
    d_bytes = max(c_unit["bytes"] - c_skip["bytes"], 0.0)
    io_bytes = b_loc * seq * io_per_tok * 2 * (3 if train else 1)
    return {
        "flops": c_skip["flops"] + d_flops * frac,
        "bytes": c_skip["bytes"] + min(float(io_bytes), d_bytes),
        "wire_bytes": c_unit["wire_bytes"],
        "ring_seconds": c_unit["ring_seconds"],
    }


def train_segments(cfg: ModelConfig, mesh, rules, p_sh, p_shapes, shape,
                   par, microbatches: Optional[int] = None) -> Dict[str, Any]:
    """Compose per-device train-step costs from unit/embed/head segments."""
    from repro.launch.dryrun import launch_policy
    dp = dp_axes_of(mesh)
    micro = microbatches or launch_policy(cfg)["microbatches"]
    b_mb = shape.global_batch // micro
    seq = shape.seq_len
    dt = _dt(cfg)
    d = cfg.d_model
    x_spec = NamedSharding(mesh, P(dp, None, None))
    x_shape = jax.ShapeDtypeStruct((b_mb, seq, d), dt)
    u_sh = _unit_shardings(cfg, mesh, rules)
    u_shapes = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                            p_shapes["units"])

    def make_unit_train(cfg_):
        def unit_train(up, x, ct):
            from repro.models.transformer import _maybe_remat
            body = _maybe_remat(
                lambda p_, x_: _apply_unit(p_, x_, cfg_, par=par)[0], cfg_)
            y, vjp = jax.vjp(body, up, x)
            dp_, dx = vjp(ct)
            return y, dp_, dx
        return unit_train

    c_unit = _cost(jax.jit(make_unit_train(cfg),
                           in_shardings=(u_sh, x_spec, x_spec))
                   .lower(u_shapes, x_shape, x_shape).compile())
    kern = None
    if any(m in ("ga", "la") for m, _ in cfg.pattern):
        c_skip = _cost(jax.jit(make_unit_train(cfg.replace(attn_impl="skip")),
                               in_shardings=(u_sh, x_spec, x_spec))
                       .lower(u_shapes, x_shape, x_shape).compile())
        kern = _kernel_adjusted(cfg, c_unit, c_skip,
                                b_mb // _dp_total(mesh), seq, True)

    # embed segment (fwd gather + bwd scatter-add)
    tok = jax.ShapeDtypeStruct((b_mb, seq), jnp.int32)
    tok_sh = NamedSharding(mesh, P(dp, None))
    e_sh = NamedSharding(mesh,
                         P(*_param_spec(cfg, mesh, rules, ("vocab", "embed"))))

    def embed_seg(w, ids, ct):
        y, vjp = jax.vjp(lambda w_: jnp.take(w_, ids, axis=0).astype(dt), w)
        return y, vjp(ct)

    c_embed = _cost(jax.jit(embed_seg, in_shardings=(e_sh, tok_sh, x_spec))
                    .lower(p_shapes["embed"], tok, x_shape).compile())

    # head segment (final norm + logits + xent fwd/bwd)
    def head_seg(hw, x, tg):
        from repro.models.layers import dense
        logits = dense(hw, x) if not cfg.tie_embeddings else jnp.einsum(
            "bsd,vd->bsv", x, hw.astype(x.dtype))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logp, tg[..., None], axis=-1)
        return -ll.mean()

    hw_shape = p_shapes["head"] if not cfg.tie_embeddings else p_shapes["embed"]
    hw_axes = ("embed", "vocab") if not cfg.tie_embeddings else ("vocab", "embed")
    hw_sh = NamedSharding(mesh, P(*_param_spec(cfg, mesh, rules, hw_axes)))
    c_head = _cost(jax.jit(jax.grad(head_seg, argnums=(0, 1)),
                           in_shardings=(hw_sh, x_spec, tok_sh))
                   .lower(hw_shape, x_shape, tok).compile())

    n_prefix = len(cfg.prefix)
    per_unit_blocks = max(len(cfg.pattern), 1)
    prefix_scale = n_prefix / per_unit_blocks
    slstm_fix = _slstm_analytic(cfg, b_mb // _dp_total(mesh), seq, True)

    unit_total = _scaled(c_unit, micro * (cfg.n_units + prefix_scale))
    emb_total = _scaled(c_embed, micro)
    head_total = _scaled(c_head, micro)
    total = _added(unit_total, emb_total, head_total, slstm_fix)
    out = {
        "per_unit_train": c_unit, "embed": c_embed, "head": c_head,
        "microbatches": micro, "n_units": cfg.n_units,
        "slstm_analytic": slstm_fix,
        "total_per_device": total,
    }
    if kern is not None:
        out["per_unit_train_kernel"] = kern
        out["total_per_device_kernel"] = _added(
            _scaled(kern, micro * (cfg.n_units + prefix_scale)),
            emb_total, head_total, slstm_fix)
    return out


def fwd_segments(cfg: ModelConfig, mesh, rules, p_sh, p_shapes, shape, par,
                 batch: int, seq: int) -> Dict[str, Any]:
    dp = dp_axes_of(mesh)
    dt = _dt(cfg)
    d = cfg.d_model
    x_spec = NamedSharding(mesh, P(dp, None, None))
    x_shape = jax.ShapeDtypeStruct((batch, seq, d), dt)
    u_sh = _unit_shardings(cfg, mesh, rules)
    u_shapes = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                            p_shapes["units"])

    def make_unit_fwd(cfg_):
        return lambda up, x: _apply_unit(up, x, cfg_, par=par)[0]

    c_unit = _cost(jax.jit(make_unit_fwd(cfg), in_shardings=(u_sh, x_spec))
                   .lower(u_shapes, x_shape).compile())
    kern = None
    if any(m in ("ga", "la") for m, _ in cfg.pattern):
        c_skip = _cost(jax.jit(make_unit_fwd(cfg.replace(attn_impl="skip")),
                               in_shardings=(u_sh, x_spec))
                       .lower(u_shapes, x_shape).compile())
        kern = _kernel_adjusted(cfg, c_unit, c_skip,
                                max(batch // _dp_total(mesh), 1), seq, False)

    def head_fwd(hw, x):
        from repro.models.layers import dense
        if cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, hw.astype(x.dtype))
        return dense(hw, x)

    hw_shape = p_shapes["head"] if not cfg.tie_embeddings else p_shapes["embed"]
    hw_axes = ("embed", "vocab") if not cfg.tie_embeddings else ("vocab", "embed")
    hw_sh = NamedSharding(mesh, P(*_param_spec(cfg, mesh, rules, hw_axes)))
    c_head = _cost(jax.jit(head_fwd, in_shardings=(hw_sh, x_spec))
                   .lower(hw_shape, x_shape).compile())

    prefix_scale = len(cfg.prefix) / max(len(cfg.pattern), 1)
    enc_scale = cfg.n_enc_units / max(cfg.n_units, 1)
    slstm_fix = _slstm_analytic(cfg, batch // _dp_total(mesh), seq, False)
    n_total_units = cfg.n_units + prefix_scale + enc_scale * cfg.n_units
    total = _added(_scaled(c_unit, n_total_units), c_head, slstm_fix)
    out = {"per_unit_fwd": c_unit, "head": c_head,
           "slstm_analytic": slstm_fix, "total_per_device": total}
    if kern is not None:
        out["per_unit_fwd_kernel"] = kern
        out["total_per_device_kernel"] = _added(
            _scaled(kern, n_total_units), c_head, slstm_fix)
    return out


def decode_segments(cfg: ModelConfig, mesh, rules, p_sh, p_shapes, shape,
                    par, c_sh, dspec) -> Dict[str, Any]:
    dp = dp_axes_of(mesh)
    dt = _dt(cfg)
    d = cfg.d_model
    B = shape.global_batch
    x_spec = NamedSharding(mesh, _bspec(mesh, B, 3))
    x_shape = jax.ShapeDtypeStruct((B, 1, d), dt)
    u_sh = _unit_shardings(cfg, mesh, rules)
    u_shapes = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                            p_shapes["units"])
    # one unit's cache slice: drop the leading n_units dim
    uc_shapes = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                             dspec["cache"]["units"])
    uc_sh = jax.tree.map(
        lambda sh: NamedSharding(mesh, P(*tuple(sh.spec)[1:])),
        c_sh["units"], is_leaf=lambda x: isinstance(x, NamedSharding))

    def unit_dec(up, uc, x, pos):
        y, nc = _apply_unit(up, x, cfg, cache=uc, pos=pos, par=par)
        return y, nc

    c_unit = _cost(jax.jit(
        unit_dec, in_shardings=(u_sh, uc_sh, x_spec, NamedSharding(mesh, P())))
        .lower(u_shapes, uc_shapes, x_shape,
               jax.ShapeDtypeStruct((), jnp.int32)).compile())

    def head_fwd(hw, x):
        from repro.models.layers import dense
        if cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, hw.astype(x.dtype))
        return dense(hw, x)

    hw_shape = p_shapes["head"] if not cfg.tie_embeddings else p_shapes["embed"]
    hw_axes = ("embed", "vocab") if not cfg.tie_embeddings else ("vocab", "embed")
    hw_sh = NamedSharding(mesh, P(*_param_spec(cfg, mesh, rules, hw_axes)))
    c_head = _cost(jax.jit(head_fwd, in_shardings=(hw_sh, x_spec))
                   .lower(hw_shape, x_shape).compile())

    prefix_scale = len(cfg.prefix) / max(len(cfg.pattern), 1)
    slstm_fix = _slstm_analytic(cfg, max(B // _dp_total(mesh), 1), 1, False)
    total = _added(_scaled(c_unit, cfg.n_units + prefix_scale), c_head,
                   slstm_fix)
    return {"per_unit_decode": c_unit, "head": c_head,
            "slstm_analytic": slstm_fix, "total_per_device": total}


def _bspec(mesh, b: int, ndim: int):
    dp = dp_axes_of(mesh)
    dp_total = _dp_total(mesh)
    lead = dp if b % dp_total == 0 else None
    return P(lead, *([None] * (ndim - 1)))


def _dp_total(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes_of(mesh)]))


def _param_spec(cfg, mesh, rules, axes: Tuple[Optional[str], ...]):
    from repro.launch.shardings import spec_from_axes
    return tuple(spec_from_axes(axes, rules))


# --------------------------------------------------------------------------
# roofline terms
# --------------------------------------------------------------------------

def model_attn_flops(cfg: ModelConfig, shape) -> float:
    """Analytic attention FLOPs (the 6ND rule ignores them; they dominate
    at 32k+ context, so MODEL_FLOPS must include the *visible* score work:
    causal S^2/2, sliding window S*W, decode = context length per token)."""
    B = shape.global_batch
    S = shape.seq_len
    hd = cfg.hd
    mult = 3 if shape.kind == "train" else 1  # fwd + ~2x bwd
    total = 0.0
    blocks = list(cfg.prefix) + [b for b in cfg.pattern
                                 for _ in range(1)] * cfg.n_units
    for mixer, _ in blocks:
        if mixer == "ga":
            visible = (S / 2) if shape.kind != "decode" else S
        elif mixer == "la":
            w = min(cfg.local_window, S)
            visible = w if shape.kind == "decode" else \
                (w - w * w / (2 * S))
        else:
            continue
        tokens = B * (S if shape.kind != "decode" else 1)
        total += 4.0 * tokens * visible * hd * cfg.n_heads * mult
    if cfg.is_encdec and shape.kind != "decode":
        total += cfg.num_enc_layers * 4.0 * B * S * S * hd * cfg.n_heads * mult
        total += cfg.num_layers * 4.0 * B * S * S * hd * cfg.n_heads * mult
    return total


def _terms_from_total(total: Dict, cfg: ModelConfig, shape, n_chips: int
                      ) -> Dict[str, Any]:
    flops_dev = total["flops"]
    bytes_dev = total["bytes"]
    comm_s = total["ring_seconds"]
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": comm_s}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: 6*N*D train, 2*N*D inference (D = tokens processed),
    # plus the analytic visible-attention term (dominates at long context)
    n_params = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = (6 if shape.kind == "train" else 2) * n_params * tokens \
        + model_attn_flops(cfg, shape)
    hlo_flops_cluster = flops_dev * n_chips
    bound_s = max(terms.values())
    useful_s = mf / (n_chips * PEAK_FLOPS_BF16)
    return {
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": float(mf),
        "hlo_flops_cluster": float(hlo_flops_cluster),
        "useful_flops_ratio": float(mf / hlo_flops_cluster)
        if hlo_flops_cluster else 0.0,
        "roofline_fraction": float(useful_s / bound_s) if bound_s else 0.0,
        "step_time_lower_bound_s": float(bound_s),
    }


def roofline_terms(result: Dict[str, Any], cfg: ModelConfig, shape,
                   n_chips: int, mesh) -> Dict[str, Any]:
    seg = result.get("segments") or {}
    total = seg.get("total_per_device")
    if not total:
        return {}
    out = {"roofline": _terms_from_total(total, cfg, shape, n_chips)}
    kern = seg.get("total_per_device_kernel")
    if kern is not None:
        out["roofline_kernel"] = _terms_from_total(kern, cfg, shape, n_chips)
        out["roofline_kernel"]["note"] = (
            "dense-attention delta replaced by the Pallas flash kernel's "
            "analytic cost (causal/window block-skip FLOPs; scores stay in "
            "VMEM so HBM traffic = q/k/v/o streaming)")
    return out
