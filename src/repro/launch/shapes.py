"""Assigned input shapes (the x-axis of the 40-cell table) + input_specs.

  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill_step
  decode_32k   seq 32768,   global_batch 128   -> decode_step (1 new token
                                                  against a 32k KV cache)
  long_500k    seq 524288,  global_batch 1     -> decode_step; only for
               archs with a sub-quadratic decode state (skip noted in
               DESIGN.md §6 otherwise)

input_specs() returns ShapeDtypeStructs only — weak-type-correct,
shardable, zero allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(supported, reason-if-not)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic attention (assignment: skip + note)")
    return True, ""


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def token_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch stand-ins."""
    sds = jax.ShapeDtypeStruct
    out = {"inputs": sds((batch, seq), jnp.int32),
           "targets": sds((batch, seq), jnp.int32)}
    if cfg.is_encdec:
        # [audio] frontend stub: precomputed frame embeddings
        out["enc_inputs"] = sds((batch, seq, cfg.d_model), _dt(cfg))
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs mirroring init_cache (no allocation)."""
    from repro.models.transformer import init_cache
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_specs(cfg: ModelConfig, batch: int, ctx_len: int):
    sds = jax.ShapeDtypeStruct
    tok = sds((batch, 1), jnp.int32)
    if cfg.frontend == "embed_stub" and not cfg.is_encdec:
        tok = sds((batch, 1, cfg.d_model), _dt(cfg))
    out = {"token": tok,
           "cache": cache_specs(cfg, batch, ctx_len),
           "pos": sds((), jnp.int32)}
    if cfg.is_encdec:
        out["enc_out"] = sds((batch, ctx_len, cfg.d_model), _dt(cfg))
    return out
