"""Attributed (labeled) simple undirected graphs.

The paper (Definition 1) works with labeled simple undirected graphs
without multi-edges or self-loops.  Vertex labels and edge labels are
small integers (a host-side vocabulary maps raw labels to ids).
"""
from __future__ import annotations

import io
import json
import os
from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Graph:
    """A labeled simple undirected graph.

    Attributes:
      n: number of vertices (ids ``0..n-1``).
      vlabels: ``(n,)`` int32 vertex labels.
      edges: ``(m, 2)`` int32 endpoints with ``edges[i, 0] < edges[i, 1]``,
        lexicographically sorted, unique.
      elabels: ``(m,)`` int32 edge labels.
    """

    n: int
    vlabels: np.ndarray
    edges: np.ndarray
    elabels: np.ndarray

    def __post_init__(self) -> None:
        object.__setattr__(self, "vlabels", np.asarray(self.vlabels, np.int32))
        e = np.asarray(self.edges, np.int32).reshape(-1, 2)
        el = np.asarray(self.elabels, np.int32).reshape(-1)
        if e.shape[0] != el.shape[0]:
            raise ValueError("edges/elabels length mismatch")
        if self.vlabels.shape[0] != self.n:
            raise ValueError("vlabels length != n")
        if e.size:
            if (e[:, 0] == e[:, 1]).any():
                raise ValueError("self-loop")
            lo = np.minimum(e[:, 0], e[:, 1])
            hi = np.maximum(e[:, 0], e[:, 1])
            order = np.lexsort((hi, lo))
            e = np.stack([lo, hi], axis=1)[order]
            el = el[order]
            if e.shape[0] > 1:
                dup = (np.diff(e[:, 0]) == 0) & (np.diff(e[:, 1]) == 0)
                if dup.any():
                    raise ValueError("multi-edge")
            if e.size and (e.min() < 0 or e.max() >= self.n):
                raise ValueError("edge endpoint out of range")
        object.__setattr__(self, "edges", e)
        object.__setattr__(self, "elabels", el)

    # ---- basic accessors -------------------------------------------------
    @property
    def m(self) -> int:
        return int(self.edges.shape[0])

    def degrees(self) -> np.ndarray:
        d = np.zeros(self.n, np.int32)
        if self.m:
            np.add.at(d, self.edges[:, 0], 1)
            np.add.at(d, self.edges[:, 1], 1)
        return d

    def degree_sequence(self) -> np.ndarray:
        """Non-increasing degree sequence (sigma_g in the paper)."""
        return np.sort(self.degrees())[::-1].astype(np.int32)

    def adjacency(self) -> List[List[Tuple[int, int]]]:
        """adj[v] = list of (neighbor, edge_label)."""
        adj: List[List[Tuple[int, int]]] = [[] for _ in range(self.n)]
        for (u, v), l in zip(self.edges, self.elabels):
            adj[int(u)].append((int(v), int(l)))
            adj[int(v)].append((int(u), int(l)))
        return adj

    def edge_label_dict(self) -> dict:
        return {(int(u), int(v)): int(l) for (u, v), l in zip(self.edges, self.elabels)}

    def vertex_label_hist(self, n_labels: int) -> np.ndarray:
        return np.bincount(self.vlabels, minlength=n_labels).astype(np.int32)

    def edge_label_hist(self, n_labels: int) -> np.ndarray:
        if self.m == 0:
            return np.zeros(n_labels, np.int32)
        return np.bincount(self.elabels, minlength=n_labels).astype(np.int32)

    def relabel_vertices(self, perm: Sequence[int]) -> "Graph":
        """Return an isomorphic graph with vertex ``i`` renamed ``perm[i]``."""
        perm = np.asarray(perm, np.int32)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.n, dtype=np.int32)
        vl = np.empty_like(self.vlabels)
        vl[perm] = self.vlabels
        e = perm[self.edges] if self.m else self.edges
        return Graph(self.n, vl, e, self.elabels)

    def __hash__(self) -> int:  # structural hash (not isomorphism-invariant)
        return hash(
            (self.n, self.vlabels.tobytes(), self.edges.tobytes(), self.elabels.tobytes())
        )

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Graph):
            return NotImplemented
        return (
            self.n == other.n
            and np.array_equal(self.vlabels, other.vlabels)
            and np.array_equal(self.edges, other.edges)
            and np.array_equal(self.elabels, other.elabels)
        )


class GraphDB:
    """An ordered collection of graphs + label vocabularies.

    This is the ``G`` of the problem statement.  It also records
    ``n_vlabels`` / ``n_elabels`` (the global label alphabets) which the
    filters need for histogram intersections.
    """

    def __init__(self, graphs: Sequence[Graph], n_vlabels: Optional[int] = None,
                 n_elabels: Optional[int] = None):
        self.graphs: List[Graph] = list(graphs)
        if n_vlabels is None:
            n_vlabels = 1 + max((int(g.vlabels.max()) for g in self.graphs if g.n), default=0)
        if n_elabels is None:
            n_elabels = 1 + max((int(g.elabels.max()) for g in self.graphs if g.m), default=0)
        self.n_vlabels = int(n_vlabels)
        self.n_elabels = int(n_elabels)

    def __len__(self) -> int:
        return len(self.graphs)

    def __getitem__(self, i: int) -> Graph:
        return self.graphs[i]

    def __iter__(self) -> Iterator[Graph]:
        return iter(self.graphs)

    # ---- bulk stats ------------------------------------------------------
    def sizes(self) -> Tuple[np.ndarray, np.ndarray]:
        nv = np.array([g.n for g in self.graphs], np.int32)
        ne = np.array([g.m for g in self.graphs], np.int32)
        return nv, ne

    def stats(self) -> dict:
        nv, ne = self.sizes()
        return {
            "num_graphs": len(self.graphs),
            "avg_V": float(nv.mean()) if len(self.graphs) else 0.0,
            "avg_E": float(ne.mean()) if len(self.graphs) else 0.0,
            "max_V": int(nv.max()) if len(self.graphs) else 0,
            "max_E": int(ne.max()) if len(self.graphs) else 0,
            "n_vlabels": self.n_vlabels,
            "n_elabels": self.n_elabels,
        }

    # ---- serialization ---------------------------------------------------
    def save(self, path: str) -> None:
        """Single-file npz serialization (CSR-style concatenation)."""
        nv, ne = self.sizes()
        voff = np.concatenate([[0], np.cumsum(nv)]).astype(np.int64)
        eoff = np.concatenate([[0], np.cumsum(ne)]).astype(np.int64)
        vlab = (np.concatenate([g.vlabels for g in self.graphs])
                if len(self.graphs) else np.zeros(0, np.int32))
        edges = (np.concatenate([g.edges for g in self.graphs])
                 if any(g.m for g in self.graphs) else np.zeros((0, 2), np.int32))
        elab = (np.concatenate([g.elabels for g in self.graphs])
                if any(g.m for g in self.graphs) else np.zeros(0, np.int32))
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        np.savez_compressed(
            path, voff=voff, eoff=eoff, vlab=vlab, edges=edges, elab=elab,
            meta=np.array([self.n_vlabels, self.n_elabels], np.int64),
        )

    @classmethod
    def load(cls, path: str) -> "GraphDB":
        z = np.load(path)
        voff, eoff = z["voff"], z["eoff"]
        graphs = []
        for i in range(len(voff) - 1):
            vl = z["vlab"][voff[i]:voff[i + 1]]
            e = z["edges"][eoff[i]:eoff[i + 1]]
            el = z["elab"][eoff[i]:eoff[i + 1]]
            graphs.append(Graph(len(vl), vl, e, el))
        meta = z["meta"]
        return cls(graphs, int(meta[0]), int(meta[1]))
