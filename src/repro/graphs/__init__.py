"""Attributed-graph substrate: containers, generators, batching, edits."""

from repro.graphs.graph import Graph, GraphDB
from repro.graphs.generators import (
    aids_like_db,
    graphgen_db,
    random_graph,
    perturb_graph,
)
from repro.graphs.batching import PaddedGraphBatch

__all__ = [
    "Graph",
    "GraphDB",
    "aids_like_db",
    "graphgen_db",
    "random_graph",
    "perturb_graph",
    "PaddedGraphBatch",
]
