"""Padded dense batch views of a GraphDB for the vectorised (JAX) paths.

The succinct host index (repro.core.succinct) is the archival format; the
accelerator path consumes fixed-shape padded arrays (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.graphs.graph import Graph, GraphDB


@dataclass
class PaddedGraphBatch:
    """Fixed-shape arrays describing ``B`` graphs.

    All pads use 0 counts / -1 ids so reductions are mask-free where
    possible.

    Attributes:
      nv, ne:        (B,) int32 vertex / edge counts.
      degseq:        (B, Vmax) int32 non-increasing degree sequences,
                     zero padded (this *is* the sigma_1 padding of Lemma 5).
      vlabel_hist:   (B, n_vlabels) int32.
      elabel_hist:   (B, n_elabels) int32.
    """

    nv: np.ndarray
    ne: np.ndarray
    degseq: np.ndarray
    vlabel_hist: np.ndarray
    elabel_hist: np.ndarray

    @property
    def batch_size(self) -> int:
        return int(self.nv.shape[0])

    @property
    def vmax(self) -> int:
        return int(self.degseq.shape[1])

    @classmethod
    def from_db(cls, db: GraphDB, vmax: Optional[int] = None) -> "PaddedGraphBatch":
        return cls.from_graphs(db.graphs, db.n_vlabels, db.n_elabels, vmax)

    @classmethod
    def from_graphs(cls, graphs: Sequence[Graph], n_vlabels: int, n_elabels: int,
                    vmax: Optional[int] = None) -> "PaddedGraphBatch":
        B = len(graphs)
        if vmax is None:
            vmax = max((g.n for g in graphs), default=1)
        nv = np.zeros(B, np.int32)
        ne = np.zeros(B, np.int32)
        degseq = np.zeros((B, vmax), np.int32)
        vh = np.zeros((B, n_vlabels), np.int32)
        eh = np.zeros((B, n_elabels), np.int32)
        for i, g in enumerate(graphs):
            nv[i] = g.n
            ne[i] = g.m
            s = g.degree_sequence()
            degseq[i, : min(len(s), vmax)] = s[:vmax]
            vh[i] = g.vertex_label_hist(n_vlabels)
            eh[i] = g.edge_label_hist(n_elabels)
        return cls(nv, ne, degseq, vh, eh)
