"""Dataset generators.

The container has no network access, so the AIDS / PubChem / GraphGen
datasets of the paper are replaced by statistically matched synthetic
generators (see DESIGN.md §9):

* ``aids_like_db`` — molecule-like sparse graphs: |V| ~ N(25.6, 8), edge
  count ≈ 1.07·|V| (ring-and-tree chemistry), 62 vertex labels drawn from
  a Zipf distribution (C/N/O dominate real molecules), 3 edge labels
  (single/double/triple bonds, heavily skewed to single).
* ``graphgen_db`` — the GraphGen parameterisation used for
  S100K.E30.D50.L5: fixed edge count, target density ρ = 2|E|/(|V|(|V|−1)),
  uniform labels.
* ``perturb_graph`` — applies ≤ k random edit operations, giving pairs with
  a *known upper bound* on GED (used by tests and query workloads).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.graphs.graph import Graph, GraphDB


def _zipf_probs(k: int, s: float = 1.3) -> np.ndarray:
    w = 1.0 / np.arange(1, k + 1) ** s
    return w / w.sum()


def random_graph(rng: np.random.Generator, n: int, m: int, n_vlabels: int,
                 n_elabels: int, vlabel_probs: Optional[np.ndarray] = None,
                 elabel_probs: Optional[np.ndarray] = None,
                 connected: bool = True,
                 max_degree: Optional[int] = None) -> Graph:
    """Uniform-ish random simple graph with ``n`` vertices and ``m`` edges.

    ``max_degree`` caps vertex degrees (chemistry valence; also controls
    degree-q-gram diversity in the AIDS-like generator)."""
    n = max(int(n), 1)
    max_m = n * (n - 1) // 2
    if max_degree is not None:
        max_m = min(max_m, n * max_degree // 2)
    m = int(min(max(m, 0), max_m))
    vlabels = rng.choice(n_vlabels, size=n, p=vlabel_probs).astype(np.int32)
    chosen: set = set()
    edges: List[Tuple[int, int]] = []
    deg = np.zeros(n, np.int32)

    def can(u: int, v: int) -> bool:
        if max_degree is None:
            return True
        return deg[u] < max_degree and deg[v] < max_degree

    if connected and n > 1 and m >= n - 1:
        # random spanning tree first (random attachment, degree-capped)
        perm = rng.permutation(n)
        for i in range(1, n):
            u = int(perm[i])
            for _try in range(16):
                v = int(perm[rng.integers(0, i)])
                if can(u, v):
                    break
            a, b = (u, v) if u < v else (v, u)
            if (a, b) in chosen:
                continue
            chosen.add((a, b))
            edges.append((a, b))
            deg[u] += 1
            deg[v] += 1
    tries = 0
    while len(edges) < m and tries < 50 * m + 100:
        tries += 1
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u == v or not can(u, v):
            continue
        a, b = (u, v) if u < v else (v, u)
        if (a, b) in chosen:
            continue
        chosen.add((a, b))
        edges.append((a, b))
        deg[u] += 1
        deg[v] += 1
    e = np.array(edges, np.int32).reshape(-1, 2)
    el = rng.choice(n_elabels, size=len(edges), p=elabel_probs).astype(np.int32)
    return Graph(n, vlabels, e, el)


def aids_like_db(num_graphs: int, seed: int = 0, mean_v: float = 25.6,
                 std_v: float = 8.0, n_vlabels: int = 62,
                 n_elabels: int = 3, family_size: int = 4) -> GraphDB:
    """Molecule-like dataset statistically matched to AIDS (Table 1).

    Real compound databases contain congeneric series (families of close
    analogues), which is what makes similarity search non-trivial:
    ``family_size`` graphs per base molecule are emitted as small edit
    perturbations of each other, so GED neighbourhoods are populated.
    """
    rng = np.random.default_rng(seed)
    vprobs = _zipf_probs(n_vlabels, 1.6)      # C/N/O-like dominance
    eprobs = np.array([0.85, 0.13, 0.02])[:n_elabels]
    eprobs = eprobs / eprobs.sum()
    graphs: List[Graph] = []
    while len(graphs) < num_graphs:
        n = int(np.clip(round(rng.normal(mean_v, std_v)), 4, 64))
        # chemistry: |E| slightly above |V|-1 (rings): AIDS has E/V ≈ 1.074,
        # valence caps degrees at 4
        extra = rng.binomial(max(n // 6, 1), 0.55)
        m = (n - 1) + extra
        base = random_graph(rng, n, m, n_vlabels, n_elabels, vprobs,
                            eprobs, max_degree=4)
        graphs.append(base)
        for _ in range(min(family_size - 1, num_graphs - len(graphs))):
            k = int(rng.integers(1, 5))
            graphs.append(perturb_graph(base, k, rng, n_vlabels, n_elabels))
    perm = rng.permutation(len(graphs))
    return GraphDB([graphs[i] for i in perm], n_vlabels, n_elabels)


def graphgen_db(num_graphs: int, num_edges: int = 30, density: float = 0.5,
                n_vlabels: int = 5, n_elabels: int = 2, seed: int = 0) -> GraphDB:
    """GraphGen-style dataset, e.g. S100K.E30.D50.L5 = (100k, 30, 0.5, 5, 2).

    ρ = 2|E| / (|V|(|V|−1))  ⇒  |V| ≈ (1 + sqrt(1 + 8|E|/ρ)) / 2.
    """
    rng = np.random.default_rng(seed)
    n_target = (1.0 + np.sqrt(1.0 + 8.0 * num_edges / density)) / 2.0
    graphs = []
    for _ in range(num_graphs):
        n = int(np.clip(round(rng.normal(n_target, 0.75)), 3, 64))
        graphs.append(random_graph(rng, n, num_edges, n_vlabels, n_elabels,
                                   connected=False))
    return GraphDB(graphs, n_vlabels, n_elabels)


def perturb_graph(g: Graph, k: int, rng: np.random.Generator,
                  n_vlabels: int, n_elabels: int) -> Graph:
    """Apply exactly ``k`` random primitive edit operations to ``g``.

    Returns a graph ``h`` with ``ged(g, h) <= k`` (each op is one of the six
    primitives of the paper; the sequence may partially cancel, so the true
    GED can be smaller — tests use this as an upper bound only).
    """
    n = g.n
    vlabels = g.vlabels.copy().tolist()
    edict = {(int(u), int(v)): int(l) for (u, v), l in zip(g.edges, g.elabels)}
    for _ in range(k):
        ops = ["vsub", "esub", "eins", "edel", "vins", "vdel"]
        rng.shuffle(ops)
        for op in ops:
            if op == "vsub" and n > 0:
                v = int(rng.integers(0, n))
                new = int(rng.integers(0, n_vlabels))
                if new != vlabels[v]:
                    vlabels[v] = new
                    break
            elif op == "esub" and edict:
                key = list(edict)[int(rng.integers(0, len(edict)))]
                new = int(rng.integers(0, n_elabels))
                if new != edict[key]:
                    edict[key] = new
                    break
            elif op == "eins" and n >= 2:
                for _try in range(10):
                    u = int(rng.integers(0, n)); v = int(rng.integers(0, n))
                    if u == v:
                        continue
                    a, b = (u, v) if u < v else (v, u)
                    if (a, b) not in edict:
                        edict[(a, b)] = int(rng.integers(0, n_elabels))
                        break
                else:
                    continue
                break
            elif op == "edel" and edict:
                key = list(edict)[int(rng.integers(0, len(edict)))]
                del edict[key]
                break
            elif op == "vins":
                vlabels.append(int(rng.integers(0, n_vlabels)))
                n += 1
                break
            elif op == "vdel" and n > 1:
                # only isolated vertices can be deleted by one primitive op
                deg = np.zeros(n, np.int64)
                for (a, b) in edict:
                    deg[a] += 1
                    deg[b] += 1
                iso = np.flatnonzero(deg == 0)
                if len(iso) == 0:
                    continue
                v = int(iso[int(rng.integers(0, len(iso)))])
                vlabels.pop(v)
                remap = {}
                for old in range(n):
                    if old == v:
                        continue
                    remap[old] = old - (1 if old > v else 0)
                edict = {(remap[a], remap[b]): l for (a, b), l in edict.items()}
                n -= 1
                break
    edges = np.array(sorted(edict), np.int32).reshape(-1, 2)
    elabels = np.array([edict[tuple(e)] for e in edges], np.int32)
    return Graph(n, np.array(vlabels, np.int32), edges, elabels)
