"""Chameleon-34B [vlm]: 48L d=8192 64H GQA kv=8 d_ff=22016 vocab=65536,
early fusion: VQ image tokens share the token vocabulary, so the frontend
is the ordinary embedding (image tokens are ids).  [arXiv:2405.09818;
unverified]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        pattern=(("ga", "swiglu"),), n_units=48,
        qk_norm=True,
    )
