"""PubChem-scale configuration (scaled to container memory; the paper's
25M-graph run is emulated by the distributed sharding math in the dry-run
and by the per-shard measurements in benchmarks/scalability.py)."""
from repro.configs.msq_aids import MSQConfig


def get_config() -> MSQConfig:
    # vocab-sharded serving: PubChem's 101 vertex labels produce a degree
    # q-gram vocabulary wide enough that replicating dense F_D per device
    # wastes HBM — split it over 'model' instead (DESIGN.md §5/§10), and
    # keep only the hot prefix of the frequency-ordered vocabulary
    # resident (the 'hot' FilterSlab, DESIGN.md §11; the CSR tail is
    # corrected per batch on host).
    return MSQConfig(name="msq_pubchem", num_graphs=500_000,
                     generator="aids_like", n_vlabels=101, n_elabels=3,
                     seed=7, sharded_layout="vocab", slab_layout="hot",
                     hot_mass=0.95)
