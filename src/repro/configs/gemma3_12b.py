"""Gemma3-12B [dense]: 48L d=3840 16H GQA kv=8 d_ff=15360 vocab=262144,
5:1 local:global interleave, 128k context.  [hf:google/gemma-3-1b-pt;
unverified]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    local = ("la", "swiglu")
    return ModelConfig(
        name="gemma3-12b", family="dense",
        d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=15360, vocab_size=262144,
        pattern=(local, local, local, local, local, ("ga", "swiglu")),
        n_units=8,
        qk_norm=True, rope_theta=1e6, local_window=1024,
        supports_long_context=True,  # 5/6 layers windowed; 8 global layers
    )
