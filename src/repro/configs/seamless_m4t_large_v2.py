"""SeamlessM4T-large-v2 [audio]: enc-dec, 24L each side, d=1024 16H
(kv=16 => MHA) d_ff=8192 vocab=256206.  Modality frontend is a STUB:
encoder inputs are precomputed frame embeddings (input_specs).
[arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=8192, vocab_size=256206,
        pattern=(("ga", "swiglu"),), n_units=24,
        enc_pattern=(("ga", "swiglu"),), n_enc_units=24,
        frontend="embed_stub",
    )
