"""RecurrentGemma-2B [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention at 1:2 attn:recurrent.
26 = 2 unscanned recurrent blocks + 8 x (rec, rec, local-attn).
[arXiv:2402.19427; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab_size=256000,
        prefix=(("rglru", "swiglu"), ("rglru", "swiglu")),
        pattern=(("rglru", "swiglu"), ("rglru", "swiglu"),
                 ("la", "swiglu")),
        n_units=8,
        local_window=2048, lru_width=2560,
        supports_long_context=True,
    )
