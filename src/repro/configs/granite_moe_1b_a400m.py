"""Granite-3.0-1B-A400M [moe]: 24L d=1024 16H GQA kv=8, MoE 32 experts
top-8 with expert d_ff=512, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=0, vocab_size=49155,
        pattern=(("ga", "moe"),), n_units=24,
        n_experts=32, top_k=8, expert_d_ff=512,
        tie_embeddings=True,
    )
