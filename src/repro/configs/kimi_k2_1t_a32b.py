"""Kimi-K2 1T-A32B [moe]: 61L d=7168 64H GQA kv=8, MoE 384 experts top-8
with expert d_ff=2048, vocab=163840.  Trillion-parameter MoE (paper-table).
Optimizer defaults to Adafactor with bf16 states at this scale (see
EXPERIMENTS.md §Dry-run).  [arXiv:2501.kimi2; unverified]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=0, vocab_size=163840,
        pattern=(("ga", "moe"),), n_units=61,
        n_experts=384, top_k=8, expert_d_ff=2048,
    )
