"""Config registry: ``--arch <id>`` resolves here.

Each assigned architecture has its own module with ``get_config()``; the
paper's own configs (msq_aids / msq_pubchem) describe index builds.
``reduced(cfg)`` shrinks any ModelConfig to a CPU-smoke-test size of the
same family (same pattern/features, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen3-1.7b",
    "qwen3-8b",
    "gemma3-12b",
    "yi-34b",
    "seamless-m4t-large-v2",
    "recurrentgemma-2b",
    "chameleon-34b",
    "xlstm-1.3b",
    "kimi-k2-1t-a32b",
    "granite-moe-1b-a400m",
]

MSQ_IDS = ["msq_aids", "msq_pubchem"]


def get_config(arch: str) -> ModelConfig:
    mod = arch.replace("-", "_").replace(".", "_")
    import importlib
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.get_config()


def get_msq_config(name: str):
    import importlib
    m = importlib.import_module(f"repro.configs.{name}")
    return m.get_config()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests (one fwd/train step)."""
    n_units = min(cfg.n_units, 2)
    n_enc_units = min(cfg.n_enc_units, 2)
    kw = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 4)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_units=n_units,
        n_enc_units=n_enc_units,
        prefix=cfg.prefix[:1],
        local_window=16,
        lru_width=64 if cfg.lru_width else None,
        mlstm_heads=2,
        dtype="float32",
        remat=False,
        attn_impl="xla",
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, expert_d_ff=32)
    return dataclasses.replace(cfg, **kw)
