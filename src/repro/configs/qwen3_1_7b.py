"""Qwen3-1.7B [dense]: 28L d=2048 16H GQA kv=8 d_ff=6144 vocab=151936,
qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=6144, vocab_size=151936,
        pattern=(("ga", "swiglu"),), n_units=28,
        qk_norm=True, rope_theta=1e6,
        tie_embeddings=True,
    )
