"""xLSTM-1.3B [ssm]: 48 blocks d=2048, mLSTM + sLSTM (7:1), no separate
FFN (d_ff=0; the blocks carry their own up/down projections).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    m = ("mlstm", "none")
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
        d_ff=0, vocab_size=50304,
        pattern=(m, m, m, m, m, m, m, ("slstm", "none")),
        n_units=6, mlstm_heads=4,
        supports_long_context=True,
    )
