"""S100K.E30.D50.L5 (Table 1): GraphGen synthetic, 100k graphs, 30 edges,
density 50%, 5 vertex labels, 2 edge labels."""
from repro.configs.msq_aids import MSQConfig


def get_config() -> MSQConfig:
    return MSQConfig(name="msq_s100k", num_graphs=100_000,
                     generator="graphgen", n_vlabels=5, n_elabels=2,
                     num_edges=30, density=0.5, seed=3)
