"""Qwen3-8B [dense]: 36L d=4096 32H GQA kv=8 d_ff=12288 vocab=151936,
qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12288, vocab_size=151936,
        pattern=(("ga", "swiglu"),), n_units=36,
        qk_norm=True, rope_theta=1e6,
    )
