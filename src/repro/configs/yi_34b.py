"""Yi-34B [dense]: 60L d=7168 56H GQA kv=8 d_ff=20480 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab_size=64000,
        pattern=(("ga", "swiglu"),), n_units=60,
        rope_theta=5e6,
    )
