"""The paper's own AIDS configuration (Table 1): 42687 molecule graphs,
avg |V|=25.6 avg |E|=27.5, 62 vertex labels, 3 edge labels; subregion
length l=4, hybrid block size b=16 (Section 7.1)."""
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MSQConfig:
    name: str
    num_graphs: int
    generator: str          # aids_like | graphgen
    n_vlabels: int
    n_elabels: int
    subregion_l: int = 4
    block: int = 16
    fanout: int = 8
    taus: tuple = (1, 2, 3, 4, 5)
    num_queries: int = 50
    # GraphGen params (generator == 'graphgen')
    num_edges: int = 30
    density: float = 0.5
    seed: int = 0
    # sharded serving (ShardedGraphQueryEngine, DESIGN.md §10):
    # 'graph' block-partitions graphs over every mesh axis; 'vocab'
    # additionally splits the dense F_D matrix over 'model' (for wide
    # q-gram vocabularies).  shard_topk sizes the fixed per-device
    # candidate block (overflow falls back to exact ids, so this is a
    # performance knob, not a recall knob).
    sharded_layout: str = "graph"
    shard_topk: int = 256
    # serving FilterSlab layout (DESIGN.md §11): 'dense' keeps the full
    # (B, U) F_D matrix resident, 'hot' keeps only the first hot_d
    # frequency-ordered columns dense (CSR tail corrected per batch),
    # 'packed' keeps the hybrid bit-packed rows and decodes on device.
    # Candidate sets are bit-identical across all three.
    slab_layout: str = "dense"
    hot_d: int = 128
    # data-tuned hot-prefix width: when set, hot_d is ignored and H is the
    # smallest frequency-ordered prefix covering this fraction of the
    # dataset's degree-q-gram count mass (core.slab.hot_d_from_mass) —
    # per-dataset instead of one fixed width.
    hot_mass: Optional[float] = None
    # persisted (qb, bb, bu) tile table for the query-batched fused filter
    # kernel (kernels.qgram_filter.autotune, DESIGN.md §13).  None = the
    # repo default path (artifacts/tune/qgram_filter.json); a missing file
    # falls back to the built-in default tiles, so tuning is always
    # optional.
    tile_tune_path: Optional[str] = None
    # stage-1.5 batched assignment lower bound (DESIGN.md §16): a
    # device-batched Hausdorff branch bound between the q-gram filter and
    # A* verification.  Provable (LB <= GED), so match sets are
    # bit-identical with it on or off — it only prunes/tightens the
    # verification worklist.  lb_hungarian > 0 additionally runs the exact
    # Hungarian assignment on that many top-LB survivors per query (a
    # tighter bound, host-side, off by default).
    assign_lb: bool = True
    lb_hungarian: int = 0
    # persisted (qb, bb) tile table for the assignment-LB kernel
    # (kernels.assign_lb.autotune); None = artifacts/tune/assign_lb.json.
    lb_tune_path: Optional[str] = None

    def tile_table(self):
        """The autotuned TileTable this config serves with (lazy import —
        configs stay jax-free until a kernel path actually needs it)."""
        from repro.kernels.qgram_filter.autotune import load_tile_table
        return load_tile_table(self.tile_tune_path)

    def lb_tile_table(self):
        """The assignment-LB kernel's (qb, bb) TileTable (lazy import)."""
        from repro.kernels.assign_lb.autotune import load_tile_table
        return load_tile_table(self.lb_tune_path)


def get_config() -> MSQConfig:
    return MSQConfig(name="msq_aids", num_graphs=42687, generator="aids_like",
                     n_vlabels=62, n_elabels=3, hot_mass=0.95)
