"""Checkpointing: chunked, atomic, async, elastic.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json        — tree structure, shapes, dtypes, leaf files
        leaf_00000.npy ...   — one file per pytree leaf (host numpy)
        _COMPLETE            — commit marker (written last)

Properties required at cluster scale (DESIGN.md §5):
  * atomic: written into step_XXXX.tmp, fsync'd, renamed; readers only
    trust directories with the _COMPLETE marker -> a killed writer never
    corrupts the latest checkpoint.
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread — training continues.
  * elastic restore: leaves are stored unsharded (gathered); ``restore``
    re-shards onto whatever mesh/sharding the *new* topology provides, so
    restarts may change device count (tested 8 -> 4 in the suite).
  * retention: keep_last prunes old steps after commit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _tree_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- write ------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host)

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot now, write in background.  Joins any previous write
        first (at most one in flight)."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # device->host now

        def work():
            try:
                self._write(step, host)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def close(self) -> None:
        """Join the in-flight background write and surface its error —
        the shutdown verb the serving/loader classes standardise on
        (``AsyncGraphQueryEngine.close`` / ``ShardedLoader.close``)."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _write(self, step: int, host_tree: Any) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _tree_paths(host_tree)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {"path": path, "file": fname,
                 "shape": list(np.asarray(leaf).shape),
                 "dtype": str(np.asarray(leaf).dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- read ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "_COMPLETE")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like``.  ``shardings`` (a
        matching tree of NamedSharding, or None) enables elastic re-shard:
        the stored unsharded arrays are device_put onto the new topology.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        sh_flat = (jax.tree.flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat))
        out = []
        for (path, like), sh in zip(
                [(jax.tree_util.keystr(p), l) for p, l in flat], sh_flat):
            rec = by_path[path]
            arr = np.load(os.path.join(d, rec["file"]))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
