"""Fault-tolerant training loop.

Responsibilities beyond ``make_train_step``:
  * periodic async checkpointing (atomic, keep-last-k) + resume;
  * retry-from-checkpoint on injected/real step failures (bounded retries);
  * NaN-loss step skipping is inside the jitted step (train/step.py);
  * data loader with straggler double-issue (repro.data.pipeline);
  * metrics log (jsonl) for the benchmarks and examples.

At real cluster scale the same loop runs under multi-process JAX: the
checkpoint layer is host-agnostic (unsharded archival + elastic re-shard on
restore) and the loader reshards by (n_shards, shard_id).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "checkpoints"
    keep_last: int = 3
    log_every: int = 10
    max_retries: int = 3
    metrics_path: Optional[str] = None


class FailureInjector:
    """Deterministically raises on chosen steps (tests/examples)."""

    def __init__(self, fail_steps: Iterable[int] = ()):  # steps that fail once
        self.remaining = set(fail_steps)

    def check(self, step: int) -> None:
        if step in self.remaining:
            self.remaining.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


class Trainer:
    def __init__(self, train_step: Callable, params, opt_state,
                 loader, tcfg: TrainerConfig,
                 failure_injector: Optional[FailureInjector] = None,
                 shardings: Optional[Any] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.loader = loader
        self.tcfg = tcfg
        self.inject = failure_injector
        self.shardings = shardings
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir, tcfg.keep_last)
        self.metrics_log = []
        self.restarts = 0

    # ---- checkpoint plumbing ----------------------------------------------
    def _state(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self, step: int, block: bool = False) -> None:
        if block:
            self.ckpt.save(step, self._state())
        else:
            self.ckpt.save_async(step, self._state())

    def restore_latest(self) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        state, step = self.ckpt.restore(self._state(), shardings=self.shardings)
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        return step

    # ---- the loop ------------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> Dict[str, Any]:
        step = self.restore_latest() if start_step is None else start_step
        fail_counts: Dict[int, int] = {}   # per-step, so deterministic
        t_start = time.perf_counter()      # failures can't retry forever
        while step < self.tcfg.total_steps:
            batch = self.loader.ds.batch(step) if hasattr(self.loader, "ds") \
                else self.loader.batch(step)
            try:
                if self.inject is not None:
                    self.inject.check(step)
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
            except Exception:
                # fault tolerance: reload last good state and retry
                fail_counts[step] = fail_counts.get(step, 0) + 1
                self.restarts += 1
                if fail_counts[step] > self.tcfg.max_retries:
                    raise
                self.ckpt.wait()
                step = self.restore_latest()
                continue
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_ok": int(metrics["step_ok"]),
                       "wall_s": time.perf_counter() - t_start}
                self.metrics_log.append(rec)
                if self.tcfg.metrics_path:
                    with open(self.tcfg.metrics_path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            step += 1
            if step % self.tcfg.checkpoint_every == 0:
                self.save(step)
        self.ckpt.wait()
        self.save(self.tcfg.total_steps, block=True)
        return {"final_step": self.tcfg.total_steps,
                "restarts": self.restarts,
                "metrics": self.metrics_log}
