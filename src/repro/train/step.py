"""Jitted step factories: train (grad + optimizer), prefill, decode.

``make_train_step`` supports:
  * microbatching (scan-accumulated gradients) — required for the MoE
    all_to_all buffers and long-sequence activation footprints;
  * global-norm clipping + NaN/inf skip (the step is rejected and params
    pass through unchanged — fault tolerance at the numerics level);
  * optional int8 gradient compression across the 'pod' axis (shard_map
    psum of quantised grads + dequant, error fed back within the step).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step as model_decode_step
from repro.models.transformer import forward, loss_fn
from repro.optim.optimizers import clip_by_global_norm, global_norm


def make_train_step(cfg: ModelConfig, opt_update, *, par=None,
                    microbatches: int = 1, clip_norm: float = 1.0,
                    skip_nonfinite: bool = True):
    def compute_grads(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, cfg, batch, par)

        def micro(c, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, cfg, mb, par)
            acc_loss, acc_g = c
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), mbs)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt_update(grads, opt_state, params)
        if skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
        else:
            ok = jnp.bool_(True)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step_ok": ok.astype(jnp.int32)}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, par=None):
    """Forward over the full prompt -> logits (cache construction is the
    same compute; the dry-run lowers this for prefill_32k)."""

    def prefill_step(params, batch):
        return forward(params, cfg, batch["inputs"],
                       enc_inputs=batch.get("enc_inputs"), par=par)

    return prefill_step


def make_decode_step(cfg: ModelConfig, par=None):
    def decode_one(params, token, cache, pos, enc_out=None):
        return model_decode_step(params, cfg, token, cache, pos,
                                 enc_out=enc_out, par=par)

    return decode_one
