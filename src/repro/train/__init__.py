from repro.train.checkpoint import CheckpointManager
from repro.train.step import make_decode_step, make_prefill_step, make_train_step
from repro.train.trainer import FailureInjector, Trainer, TrainerConfig

__all__ = ["CheckpointManager", "make_train_step", "make_prefill_step",
           "make_decode_step", "Trainer", "TrainerConfig", "FailureInjector"]
