from repro.data.pipeline import (SyntheticLMDataset, ShardedLoader,
                                 StragglerSimulator)

__all__ = ["SyntheticLMDataset", "ShardedLoader", "StragglerSimulator"]
