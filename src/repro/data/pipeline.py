"""Deterministic data pipeline with host sharding, prefetch and straggler
mitigation.

* ``SyntheticLMDataset`` — reproducible token streams (per-shard seeded);
  also produces frontend-stub embedding inputs for [audio]/[vlm] archs.
* ``ShardedLoader`` — background prefetch with speculative double-issue:
  if a shard read exceeds ``straggler_timeout_s``, the same batch index is
  re-issued to a hot spare worker and the first result wins — bounded-delay
  semantics matching what a multi-host input service needs at 1000+ nodes.
* ``StragglerSimulator`` — fault injection for the tests.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np


class SyntheticLMDataset:
    """Deterministic synthetic next-token batches.

    Per (shard, batch_index) seeding: any host can regenerate any batch —
    elastic rescale just changes the shard grid, no data loss or dup.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 n_shards: int = 1, shard_id: int = 0, seed: int = 0,
                 embed_dim: Optional[int] = None):
        assert global_batch % n_shards == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_shards
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self.embed_dim = embed_dim

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + index) * 65_537 + self.shard_id)
        toks = rng.integers(0, self.vocab,
                            (self.local_batch, self.seq + 1)).astype(np.int32)
        out = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
        if self.embed_dim is not None:
            out["enc_inputs"] = rng.normal(
                size=(self.local_batch, self.seq, self.embed_dim)
            ).astype(np.float32)
        return out


class StragglerSimulator:
    """Injects delays into loader reads (tests / demos)."""

    def __init__(self, slow_every: int = 0, delay_s: float = 0.0):
        self.slow_every = slow_every
        self.delay_s = delay_s

    def maybe_stall(self, index: int) -> None:
        if self.slow_every and index % self.slow_every == self.slow_every - 1:
            time.sleep(self.delay_s)


class ShardedLoader:
    """Prefetching loader with speculative re-issue of slow reads."""

    def __init__(self, dataset: SyntheticLMDataset, prefetch: int = 2,
                 straggler_timeout_s: float = 5.0,
                 straggler: Optional[StragglerSimulator] = None):
        self.ds = dataset
        self.prefetch = prefetch
        self.timeout = straggler_timeout_s
        self.straggler = straggler
        self.reissues = 0
        # live reader threads (daemonized; pruned per batch, joined by
        # close() so lifecycle is deterministic, not exit-time luck)
        self._readers: List[threading.Thread] = []

    def _read(self, index: int, out_q: "queue.Queue", attempt: int) -> None:
        if self.straggler is not None and attempt == 0:
            self.straggler.maybe_stall(index)
        out_q.put((index, self.ds.batch(index)))

    def _spawn(self, index: int, q: "queue.Queue",
               attempt: int) -> threading.Thread:
        self._readers = [t for t in self._readers if t.is_alive()]
        t = threading.Thread(target=self._read, args=(index, q, attempt),
                             daemon=True)
        self._readers.append(t)
        t.start()
        return t

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Join any still-running reader (a stalled speculative loser may
        outlive its batch) — same shutdown semantics as
        ``AsyncGraphQueryEngine.close``; safe to call repeatedly."""
        for t in self._readers:
            t.join(timeout)
        self._readers = [t for t in self._readers if t.is_alive()]

    def __enter__(self) -> "ShardedLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate()

    def iterate(self, start: int = 0, stop: Optional[int] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        index = start
        while stop is None or index < stop:
            q: "queue.Queue" = queue.Queue()
            self._spawn(index, q, 0)
            try:
                _, batch = q.get(timeout=self.timeout)
            except queue.Empty:
                # speculative double-issue: spare worker, first result wins
                self.reissues += 1
                self._spawn(index, q, 1)
                _, batch = q.get()
            yield batch
            index += 1
