"""Model assembly: spec tree, forward / prefill / decode, loss.

Layer stack = unscanned ``prefix`` blocks + ``lax.scan`` over ``n_units``
repetitions of the block ``pattern`` (params stacked over a leading
'layers' axis).  Scan keeps the HLO (and compile time / memory) O(pattern)
instead of O(depth) — the production norm for deep stacks; the dry-run's
roofline extraction multiplies in-loop costs by the trip count
(launch/dryrun.py).

Caches mirror the stack: a dict {'prefix': [...], 'units': stacked-tree}.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.config import Block, ModelConfig
from repro.models.layers import (ParamSpec, dense, embed, init_params,
                                 logical_axes, make_embedding, make_rmsnorm,
                                 rmsnorm, shapes_of, spec_tree_map)


# ==========================================================================
# spec construction
# ==========================================================================

def _mixer_spec(cfg: ModelConfig, kind: str):
    if kind in ("ga", "la", "xattn"):
        return B.attn_spec(cfg)
    if kind == "rglru":
        return B.rglru_spec(cfg)
    if kind == "mlstm":
        return B.mlstm_spec(cfg)
    if kind == "slstm":
        return B.slstm_spec(cfg)
    raise ValueError(kind)


def _ffn_spec(cfg: ModelConfig, kind: str):
    if kind == "swiglu":
        return B.swiglu_spec(cfg)
    if kind == "moe":
        return B.moe_spec(cfg)
    if kind == "none":
        return None
    raise ValueError(kind)


def block_spec(cfg: ModelConfig, blk: Block, cross: bool = False) -> Dict[str, Any]:
    mixer, ffn = blk
    spec: Dict[str, Any] = {
        "norm1": make_rmsnorm(cfg.d_model),
        "mixer": _mixer_spec(cfg, mixer),
    }
    if cross:
        spec["norm_x"] = make_rmsnorm(cfg.d_model)
        spec["xattn"] = B.attn_spec(cfg)
    f = _ffn_spec(cfg, ffn)
    if f is not None:
        spec["norm2"] = make_rmsnorm(cfg.d_model)
        spec["ffn"] = f
    return spec


def stack_spec(tree: Any, n: int) -> Any:
    return spec_tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale), tree)


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {
        "embed": make_embedding(cfg.vocab_size, cfg.d_model),
        "final_norm": make_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"))
    if cfg.prefix:
        spec["prefix"] = {str(i): block_spec(cfg, b, cross=cfg.is_encdec)
                          for i, b in enumerate(cfg.prefix)}
    unit = {f"b{i}": block_spec(cfg, b, cross=cfg.is_encdec)
            for i, b in enumerate(cfg.pattern)}
    spec["units"] = stack_spec(unit, cfg.n_units)
    if cfg.is_encdec:
        enc_unit = {f"b{i}": block_spec(cfg, b)
                    for i, b in enumerate(cfg.enc_pattern)}
        spec["enc"] = {
            "units": stack_spec(enc_unit, cfg.n_enc_units),
            "final_norm": make_rmsnorm(cfg.d_model),
        }
    return spec


# ==========================================================================
# block application
# ==========================================================================

def _apply_block(params, x, cfg: ModelConfig, blk: Block, *,
                 cache=None, pos=None, enc_out=None, par=None):
    mixer, ffn = blk
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = None
    if mixer in ("ga", "la"):
        window = cfg.local_window if mixer == "la" else 0
        att_cache = None if cache is None else cache.get("attn")
        y, new_attn = B.attn_apply(params["mixer"], h, cfg, causal=True,
                                   window=window, cache=att_cache, pos=pos,
                                   par=par)
        new_cache = {"attn": new_attn}
    elif mixer == "rglru":
        y, st = B.rglru_apply(params["mixer"], h, cfg,
                              cache=None if cache is None else cache.get("rec"))
        new_cache = {"rec": st}
    elif mixer == "mlstm":
        y, st = B.mlstm_apply(params["mixer"], h, cfg,
                              cache=None if cache is None else cache.get("rec"))
        new_cache = {"rec": st}
    elif mixer == "slstm":
        y, st = B.slstm_apply(params["mixer"], h, cfg,
                              cache=None if cache is None else cache.get("rec"))
        new_cache = {"rec": st}
    else:
        raise ValueError(mixer)
    x = x + y
    if enc_out is not None and "xattn" in params:
        h = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        y, _ = B.attn_apply(params["xattn"], h, cfg, causal=False,
                            kv_x=enc_out)
        x = x + y
    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "swiglu":
            y = B.swiglu_apply(params["ffn"], h)
        elif ffn == "moe":
            if par is not None:
                y = par.moe(params["ffn"], h, cfg)
            else:
                y = B.moe_apply(params["ffn"], h, cfg)
        else:
            raise ValueError(ffn)
        x = x + y
    return x, new_cache


def _apply_unit(params, x, cfg: ModelConfig, *, cache=None, pos=None,
                enc_out=None, par=None, pattern=None):
    pattern = pattern or cfg.pattern
    if par is not None and cache is None:
        x = par.shard_act(x)   # remat-stash sequence sharding (§Perf-B)
    new_caches = {}
    for i, blk in enumerate(pattern):
        c = None if cache is None else cache.get(f"b{i}")
        x, nc = _apply_block(params[f"b{i}"], x, cfg, blk, cache=c, pos=pos,
                             enc_out=enc_out, par=par)
        new_caches[f"b{i}"] = nc
    return x, new_caches


# ==========================================================================
# forward passes
# ==========================================================================

def _embed_inputs(params, cfg: ModelConfig, inputs):
    """inputs: token ids (B, S) or precomputed embeddings (B, S, d) for
    the [audio]/[vlm] frontend stubs."""
    if cfg.frontend == "embed_stub" and inputs.ndim == 3:
        return inputs.astype(_dt(cfg))
    return embed(params["embed"], inputs).astype(_dt(cfg))


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def encode(params, cfg: ModelConfig, enc_inputs) -> jax.Array:
    x = _embed_inputs(params, cfg, enc_inputs)

    def unit_fn(x, unit_params):
        # encoder: bidirectional local attention pattern
        for i, blk in enumerate(cfg.enc_pattern):
            h = rmsnorm(unit_params[f"b{i}"]["norm1"], x, cfg.norm_eps)
            y, _ = B.attn_apply(unit_params[f"b{i}"]["mixer"], h, cfg,
                                causal=False)
            x = x + y
            h = rmsnorm(unit_params[f"b{i}"]["norm2"], x, cfg.norm_eps)
            x = x + B.swiglu_apply(unit_params[f"b{i}"]["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(unit_fn, cfg), x,
                        params["enc"]["units"])
    return rmsnorm(params["enc"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, inputs, enc_inputs=None,
            par=None) -> jax.Array:
    """Full-sequence forward -> logits (B, S, vocab)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, enc_inputs)
    x = _embed_inputs(params, cfg, inputs)
    for i, blk in enumerate(cfg.prefix):
        x, _ = _apply_block(params["prefix"][str(i)], x, cfg, blk,
                            enc_out=enc_out, par=par)

    def unit_fn(x, unit_params):
        y, _ = _apply_unit(unit_params, x, cfg, enc_out=enc_out,
                           par=par)
        return y, None

    x, _ = jax.lax.scan(_maybe_remat(unit_fn, cfg), x, params["units"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = dense(params["head"], x)
    return logits


def loss_fn(params, cfg: ModelConfig, batch, par=None) -> jax.Array:
    """Mean next-token cross-entropy.  batch: {'inputs', 'targets',
    optional 'enc_inputs'}; targets -100 = masked."""
    logits = forward(params, cfg, batch["inputs"],
                     enc_inputs=batch.get("enc_inputs"), par=par)
    targets = batch["targets"]
    valid = targets >= 0
    tsafe = jnp.where(valid, targets, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tsafe[..., None], axis=-1)[..., 0]
    return -(ll * valid).sum() / jnp.maximum(valid.sum(), 1)


# ==========================================================================
# serving: prefill + decode with structured caches
# ==========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    def one_block(blk: Block):
        mixer, _ = blk
        if mixer == "ga":
            return {"attn": B.init_attn_cache(cfg, batch, max_len)}
        if mixer == "la":
            return {"attn": B.init_attn_cache(cfg, batch, max_len,
                                              window=cfg.local_window)}
        if mixer == "rglru":
            return {"rec": B.init_rglru_cache(cfg, batch)}
        if mixer == "mlstm":
            return {"rec": B.init_mlstm_cache(cfg, batch)}
        if mixer == "slstm":
            return {"rec": B.init_slstm_cache(cfg, batch)}
        raise ValueError(mixer)

    cache: Dict[str, Any] = {}
    if cfg.prefix:
        cache["prefix"] = {str(i): one_block(b)
                           for i, b in enumerate(cfg.prefix)}
    unit = {f"b{i}": one_block(b) for i, b in enumerate(cfg.pattern)}
    cache["units"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_units,) + x.shape), unit)
    return cache


def decode_step(params, cfg: ModelConfig, token, cache, pos,
                enc_out=None, par=None):
    """One decode step.  token: (B, 1) ids (or (B, 1, d) stub embeddings);
    pos: scalar int32 current position.  Returns (logits, new_cache)."""
    x = _embed_inputs(params, cfg, token)
    new_cache: Dict[str, Any] = {}
    if cfg.prefix:
        new_cache["prefix"] = {}
        for i, blk in enumerate(cfg.prefix):
            x, nc = _apply_block(params["prefix"][str(i)], x, cfg, blk,
                                 cache=cache["prefix"][str(i)], pos=pos,
                                 enc_out=enc_out, par=par)
            new_cache["prefix"][str(i)] = nc

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        y, nc = _apply_unit(unit_params, x, cfg, cache=unit_cache, pos=pos,
                            enc_out=enc_out, par=par)
        return y, nc

    x, new_unit_caches = jax.lax.scan(unit_fn, x,
                                      (params["units"], cache["units"]))
    new_cache["units"] = new_unit_caches
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = dense(params["head"], x)
    return logits, new_cache


# ==========================================================================
# convenience
# ==========================================================================

def build_params(cfg: ModelConfig, key: jax.Array):
    return init_params(model_spec(cfg), key, _dt(cfg))


def build_shapes(cfg: ModelConfig):
    return shapes_of(model_spec(cfg), _dt(cfg))


def param_logical_axes(cfg: ModelConfig):
    return logical_axes(model_spec(cfg))
