"""Composable LM model stack (DESIGN.md §2): config, blocks, assembly."""

from repro.models.config import ModelConfig
from repro.models.transformer import (build_params, build_shapes, decode_step,
                                      forward, init_cache, loss_fn,
                                      model_spec, param_logical_axes)

__all__ = [
    "ModelConfig",
    "build_params",
    "build_shapes",
    "decode_step",
    "forward",
    "init_cache",
    "loss_fn",
    "model_spec",
    "param_logical_axes",
]
