"""Model configuration covering every assigned architecture family.

A model is: optional modality frontend stub -> embedding -> a few unscanned
``prefix`` blocks -> ``n_units`` repetitions of a block ``pattern`` (scanned;
params stacked over units) -> final norm -> LM head.

Block descriptor = (mixer, ffn):
  mixer: 'ga' global attention | 'la' local (sliding-window) attention |
         'rglru' RG-LRU recurrence | 'mlstm' | 'slstm' | 'xattn' (cross)
  ffn:   'swiglu' | 'moe' | 'none'
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

Block = Tuple[str, str]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # layer stack
    pattern: Tuple[Block, ...] = (("ga", "swiglu"),)
    n_units: int = 1                 # scanned repetitions of `pattern`
    prefix: Tuple[Block, ...] = ()   # unscanned leading blocks
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    local_window: int = 1024
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_experts: int = 0
    capacity_factor: float = 1.25
    # recurrent (RG-LRU / xLSTM)
    lru_width: Optional[int] = None
    conv1d_width: int = 4
    mlstm_heads: int = 4
    # encoder-decoder
    n_enc_units: int = 0
    enc_pattern: Tuple[Block, ...] = ()
    # frontend stub for [audio]/[vlm]: inputs arrive as precomputed
    # frame/patch embeddings when 'embed_stub'; 'tokens' = ordinary ids
    frontend: str = "tokens"
    # numerics / lowering
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "full"       # full | dots (checkpoint_dots)
    attn_impl: str = "auto"          # 'xla' for dry-run lowering; 'pallas' on TPU
    tie_embeddings: bool = False
    # skip flags (assignment notes)
    supports_long_context: bool = False   # sub-quadratic decode path exists

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + self.n_units * len(self.pattern)

    @property
    def num_enc_layers(self) -> int:
        return self.n_enc_units * len(self.enc_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_units > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP model (for roofline §Roofline) ---------
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        qkvo = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d

        def ffn_params(kind: str) -> int:
            if kind == "swiglu":
                return 3 * d * self.d_ff
            if kind == "moe":
                per = 3 * d * self.expert_d_ff
                return (self.n_experts + self.shared_experts) * per \
                    + d * self.n_experts  # router
            return 0

        def mixer_params(kind: str) -> int:
            if kind in ("ga", "la", "xattn"):
                return qkvo
            if kind == "rglru":
                w = self.lru_width or d
                # in/out proj + gates + conv
                return 2 * d * w + 2 * w * w // 1 + self.conv1d_width * w
            if kind in ("mlstm",):
                w = 2 * d  # up-projection factor 2
                return (2 * d * w + w * d + 3 * w * w
                        + 2 * w * self.mlstm_heads + w)
            if kind == "slstm":
                return 8 * d * d + d * d + d
            return 0

        def block_params(b: Block) -> int:
            m, f = b
            return mixer_params(m) + ffn_params(f) + 2 * d  # 2 norms

        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        for b in self.prefix:
            total += block_params(b)
        for b in self.pattern:
            total += block_params(b) * self.n_units
        for b in self.enc_pattern:
            total += block_params(b) * self.n_enc_units
        if self.is_encdec:
            total += self.num_layers * qkvo  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.expert_d_ff
        inactive = (self.n_experts - self.top_k) * per_expert
        n_moe_blocks = sum(1 for b in self.prefix if b[1] == "moe") \
            + self.n_units * sum(1 for b in self.pattern if b[1] == "moe")
        return self.param_count() - n_moe_blocks * inactive
