"""Parameter machinery + primitive layers.

Params are plain dict pytrees.  Every model first builds a ``ParamSpec``
tree (shape + logical axes + init), from which we derive
  * real initialised params (smoke tests, examples),
  * ShapeDtypeStructs (dry-run: no allocation),
  * NamedShardings via logical-axis rules (launch/shardings.py).
This guarantees params / shapes / shardings never drift.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones
    scale: Optional[float] = None     # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn: Callable[[ParamSpec], Any], tree: Any) -> Any:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def shapes_of(tree: Any, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStruct tree (dry-run path; no allocation)."""
    return spec_tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def init_params(tree: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    """Materialise real parameters (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
        scale = s.scale if s.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dtype)

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, keys)])


def logical_axes(tree: Any) -> Any:
    """Tree of logical-axes tuples mirroring the params."""
    return spec_tree_map(lambda s: s.axes, tree)


# --------------------------------------------------------------------------
# primitive layers (apply functions over dict params)
# --------------------------------------------------------------------------

def rmsnorm_spec() -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((0,), (None,), "ones")}  # shape fixed later


def make_rmsnorm(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def make_dense(d_in: int, d_out: int, axes: Tuple[Optional[str], Optional[str]]
               ) -> ParamSpec:
    return ParamSpec((d_in, d_out), axes)


def dense(w, x):
    return jnp.einsum("...i,io->...o", x, w.astype(x.dtype))


def make_embedding(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)


def embed(w, ids):
    return jnp.take(w, ids, axis=0)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# activation checkpointing policy
# --------------------------------------------------------------------------

def remat_policy(name: str):
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(name)
