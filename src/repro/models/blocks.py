"""Block library: mixers + FFNs, each with ParamSpec builder and apply fn.

Every mixer supports two modes:
  * full-sequence forward (train / prefill) — returns (y, cache)
  * single-step decode — ``x`` is (B, 1, d); consumes + updates cache.

Caches are dict pytrees with static shapes (ring buffers for local
attention; constant-size recurrent states for RG-LRU / xLSTM), so decode
steps lower to fixed-shape HLO for any context length.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_compat as jc
from repro.models.config import ModelConfig
from repro.models.layers import (ParamSpec, apply_rope, dense, make_dense,
                                 make_rmsnorm, rmsnorm)

Cache = Optional[Dict[str, Any]]


# ==========================================================================
# attention (GQA + qk_norm + RoPE + optional sliding window + cross-attn)
# ==========================================================================

def attn_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.hd
    spec = {
        "wq": ParamSpec((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), (None,), "ones")
        spec["k_norm"] = ParamSpec((hd,), (None,), "ones")
    return spec


def _qk_normalize(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    window: int = 0) -> Dict[str, Any]:
    """window > 0: ring buffer of that size; else full-length cache."""
    length = min(window, max_len) if window > 0 else max_len
    shape = (batch, cfg.n_kv_heads, length, cfg.hd)
    return {
        "k": jnp.zeros(shape, jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
        "v": jnp.zeros(shape, jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
    }


def attn_apply(params, x, cfg: ModelConfig, *, causal: bool = True,
               window: int = 0, cache: Cache = None,
               pos: Optional[jax.Array] = None,
               kv_x: Optional[jax.Array] = None,
               par=None) -> Tuple[jax.Array, Cache]:
    """x: (B, S, d).  Decode mode iff ``cache`` is not None and S == 1 — the
    new k/v are written at ``pos`` (ring position for local layers).
    ``kv_x`` switches to cross-attention (no cache update semantics of
    self-attn; encoder memory is precomputed once).
    """
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, d = x.shape
    hd = cfg.hd
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", src, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = _qk_normalize(q, params["q_norm"], cfg.norm_eps)
        k = _qk_normalize(k, params["k_norm"], cfg.norm_eps)
    if kv_x is None:  # rope only for self-attention
        if pos is None:
            positions = jnp.arange(S, dtype=jnp.int32)
        else:
            positions = pos + jnp.arange(S, dtype=jnp.int32)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and kv_x is None:
        length = cache["k"].shape[2]
        if pos is None:
            raise ValueError("decode requires pos")
        write_at = jnp.mod(pos, length) if window > 0 else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, write_at, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, write_at, 0))
        new_cache = {"k": ck, "v": cv}
        # decode attention over the cache (mask invalid/future slots)
        scale = hd ** -0.5
        s = jnp.einsum("bhsk,bhtk->bhst", q.astype(jnp.float32) * scale,
                       ck.astype(jnp.float32).repeat(
                           cfg.n_heads // cfg.n_kv_heads, axis=1))
        slots = jnp.arange(length)
        if window > 0:
            # ring buffer slot t holds global position p iff p ≡ t (mod L)
            # and pos - L < p <= pos; valid slots: within last min(pos+1, L)
            age = jnp.mod(write_at - slots, length)  # 0 = newest
            valid = (age < jnp.minimum(pos + 1, length)) & (age < window)
        else:
            valid = slots <= pos
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bhtk->bhsk", p.astype(jnp.float32),
                       cv.astype(jnp.float32).repeat(
                           cfg.n_heads // cfg.n_kv_heads, axis=1))
        o = o.astype(x.dtype)
    elif cfg.attn_impl == "skip":
        # roofline instrumentation: identity attention (projections kept).
        # The delta between 'xla' and 'skip' unit compiles isolates the
        # attention-matrix cost, which the Pallas kernel replaces with its
        # analytic VMEM-resident traffic (hlo_costs.attention_adjustment).
        o = q
    else:
        if par is not None and kv_x is None:
            q = par.shard_attn_q(q)   # context parallelism (§Perf-A)
            k, v = par.shard_attn_kv(k, v)
        o = flash_attention(q, k, v, causal=causal and kv_x is None,
                            window=window, impl=cfg.attn_impl)
        if par is not None and kv_x is None:
            o = par.shard_attn_out(o)
        if cache is not None:
            new_cache = cache
        elif kv_x is None:
            # prefill: emit the cache for subsequent decode
            new_cache = {"k": k, "v": v}
    y = jnp.einsum("bhsk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return y, new_cache


# ==========================================================================
# SwiGLU FFN
# ==========================================================================

def swiglu_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def swiglu_apply(params, x):
    g = dense(params["w_gate"], x)
    u = dense(params["w_up"], x)
    return dense(params["w_down"], jax.nn.silu(g) * u)


# ==========================================================================
# Mixture of Experts (token-choice top-k, dropless grouped matmul)
# ==========================================================================

def moe_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, E = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    spec = {
        "router": ParamSpec((d, E), ("embed", "experts")),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.shared_experts:
        spec["shared"] = swiglu_spec(cfg, cfg.expert_d_ff * cfg.shared_experts)
    return spec


def moe_apply(params, x, cfg: ModelConfig) -> jax.Array:
    """Dropless token-choice MoE via sort + ragged grouped matmul.

    Data-dependent values, static shapes: jit/pjit-safe.  Under GSPMD the
    expert weights shard over ('expert' -> model axis); the EP all_to_all
    variant lives in ``moe_apply_ep`` (explicit shard_map collectives).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, d)
    logits = dense(params["router"], xt).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (N, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_e = expert_idx.reshape(-1)                          # (N*k,)
    order = jnp.argsort(flat_e)
    token_of = order // k
    xs = jnp.take(xt, token_of, axis=0)                     # (N*k, d)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    g = jax.lax.ragged_dot(xs, params["w_gate"].astype(xs.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, params["w_up"].astype(xs.dtype), group_sizes)
    h = jax.nn.silu(g) * u
    out = jax.lax.ragged_dot(h, params["w_down"].astype(xs.dtype), group_sizes)
    gates = jnp.take(gate_vals.reshape(-1), order, axis=0)
    y = jnp.zeros((N, d), x.dtype).at[token_of].add(
        out * gates[:, None].astype(out.dtype))
    if cfg.shared_experts:
        y = y + swiglu_apply(params["shared"], xt)
    return y.reshape(B, S, d)


def moe_apply_ep(params, x, cfg: ModelConfig, ep_axis: str,
                 capacity_factor: Optional[float] = None,
                 pre_sharded: bool = False) -> jax.Array:
    """Expert-parallel MoE body for use INSIDE shard_map (GShard-style).

    ``x`` arrives batch-sharded over the DP axes and REPLICATED along
    ``ep_axis`` — the body first takes this shard's 1/M token slice
    (sequence-sharded MoE), so routing + dispatch work is divided across
    the EP group instead of replicated.

    Dispatch: per-(source, expert) capacity slots -> (E, cap, d) send
    buffer -> all_to_all over the expert-owner dim -> per-local-expert
    batched einsum (honest grouped-matmul FLOPs; ragged_dot lowers dense
    per group off-TPU) -> all_to_all back -> gate-weighted combine ->
    all_gather of the token slices.  Overflow beyond capacity is dropped
    (standard capacity-factor semantics).
    """
    M = jc.named_axis_size(ep_axis)
    me = jax.lax.axis_index(ep_axis)
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = params["w_gate"].shape[0]
    assert E_local * M == E, (E_local, M, E)
    N = B * S
    cf = capacity_factor or cfg.capacity_factor

    xt = x.reshape(N, d)
    if pre_sharded:
        # caller already sequence-sharded the activations over ep_axis
        # (act_seq_shard): x IS this shard's token slice — no slice/gather.
        n_loc = N
        x_loc = xt
        pad_n = 0
    else:
        pad_n = (-N) % M
        if pad_n:
            xt = jnp.pad(xt, ((0, pad_n), (0, 0)))
        n_loc = (N + pad_n) // M
        x_loc = jax.lax.dynamic_slice_in_dim(xt, me * n_loc, n_loc, axis=0)

    logits = dense(params["router"], x_loc).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (n_loc, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(np.ceil(n_loc * k / E * cf)), 1)
    flat_e = expert_idx.reshape(-1)                        # (n_loc*k,)
    order = jnp.argsort(flat_e)
    sorted_e = jnp.take(flat_e, order, axis=0)
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(n_loc * k) - jnp.take(start, sorted_e, axis=0)
    keep = rank < cap
    tok = order // k                                       # local token id

    # cap+1 column: dropped entries land in the spill slot, then sliced off
    send_x = jnp.zeros((E, cap + 1, d), x.dtype)
    send_tok = jnp.full((E, cap + 1), -1, jnp.int32)
    send_gate = jnp.zeros((E, cap + 1), jnp.float32)
    cidx = jnp.where(keep, rank, cap)
    send_x = send_x.at[sorted_e, cidx].set(jnp.take(x_loc, tok, axis=0))
    send_tok = send_tok.at[sorted_e, cidx].set(tok)
    send_gate = send_gate.at[sorted_e, cidx].set(
        jnp.take(gate_vals.reshape(-1), order, axis=0))
    send_x = send_x[:, :cap]
    send_tok = send_tok[:, :cap]
    send_gate = send_gate[:, :cap]

    # exchange: (M, E_local, cap, d) along the expert-owner dim
    recv_x = jax.lax.all_to_all(send_x.reshape(M, E_local, cap, d), ep_axis,
                                0, 0, tiled=False)
    tokens_e = recv_x.transpose(1, 0, 2, 3).reshape(E_local, M * cap, d)

    g = jnp.einsum("egd,edf->egf", tokens_e,
                   params["w_gate"].astype(tokens_e.dtype))
    u = jnp.einsum("egd,edf->egf", tokens_e,
                   params["w_up"].astype(tokens_e.dtype))
    h = jax.nn.silu(g) * u
    o = jnp.einsum("egf,efd->egd", h,
                   params["w_down"].astype(tokens_e.dtype))

    back = jax.lax.all_to_all(
        o.reshape(E_local, M, cap, d).transpose(1, 0, 2, 3), ep_axis,
        0, 0, tiled=False)                                 # (M, E_local, cap, d)
    back = back.reshape(E * cap, d)

    flat_tok = send_tok.reshape(-1)
    flat_gate = send_gate.reshape(-1)
    contrib = back.astype(jnp.float32) * flat_gate[:, None]
    safe_tok = jnp.where(flat_tok >= 0, flat_tok, 0)
    y_loc = jnp.zeros((n_loc, d), jnp.float32).at[safe_tok].add(
        jnp.where((flat_tok >= 0)[:, None], contrib, 0.0)).astype(x.dtype)

    if pre_sharded:
        y = y_loc
    else:
        y = jax.lax.all_gather(y_loc, ep_axis, axis=0, tiled=True)[:N]
    if cfg.shared_experts:
        y = y + swiglu_apply(params["shared"], xt[:N])
    return y.reshape(B, S, d)


# ==========================================================================
# RG-LRU (RecurrentGemma recurrent block)
# ==========================================================================

def rglru_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_in_x": ParamSpec((d, w), ("embed", "mlp")),       # recurrence branch
        "w_in_y": ParamSpec((d, w), ("embed", "mlp")),       # gate branch
        "conv_w": ParamSpec((cfg.conv1d_width, w), (None, "mlp")),
        "w_a": ParamSpec((w, w), ("mlp", None)),             # recurrence gate
        "w_i": ParamSpec((w, w), ("mlp", None)),             # input gate
        "log_lambda": ParamSpec((w,), (None,), "zeros"),
        "w_out": ParamSpec((w, d), ("mlp", "embed")),
    }


def _rglru_coeffs(params, u, c: float = 8.0):
    """Per-step decay a_t and input i_t from branch activations u (B,S,w)."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, params["w_a"].astype(u.dtype))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, params["w_i"].astype(u.dtype))
                       .astype(jnp.float32))
    log_a = -c * jax.nn.softplus(params["log_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gate = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, gate * i


def rglru_apply(params, x, cfg: ModelConfig, cache: Cache = None
                ) -> Tuple[jax.Array, Cache]:
    """Full block: conv1d + linear recurrence (associative scan) + GLU out."""
    B, S, d = x.shape
    u = dense(params["w_in_x"], x)                      # (B,S,w) recurrence in
    y_gate = jax.nn.gelu(dense(params["w_in_y"], x))    # (B,S,w)
    # causal conv1d over the recurrence branch
    K = params["conv_w"].shape[0]
    if cache is not None and S == 1:
        hist = jnp.concatenate([cache["conv"], u], axis=1)   # (B, K, w)
        u_conv = jnp.einsum("bkw,kw->bw", hist,
                            params["conv_w"].astype(u.dtype))[:, None]
        new_conv = hist[:, 1:]
    else:
        pad = jnp.zeros((B, K - 1, u.shape[-1]), u.dtype)
        up = jnp.concatenate([pad, u], axis=1)
        u_conv = sum(
            up[:, i:i + S] * params["conv_w"][i].astype(u.dtype)
            for i in range(K))
        new_conv = up[:, S:S + K - 1] if S >= K - 1 else up[:, -(K - 1):]
    a, b = _rglru_coeffs(params, u_conv)
    bx = b * u_conv.astype(jnp.float32)
    if cache is not None and S == 1:
        h = a[:, 0] * cache["state"] + bx[:, 0]
        new_state = h
        h = h[:, None]
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h = hh
        new_state = hh[:, -1]
    out = dense(params["w_out"], (h.astype(x.dtype) * y_gate))
    return out, {"state": new_state, "conv": new_conv}


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    w = cfg.lru_width or cfg.d_model
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {"state": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dt)}


# ==========================================================================
# xLSTM: mLSTM (chunkwise-parallel) and sLSTM (sequential)
# ==========================================================================

def mlstm_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    w = 2 * d                       # up-projection factor 2 (xLSTM paper)
    nh = cfg.mlstm_heads
    dh = w // nh
    return {
        "w_up": ParamSpec((d, w), ("embed", "mlp")),
        "w_gate_up": ParamSpec((d, w), ("embed", "mlp")),
        "wq": ParamSpec((w, nh, dh), ("mlp", "heads", None)),
        "wk": ParamSpec((w, nh, dh), ("mlp", "heads", None)),
        "wv": ParamSpec((w, nh, dh), ("mlp", "heads", None)),
        "w_i": ParamSpec((w, nh), ("mlp", "heads")),
        "w_f": ParamSpec((w, nh), ("mlp", "heads")),
        "out_norm": ParamSpec((w,), ("mlp",), "ones"),
        "w_down": ParamSpec((w, d), ("mlp", "embed")),
    }


def _headwise_norm(h, scale, nh: int, eps: float = 1e-6):
    """GroupNorm-per-head on the cell output (xLSTM's out-norm)."""
    B, S, w = h.shape
    hh = h.reshape(B, S, nh, w // nh).astype(jnp.float32)
    var = jnp.mean(jnp.square(hh), axis=-1, keepdims=True)
    hh = hh * jax.lax.rsqrt(var + eps)
    return (hh.reshape(B, S, w) * scale.astype(jnp.float32)).astype(h.dtype)


def mlstm_apply(params, x, cfg: ModelConfig, cache: Cache = None,
                chunk: int = 256) -> Tuple[jax.Array, Cache]:
    """mLSTM block: matrix memory with exponential gating.

    Full-sequence mode uses a chunkwise formulation: recurrent (C, n, m)
    state across chunks + quadratic in-chunk attention with log-space decay
    (sub-quadratic overall: O(S * chunk)).  Decode mode is the plain
    recurrence.
    """
    B, S, d = x.shape
    nh = cfg.mlstm_heads
    u = dense(params["w_up"], x)
    gate = jax.nn.silu(dense(params["w_gate_up"], x))
    w = u.shape[-1]
    dh = w // nh
    q = jnp.einsum("bsw,whk->bhsk", u, params["wq"].astype(u.dtype))
    k = jnp.einsum("bsw,whk->bhsk", u, params["wk"].astype(u.dtype)) * (dh ** -0.5)
    v = jnp.einsum("bsw,whk->bhsk", u, params["wv"].astype(u.dtype))
    it = jnp.einsum("bsw,wh->bhs", u, params["w_i"].astype(u.dtype)).astype(jnp.float32)
    ft = jnp.einsum("bsw,wh->bhs", u, params["w_f"].astype(u.dtype)).astype(jnp.float32)
    logf = -jax.nn.softplus(-ft)     # log sigmoid(ft)

    if cache is not None and S == 1:
        C, n, m = cache["C"], cache["n"], cache["m"]
        m_new = jnp.maximum(logf[..., 0] + m, it[..., 0])
        fprime = jnp.exp(logf[..., 0] + m - m_new)
        iprime = jnp.exp(it[..., 0] - m_new)
        C = fprime[..., None, None] * C + iprime[..., None, None] * \
            jnp.einsum("bhk,bhv->bhkv", k[:, :, 0].astype(jnp.float32),
                       v[:, :, 0].astype(jnp.float32))
        n = fprime[..., None] * n + iprime[..., None] * k[:, :, 0].astype(jnp.float32)
        hnum = jnp.einsum("bhk,bhkv->bhv", q[:, :, 0].astype(jnp.float32), C)
        hden = jnp.abs(jnp.einsum("bhk,bhk->bh", q[:, :, 0].astype(jnp.float32), n))
        h = hnum / jnp.maximum(hden, jnp.exp(-m_new))[..., None]
        h = h.reshape(B, 1, w).astype(x.dtype)
        h = _headwise_norm(h, params["out_norm"], nh)
        out = dense(params["w_down"], h * gate)
        return out, {"C": C, "n": n, "m": m_new}

    # ---- chunkwise parallel (training / prefill) --------------------------
    pad = (-S) % chunk
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                   for t in (q, k, v))
        it = jnp.pad(it, ((0, 0), (0, 0), (0, pad)))
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nc = Sp // chunk

    def resh(t):
        return t.reshape(B, nh, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    qc, kc, vc = resh(q), resh(k), resh(v)
    ic = it.reshape(B, nh, nc, chunk).transpose(2, 0, 1, 3)
    fc = logf.reshape(B, nh, nc, chunk).transpose(2, 0, 1, 3)

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.full((B, nh), -1e30, jnp.float32)

    def chunk_step(carry, inp):
        C, n, m = carry
        qx, kx, vx, ix, fx = inp
        qx = qx.astype(jnp.float32)
        kx = kx.astype(jnp.float32)
        vx = vx.astype(jnp.float32)
        fcum = jnp.cumsum(fx, axis=-1)                  # (B,nh,T)
        ftot = fcum[..., -1]
        # stabiliser per position: max(inter m + fcum, running intra max)
        intra = ix - fcum                                # log i_j - sum f<=j
        intra_max = jax.lax.cummax(intra, axis=intra.ndim - 1)
        m_t = jnp.maximum(fcum + m[..., None], fcum + intra_max)
        # inter-chunk: h_inter = (q * exp(fcum + m - m_t)) @ C
        w_inter = jnp.exp(fcum + m[..., None] - m_t)
        hi = jnp.einsum("bhtk,bhkv->bhtv", qx * w_inter[..., None], C)
        ni = jnp.einsum("bhtk,bhk->bht", qx * w_inter[..., None], n)
        # intra-chunk: D_tj = exp(fcum_t - fcum_j + i_j - m_t) for j <= t
        logD = (fcum[..., :, None] - fcum[..., None, :]
                + ix[..., None, :] - m_t[..., :, None])
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, None], jnp.exp(logD), 0.0)
        s = jnp.einsum("bhtk,bhjk->bhtj", qx, kx) * D
        ha = jnp.einsum("bhtj,bhjv->bhtv", s, vx)
        na = s.sum(-1)
        denom = jnp.maximum(jnp.abs(ni + na), jnp.exp(-m_t))
        h = (hi + ha) / denom[..., None]
        # carry update (stabilised at chunk end)
        m_end = m_t[..., -1]
        scale_old = jnp.exp(ftot + m - m_end)
        wk_new = jnp.exp(ix - fcum + ftot[..., None] - m_end[..., None])
        C_new = scale_old[..., None, None] * C + jnp.einsum(
            "bhtk,bhtv->bhkv", kx * wk_new[..., None], vx)
        n_new = scale_old[..., None] * n + (kx * wk_new[..., None]).sum(2)
        return (C_new, n_new, m_end), h

    if nc == 1:
        # single chunk: skip the scan so HLO cost analysis sees the body
        # (and decode-prefill of short prompts avoids while overhead)
        _, hs = chunk_step((C0, n0, m0), (qc[0], kc[0], vc[0], ic[0], fc[0]))
        hs = hs[None]
    else:
        (_, _, _), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                     (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, nh, Sp, dh)[:, :, :S]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, w).astype(x.dtype)
    h = _headwise_norm(h, params["out_norm"], nh)
    out = dense(params["w_down"], h * gate)
    return out, None


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    w = 2 * cfg.d_model
    nh = cfg.mlstm_heads
    dh = w // nh
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def slstm_spec(cfg: ModelConfig) -> Dict[str, Any]:
    # sLSTM weights are REPLICATED (axes None): the cell is inherently
    # sequential (a matmul per timestep inside the scan), so sharding its
    # weights over 'model' would emit one psum per token step.  At xLSTM
    # scale the weights are small; replication is the sane layout
    # (see EXPERIMENTS.md roofline notes for xlstm-1.3b).
    d = cfg.d_model
    return {
        "w_gates": ParamSpec((d, 4 * d), (None, None)),       # i, f, z, o
        "r_gates": ParamSpec((d, 4 * d), (None, None), scale=0.0),
        "out_norm": ParamSpec((d,), (None,), "ones"),
        "w_down": ParamSpec((d, d), (None, None)),
    }


def _slstm_step(params, carry, xt):
    """One sLSTM step; xt: (B, d)."""
    c, n, m, h = carry
    z4 = dense(params["w_gates"], xt) + dense(params["r_gates"], h)
    it, ft, zt, ot = jnp.split(z4.astype(jnp.float32), 4, axis=-1)
    logf = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(logf + m, it)
    iprime = jnp.exp(it - m_new)
    fprime = jnp.exp(logf + m - m_new)
    c_new = fprime * c + iprime * jnp.tanh(zt)
    n_new = fprime * n + iprime
    h_new = jax.nn.sigmoid(ot) * (c_new / jnp.maximum(n_new, 1e-6))
    h_new = h_new.astype(xt.dtype)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_apply(params, x, cfg: ModelConfig, cache: Cache = None
                ) -> Tuple[jax.Array, Cache]:
    B, S, d = x.shape
    if cache is not None and S == 1:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
        carry, h = _slstm_step(params, carry, x[:, 0])
        hn = _headwise_norm(h[:, None], params["out_norm"], nh=1)
        out = dense(params["w_down"], hn)
        return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    c0 = jnp.zeros((B, d), jnp.float32)
    m0 = jnp.full((B, d), -1e30, jnp.float32)
    h0 = jnp.zeros((B, d), x.dtype)
    (c, n, m, h), hs = jax.lax.scan(
        functools.partial(_slstm_step, params),
        (c0, c0, m0, h0), x.transpose(1, 0, 2))
    hn = _headwise_norm(hs.transpose(1, 0, 2), params["out_norm"], nh=1)
    out = dense(params["w_down"], hn)
    return out, {"c": c, "n": n, "m": m, "h": h}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    d = cfg.d_model
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
            "h": jnp.zeros((batch, d), dt)}
