"""The q-gram tree (Definition 9) and its succinct representation (Sec 5).

Build path:
  leaves  = per-graph four-tuples LD = (F_D, F_L, n_v, n_e)   (sparse dicts)
  internal = element-wise max union (Definition 8), min n_v / n_e
  succinct = per-node zero/nonzero bitmaps concatenated into B_D / B_L
             (BitVector + rank), nonzero values concatenated into Psi_D /
             Psi_L (HybridEncodedArray), node metadata arrays (l/r global
             bit offsets, n_v, n_e, children ranges).

Query path = Algorithm 1 (searchQTree): the label-count prune (Lemma 6),
the degree-count prune (Lemma 6), the degree-q-gram prune (Lemma 2, leaf),
and the degree-sequence filter (Lemma 5, leaf, via the T_D table).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import filters
from repro.core.qgrams import QGramVocab
from repro.core.succinct import BitVector, HybridEncodedArray


# --------------------------------------------------------------------------
# plain (uncompressed) q-gram tree — the T_Q baseline of Table 3
# --------------------------------------------------------------------------

@dataclass
class TreeNode:
    f_d: Counter          # sparse F_D (id -> count)
    f_l: Counter          # sparse F_L
    n_v: int
    n_e: int
    children: List[int]   # indices into the node list; empty = leaf
    graph_id: int = -1    # valid for leaves


def union_tuple(nodes: Sequence[TreeNode]) -> TreeNode:
    """Definition 8 extended to multiple children."""
    f_d: Counter = Counter()
    f_l: Counter = Counter()
    for nd in nodes:
        for k, v in nd.f_d.items():
            if v > f_d[k]:
                f_d[k] = v
        for k, v in nd.f_l.items():
            if v > f_l[k]:
                f_l[k] = v
    return TreeNode(
        f_d=f_d,
        f_l=f_l,
        n_v=min(nd.n_v for nd in nodes),
        n_e=min(nd.n_e for nd in nodes),
        children=[],
    )


class QGramTree:
    """Balanced bottom-up q-gram tree over a list of leaf four-tuples.

    ``nodes[0]`` is the root; children indices point into ``nodes``.
    """

    def __init__(self, leaves: Sequence[TreeNode], fanout: int = 8):
        if not leaves:
            raise ValueError("empty tree")
        self.fanout = fanout
        level: List[TreeNode] = list(leaves)
        levels: List[List[TreeNode]] = [level]
        while len(level) > 1:
            nxt: List[TreeNode] = []
            for i in range(0, len(level), fanout):
                group = level[i:i + fanout]
                parent = union_tuple(group)
                parent.children = list(range(i, i + len(group)))  # per-level
                nxt.append(parent)
            levels.append(nxt)
            level = nxt
        # flatten top-down (BFS): root first
        self.nodes: List[TreeNode] = []
        offsets: List[int] = []
        for lvl in reversed(levels):
            offsets.append(len(self.nodes))
            self.nodes.extend(lvl)
        # fix child indices to absolute positions
        for li, lvl in enumerate(reversed(levels)):
            if li == len(levels) - 1:
                break  # leaves have no children
            child_off = offsets[li + 1]
            for nd in lvl:
                nd.children = [child_off + c for c in nd.children]
        self.root = 0
        self.n_leaves = len(leaves)

    # ---- Table 3 size accounting (uncompressed T_Q) ----------------------
    def size_bits(self) -> Dict[str, int]:
        """S_a: n_v, n_e + pointers; S_b: F_D arrays; S_c: F_L arrays.

        T_Q stores F_X as plain (dense-length) int arrays per node with
        32-bit entries, matching the in-memory layout the paper compares
        against.
        """
        s_a = s_b = s_c = 0
        for nd in self.nodes:
            s_a += 32 * 2 + 64 * max(len(nd.children), 1)  # n_v,n_e + pointers
            len_d = (max(nd.f_d) + 1) if nd.f_d else 0
            len_l = (max(nd.f_l) + 1) if nd.f_l else 0
            s_b += 32 * len_d
            s_c += 32 * len_l
        return {"S_a": s_a, "S_b": s_b, "S_c": s_c, "total": s_a + s_b + s_c}


def leaves_from_encoded(enc, graph_ids: Sequence[int]) -> List[TreeNode]:
    """Build leaf four-tuples from an EncodedDB for the given graph ids."""
    out = []
    for gid in graph_ids:
        ids, cnt = enc.row_degree(gid)
        f_d = Counter({int(i): int(c) for i, c in zip(ids, cnt)})
        ids, cnt = enc.row_label(gid)
        f_l = Counter({int(i): int(c) for i, c in zip(ids, cnt)})
        out.append(TreeNode(f_d=f_d, f_l=f_l, n_v=int(enc.nv[gid]),
                            n_e=int(enc.ne[gid]), children=[],
                            graph_id=int(gid)))
    return out


# --------------------------------------------------------------------------
# succinct q-gram tree — T_SQ
# --------------------------------------------------------------------------

class SuccinctQGramTree:
    """Succinct representation of a QGramTree (Section 5.2).

    Per-node F_X (X in {D, L}) spans vocabulary ids [0, len_X) where len_X =
    1 + max nonzero id; its zero/nonzero bitmap slice occupies global bit
    positions [l_X, r_X) of B_X, and its nonzero values occupy
    Psi_X[rank1(B_X, l_X) : rank1(B_X, r_X)].
    """

    def __init__(self, tree: QGramTree, vocab: QGramVocab, block: int = 16):
        self.vocab = vocab
        self.block = block
        n = len(tree.nodes)
        self.n_nodes = n
        self.graph_id = np.array([nd.graph_id for nd in tree.nodes], np.int64)
        self.n_v = np.array([nd.n_v for nd in tree.nodes], np.int32)
        self.n_e = np.array([nd.n_e for nd in tree.nodes], np.int32)
        self.child_lo = np.zeros(n, np.int64)
        self.child_hi = np.zeros(n, np.int64)
        for i, nd in enumerate(tree.nodes):
            if nd.children:
                self.child_lo[i] = nd.children[0]
                self.child_hi[i] = nd.children[-1] + 1
        self.root = tree.root

        bits_d: List[np.ndarray] = []
        bits_l: List[np.ndarray] = []
        psi_d: List[int] = []
        psi_l: List[int] = []
        self.l_d = np.zeros(n, np.int64)
        self.r_d = np.zeros(n, np.int64)
        self.l_l = np.zeros(n, np.int64)
        self.r_l = np.zeros(n, np.int64)
        pos_d = pos_l = 0
        for i, nd in enumerate(tree.nodes):
            len_d = (max(nd.f_d) + 1) if nd.f_d else 0
            bm = np.zeros(len_d, np.uint8)
            for k, v in sorted(nd.f_d.items()):
                bm[k] = 1
                psi_d.append(v)
            bits_d.append(bm)
            self.l_d[i] = pos_d
            pos_d += len_d
            self.r_d[i] = pos_d

            len_l = (max(nd.f_l) + 1) if nd.f_l else 0
            bm = np.zeros(len_l, np.uint8)
            for k, v in sorted(nd.f_l.items()):
                bm[k] = 1
                psi_l.append(v)
            bits_l.append(bm)
            self.l_l[i] = pos_l
            pos_l += len_l
            self.r_l[i] = pos_l

        self.B_D = BitVector(np.concatenate(bits_d) if bits_d else np.zeros(0, np.uint8))
        self.B_L = BitVector(np.concatenate(bits_l) if bits_l else np.zeros(0, np.uint8))
        self.Psi_D = HybridEncodedArray(psi_d, block) if psi_d else None
        self.Psi_L = HybridEncodedArray(psi_l, block) if psi_l else None

    # ---- formula (3): F_X[i] for node w -----------------------------------
    def _access_f(self, which: str, node: int, i: int) -> int:
        if which == "D":
            l, r, B, Psi = self.l_d[node], self.r_d[node], self.B_D, self.Psi_D
        else:
            l, r, B, Psi = self.l_l[node], self.r_l[node], self.B_L, self.Psi_L
        p = int(l) + int(i)
        if i < 0 or p >= int(r) or not B.get(p):
            return 0
        return Psi.access(B.rank1(p))

    def f_d(self, node: int, i: int) -> int:
        return self._access_f("D", node, i)

    def f_l(self, node: int, i: int) -> int:
        return self._access_f("L", node, i)

    def _common_count(self, which: str, node: int,
                      q_ids: np.ndarray, q_cnt: np.ndarray) -> int:
        """C_X = sum_i min(F_X[i], F'_X[i]) — iterate the query's nonzeros."""
        if which == "D":
            l, r, B, Psi = self.l_d[node], self.r_d[node], self.B_D, self.Psi_D
        else:
            l, r, B, Psi = self.l_l[node], self.r_l[node], self.B_L, self.Psi_L
        if Psi is None or len(q_ids) == 0:
            return 0
        pos = int(l) + q_ids.astype(np.int64)
        valid = pos < int(r)
        if not valid.any():
            return 0
        pos = pos[valid]
        qc = q_cnt[valid]
        bits = B.get_bulk(pos).astype(bool)
        if not bits.any():
            return 0
        ranks = B.rank1_bulk(pos[bits])
        vals = Psi.access_bulk(ranks)
        return int(np.minimum(vals, qc[bits]).sum())

    def node_f_d_full(self, node: int) -> Tuple[np.ndarray, np.ndarray]:
        """All nonzero (ids, counts) of F_D at a node (Alg 1 lines 14–15)."""
        l, r = int(self.l_d[node]), int(self.r_d[node])
        if self.Psi_D is None or r <= l:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        pos = np.arange(l, r, dtype=np.int64)
        bits = self.B_D.get_bulk(pos).astype(bool)
        ids = pos[bits] - l
        lo = self.B_D.rank1(l)
        hi = self.B_D.rank1(r)
        vals = np.array([self.Psi_D.access(j) for j in range(lo, hi)], np.int64)
        return ids, vals

    # ---- Algorithm 1 -------------------------------------------------------
    def search(self, query_tuple, tau: int, collect_stats: bool = False):
        """searchQTree: returns candidate graph ids (and stats if asked).

        ``query_tuple`` is a ``QueryTuple`` (see below).
        """
        q = query_tuple
        cand: List[int] = []
        stats = {"nodes_visited": 0, "leaves_checked": 0}
        t_d = self.vocab.degree_id_table()
        stack = [self.root]
        while stack:
            w = stack.pop()
            stats["nodes_visited"] += 1
            n_v, n_e = int(self.n_v[w]), int(self.n_e[w])
            # Lemma 6 prune #1: label-based q-grams
            c_l = self._common_count("L", w, q.l_ids, q.l_cnt)
            if c_l < max(n_v, q.nv) + max(n_e, q.ne) - tau:
                continue
            # Lemma 6 prune #2: degree-based q-grams (weak form)
            c_d = self._common_count("D", w, q.d_ids, q.d_cnt)
            if c_d < max(n_v, q.nv) - 2 * tau:
                continue
            if self.child_hi[w] > self.child_lo[w]:  # internal
                stack.extend(range(int(self.child_lo[w]), int(self.child_hi[w])))
                continue
            # leaf: Lemma 2 (full degree-q-gram counting filter)
            stats["leaves_checked"] += 1
            overlap_v = self._vertex_label_overlap(w, q)
            if c_d < 2 * max(n_v, q.nv) - overlap_v - 2 * tau:
                continue
            # leaf: degree-sequence filter (Lemma 5) via T_D
            ids, vals = self.node_f_d_full(w)
            degs = np.repeat(t_d[ids], vals)
            sigma_w = np.sort(degs)[::-1]
            xi = filters.degree_sequence_lb(
                n_v, n_e, sigma_w, q.nv, q.ne, q.sigma, overlap_v)
            # cheap global filters come along for free (n_v/n_e stored):
            xi = max(
                xi,
                filters.number_count_lb(n_v, n_e, q.nv, q.ne),
                filters.label_qgram_lb(n_v, n_e, q.nv, q.ne, c_l),
                filters.degree_qgram_lb(n_v, q.nv, overlap_v, c_d),
            )
            if xi <= tau:
                cand.append(int(self.graph_id[w]))
        if collect_stats:
            return cand, stats
        return cand

    def _vertex_label_overlap(self, node: int, q) -> int:
        """|Sigma_Vw ∩ Sigma_Vh| — the vertex-label part of C_L."""
        sel = q.l_ids < self.vocab.n_vlabels
        return self._common_count("L", node, q.l_ids[sel], q.l_cnt[sel])

    # ---- Table 3 size accounting (T_SQ) ------------------------------------
    def size_bits(self) -> Dict[str, int]:
        n = self.n_nodes
        nbits_bd = max(int(self.B_D.n).bit_length(), 1)
        nbits_bl = max(int(self.B_L.n).bit_length(), 1)
        # S'_a: n_v, n_e, l_D, r_D, l_L, r_L + child pointers
        s_a = n * (32 * 2 + 2 * nbits_bd + 2 * nbits_bl + 64)
        bd = self.B_D.size_bits()["total"]
        bl = self.B_L.size_bits()["total"]
        pd = self.Psi_D.size_bits().total if self.Psi_D else 0
        pl = self.Psi_L.size_bits().total if self.Psi_L else 0
        return {"S_a": s_a, "S_b": bd + pd, "S_c": bl + pl,
                "total": s_a + bd + pd + bl + pl}


# --------------------------------------------------------------------------
# query-side four-tuple
# --------------------------------------------------------------------------

@dataclass
class QueryTuple:
    """LD' of Algorithm 1 plus the degree sequence sigma_h."""

    nv: int
    ne: int
    d_ids: np.ndarray
    d_cnt: np.ndarray
    l_ids: np.ndarray
    l_cnt: np.ndarray
    sigma: np.ndarray

    @classmethod
    def from_graph(cls, h, vocab: QGramVocab) -> "QueryTuple":
        dc = vocab.encode_degree(h)
        known = sorted(k for k in dc if k >= 0)
        lc = vocab.encode_label(h)
        lids = sorted(lc)
        return cls(
            nv=h.n,
            ne=h.m,
            d_ids=np.array(known, np.int64),
            d_cnt=np.array([dc[k] for k in known], np.int64),
            l_ids=np.array(lids, np.int64),
            l_cnt=np.array([lc[k] for k in lids], np.int64),
            sigma=h.degree_sequence().astype(np.int64),
        )
