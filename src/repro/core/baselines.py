"""Competitor filters the paper compares against (Sections 7–8).

* C-Star  (Zeng et al. 2009): star-structure mapping distance;
  L_S(g,h) = s_m(g,h) / max(4, max(d_g, d_h) + 1), where s_m is the
  minimum-weight bipartite matching over star edit distances.
* Branch / Mixed (Zheng et al. 2015): branch structures (vertex label +
  sorted incident edge labels); L_B(g,h) = b_m(g,h) / 2 with branch edit
  cost = [vertex label differs] + |multiset diff of edge labels| / 2.
* GSimJoin (Zhao et al. 2012): path q-grams of length p; counting bound:
  common q-grams >= max(|Q(g)| - gamma_g * tau, |Q(h)| - gamma_h * tau)
  where gamma is the max number of q-grams one edit op can touch.
* kappa-AT (Wang et al. 2012): kappa-adjacent-subtree q-grams, same
  counting principle with gamma = 1 + kappa * d_max^kappa style bound
  (we use the standard kappa=1 star form).

All are admissible lower bounds (tested).  ``index_bits`` methods emulate
each method's index footprint for the Fig-7 comparison.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy.optimize import linear_sum_assignment

from repro.graphs.graph import Graph


# --------------------------------------------------------------------------
# star / branch structures
# --------------------------------------------------------------------------

def star_structures(g: Graph) -> List[Tuple[int, Tuple[int, ...], Tuple[int, ...]]]:
    """Star of v = (label(v), sorted neighbor labels, sorted edge labels)."""
    adjv: List[List[int]] = [[] for _ in range(g.n)]
    adje: List[List[int]] = [[] for _ in range(g.n)]
    for (u, v), l in zip(g.edges, g.elabels):
        adjv[int(u)].append(int(g.vlabels[int(v)]))
        adjv[int(v)].append(int(g.vlabels[int(u)]))
        adje[int(u)].append(int(l))
        adje[int(v)].append(int(l))
    return [(int(g.vlabels[v]), tuple(sorted(adjv[v])), tuple(sorted(adje[v])))
            for v in range(g.n)]


def _star_edit_cost(s1, s2) -> int:
    """Zeng et al.'s lambda: T(r1,r2) + ||L1|-|L2|| + M(L1,L2) over leaf
    vertex labels.  Edge labels are deliberately NOT counted — the
    max(4, dmax+1) normaliser of L_S is proven for exactly this lambda
    (adding terms breaks admissibility; verified by the property tests)."""
    l1, nb1, _el1 = s1
    l2, nb2, _el2 = s2
    cost = int(l1 != l2)
    d1, d2 = len(nb1), len(nb2)
    cost += abs(d1 - d2)
    c1, c2 = Counter(nb1), Counter(nb2)
    inter_n = sum(min(c1[k], c2[k]) for k in c1)
    cost += max(d1, d2) - inter_n
    return cost


def _mapping_distance(items_g: Sequence, items_h: Sequence, cost_fn,
                      eps_cost_g, eps_cost_h) -> float:
    """Min-cost bipartite matching padded with eps items (Hungarian)."""
    n, m = len(items_g), len(items_h)
    size = max(n, m)
    if size == 0:
        return 0.0
    C = np.zeros((size, size), np.float64)
    for i in range(size):
        for j in range(size):
            if i < n and j < m:
                C[i, j] = cost_fn(items_g[i], items_h[j])
            elif i < n:
                C[i, j] = eps_cost_g(items_g[i])
            elif j < m:
                C[i, j] = eps_cost_h(items_h[j])
    r, c = linear_sum_assignment(C)
    return float(C[r, c].sum())


def cstar_lb(g: Graph, h: Graph) -> float:
    """L_S(g,h) = s_m / max(4, max(d_g, d_h) + 1)."""
    sg, sh = star_structures(g), star_structures(h)
    s_m = _mapping_distance(
        sg, sh, _star_edit_cost,
        eps_cost_g=lambda s: 1 + 2 * len(s[1]),
        eps_cost_h=lambda s: 1 + 2 * len(s[1]),
    )
    dg = int(g.degrees().max()) if g.n else 0
    dh = int(h.degrees().max()) if h.n else 0
    return s_m / max(4, max(dg, dh) + 1)


def branch_structures(g: Graph) -> List[Tuple[int, Tuple[int, ...]]]:
    """Branch of v = (label(v), sorted incident edge labels)."""
    adje: List[List[int]] = [[] for _ in range(g.n)]
    for (u, v), l in zip(g.edges, g.elabels):
        adje[int(u)].append(int(l))
        adje[int(v)].append(int(l))
    return [(int(g.vlabels[v]), tuple(sorted(adje[v]))) for v in range(g.n)]


def _branch_edit_cost(b1, b2) -> float:
    l1, e1 = b1
    l2, e2 = b2
    c1, c2 = Counter(e1), Counter(e2)
    inter = sum(min(c1[k], c2[k]) for k in c1)
    return int(l1 != l2) + (max(len(e1), len(e2)) - inter) / 2.0


def branch_lb(g: Graph, h: Graph) -> float:
    """Mixed/Branch filter: L_B = b_m / 2 (Zheng et al. 2015)."""
    bg, bh = branch_structures(g), branch_structures(h)
    b_m = _mapping_distance(
        bg, bh, _branch_edit_cost,
        eps_cost_g=lambda b: 1 + len(b[1]) / 2.0,
        eps_cost_h=lambda b: 1 + len(b[1]) / 2.0,
    )
    return b_m / 2.0


# --------------------------------------------------------------------------
# path q-grams (GSimJoin)
# --------------------------------------------------------------------------

def path_qgrams(g: Graph, p: int = 2) -> Counter:
    """All simple paths with p edges, as label sequences (both directions
    canonicalised).  p=2 default keeps enumeration tractable on dense data.
    """
    adj: List[List[Tuple[int, int]]] = [[] for _ in range(g.n)]
    for (u, v), l in zip(g.edges, g.elabels):
        adj[int(u)].append((int(v), int(l)))
        adj[int(v)].append((int(u), int(l)))
    grams: Counter = Counter()

    def extend(path_v: List[int], labels: List[int]) -> None:
        if (len(path_v) - 1) == p:
            fwd = tuple(labels)
            rev = tuple(reversed(labels))
            grams[min(fwd, rev)] += 1
            return
        last = path_v[-1]
        for (w, el) in adj[last]:
            if w in path_v:
                continue
            extend(path_v + [w],
                   labels + [el, int(g.vlabels[w])])

    for v in range(g.n):
        extend([v], [int(g.vlabels[v])])
    for k in grams:  # each path enumerated from both ends
        grams[k] //= 2
    return grams


def path_qgram_lb(g: Graph, h: Graph, p: int = 2) -> float:
    """Counting bound: if ged <= tau then common >= max(|Qg| - gamma tau,
    |Qh| - gamma tau); rearranged into a lower bound on tau.

    gamma must bound the q-grams an op can touch on ANY graph along the
    edit path (intermediate degrees are bounded by the larger endpoint
    degree here — the conservative shared-gamma form), so it is shared
    between the two sides.
    """
    qg, qh = path_qgrams(g, p), path_qgrams(h, p)
    common = sum(min(qg[k], qh[k]) for k in qg.keys() & qh.keys())
    gamma = _max_qgrams_per_op(g, h, p)
    bound_g = (sum(qg.values()) - common) / gamma
    bound_h = (sum(qh.values()) - common) / gamma
    return max(bound_g, bound_h, 0.0)


def _max_qgrams_per_op(g: Graph, h: Graph, p: int) -> int:
    """gamma: max #path-q-grams one edit op can affect along the path."""
    dg = int(g.degrees().max()) if g.m else 0
    dh = int(h.degrees().max()) if h.m else 0
    dmax = max(dg, dh, 1)
    # an edge op can touch every path through that edge: <= p * dmax^(p-1);
    # any op touches >= 2 endpoint neighbourhoods
    return max(2, p * dmax ** (p - 1))


# --------------------------------------------------------------------------
# kappa-AT (tree q-grams)
# --------------------------------------------------------------------------

def kat_qgrams(g: Graph, kappa: int = 1) -> Counter:
    """kappa-adjacent-subtree q-grams; kappa=1 = (label, sorted nbr labels)."""
    adj: List[List[int]] = [[] for _ in range(g.n)]
    for (u, v) in g.edges:
        adj[int(u)].append(int(g.vlabels[int(v)]))
        adj[int(v)].append(int(g.vlabels[int(u)]))
    grams: Counter = Counter()
    for v in range(g.n):
        grams[(int(g.vlabels[v]), tuple(sorted(adj[v])))] += 1
    return grams


def kat_lb(g: Graph, h: Graph, kappa: int = 1) -> float:
    qg, qh = kat_qgrams(g, kappa), kat_qgrams(h, kappa)
    common = sum(min(qg[k], qh[k]) for k in qg.keys() & qh.keys())
    dg = int(g.degrees().max()) if g.m else 0
    dh = int(h.degrees().max()) if h.m else 0
    # one op touches <= 1 + dmax subtrees (kappa=1) on any graph along the
    # edit path; shared gamma (per-side gammas are NOT admissible — an op
    # can touch intermediate vertices whose degree exceeds that side's dmax)
    gamma = max(2, 1 + max(dg, dh))
    return max((sum(qg.values()) - common) / gamma,
               (sum(qh.values()) - common) / gamma, 0.0)


# --------------------------------------------------------------------------
# index-size emulation for Fig 7 comparisons (bits)
# --------------------------------------------------------------------------

def cstar_index_bits(db) -> int:
    """C-Star stores every star structure: label + nbr labels + edge labels."""
    total = 0
    for g in db:
        for (l, nb, el) in star_structures(g):
            total += 32 * (1 + len(nb) + len(el))
    return total


def branch_index_bits(db) -> int:
    """Mixed stores branch + disjoint structures (~2x branch footprint)."""
    total = 0
    for g in db:
        for (l, el) in branch_structures(g):
            total += 32 * (1 + len(el)) * 2
    return total


def path_index_bits(db, p: int = 2) -> int:
    """GSimJoin stores every path q-gram occurrence (id + graph ref)."""
    total = 0
    for g in db:
        total += 64 * sum(path_qgrams(g, p).values())
    return total
