"""Array containers + host-side builders for the vectorised filter paths.

jax-free on purpose: the tree index, the numpy filter backend, and
``repro.core.search`` import this module without paying the jax import /
backend-init cost.  The containers hold numpy arrays on host and jax
arrays on device (NamedTuple is layout-only); ``repro.core.filters_jax``
re-exports everything here for the accelerator code.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # only for annotations; never imported at runtime here
    import jax


class DBArrays(NamedTuple):
    """Device-side database shard (all (B, ...) along the graph axis)."""

    nv: "jax.Array"         # (B,)   int32
    ne: "jax.Array"         # (B,)   int32
    degseq: "jax.Array"     # (B, Vmax) int32, non-increasing, zero-padded
    vhist: "jax.Array"      # (B, n_vlabels) int32
    ehist: "jax.Array"      # (B, n_elabels) int32
    fd: "jax.Array"         # (B, U) int32 dense degree-q-gram frequencies
    region_i: "jax.Array"   # (B,)   int32
    region_j: "jax.Array"   # (B,)   int32


class QueryArrays(NamedTuple):
    nv: "jax.Array"         # () int32
    ne: "jax.Array"         # () int32
    sigma: "jax.Array"      # (Vmax,) int32
    vhist: "jax.Array"      # (n_vlabels,) int32
    ehist: "jax.Array"      # (n_elabels,) int32
    fd: "jax.Array"         # (U,) int32
    tau: "jax.Array"        # () int32


# --------------------------------------------------------------------------
# host-side builders
# --------------------------------------------------------------------------

def db_arrays_from_encoded(enc, partition, hot: Optional[int] = None,
                           vmax: Optional[int] = None) -> DBArrays:
    """Materialise DBArrays (numpy) from an EncodedDB + RegionPartition."""
    B = len(enc)
    if vmax is None:
        vmax = int(max(enc.nv.max(), 1))
    U = enc.vocab.n_degree_ids if hot is None else min(hot, enc.vocab.n_degree_ids)
    fd = np.zeros((B, max(U, 1)), np.int32)
    for i in range(B):
        ids, cnt = enc.row_degree(i)
        sel = ids < U
        fd[i, ids[sel]] = cnt[sel]
    ri, rj = partition.region_of(enc.nv, enc.ne)
    # degseq/vhist/ehist recomputed from CSR data:
    degs = np.zeros((B, vmax), np.int32)
    t_d = enc.vocab.degree_id_table()
    for i in range(B):
        ids, cnt = enc.row_degree(i)
        d = np.repeat(t_d[ids], cnt)
        d = np.sort(d)[::-1][:vmax]
        degs[i, :len(d)] = d
    nvl, nel = enc.vocab.n_vlabels, enc.vocab.n_elabels
    vhist = np.zeros((B, nvl), np.int32)
    ehist = np.zeros((B, nel), np.int32)
    for i in range(B):
        ids, cnt = enc.row_label(i)
        vsel = ids < nvl
        vhist[i, ids[vsel]] = cnt[vsel]
        esel = ~vsel
        ehist[i, ids[esel] - nvl] = cnt[esel]
    return DBArrays(
        nv=enc.nv.astype(np.int32), ne=enc.ne.astype(np.int32),
        degseq=degs, vhist=vhist, ehist=ehist, fd=fd,
        region_i=ri.astype(np.int32), region_j=rj.astype(np.int32))


def query_arrays_from_graph(h, vocab, partition, tau: int, vmax: int,
                            hot: Optional[int] = None,
                            qt=None) -> QueryArrays:
    """Query-side arrays; pass a precomputed ``QueryTuple`` as ``qt`` to
    skip re-encoding (the engine's LRU cache does)."""
    from repro.core.tree import QueryTuple

    q = QueryTuple.from_graph(h, vocab) if qt is None else qt
    U = vocab.n_degree_ids if hot is None else min(hot, vocab.n_degree_ids)
    fd = np.zeros(max(U, 1), np.int32)
    sel = q.d_ids < U
    fd[q.d_ids[sel]] = q.d_cnt[sel]
    sigma = np.zeros(vmax, np.int32)
    sigma[:min(len(q.sigma), vmax)] = q.sigma[:vmax]
    return QueryArrays(
        nv=np.int32(h.n), ne=np.int32(h.m), sigma=sigma,
        vhist=h.vertex_label_hist(vocab.n_vlabels).astype(np.int32),
        ehist=h.edge_label_hist(vocab.n_elabels).astype(np.int32),
        fd=fd, tau=np.int32(tau))
