"""Succinct building blocks (Section 5.2–5.4).

Paper-faithful host implementations:

* ``BitVector`` — packed bits + two-level rank dictionary (Jacobson-style):
  superblocks of 512 bits (cumulative int64) + 64-bit blocks (int16 offsets),
  giving O(1) ``rank1`` with o(n) extra bits.
* ``elias_gamma`` / ``elias_delta`` / ``golomb`` / fixed-length coders —
  the encodings compared in Table 2.
* ``HybridEncodedArray`` — the paper's hybrid scheme: Psi is split into
  fixed-length blocks of ``b`` entries; each block is stored either
  fixed-width (floor(log2 b_max)+1 bits/entry) or Elias-gamma, whichever is
  smaller.  Auxiliary structures: SB (block start offsets in S), flag (1 bit
  per block + rank dictionary), words (per fixed block width).  ``access(j)``
  implements formula (2); whole-block decode is vectorised for the batch
  paths.

All size accounting is in *bits* and mirrors the Section 5.4 analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------
# bit I/O
# --------------------------------------------------------------------------

class BitWriter:
    """Append-only MSB-first bit writer backed by a python int buffer."""

    def __init__(self) -> None:
        self._chunks: List[Tuple[int, int]] = []  # (value, nbits)
        self._nbits = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits < 0 or (nbits and value >> nbits):
            raise ValueError(f"value {value} does not fit in {nbits} bits")
        if nbits == 0:
            return
        self._chunks.append((int(value), int(nbits)))
        self._nbits += int(nbits)

    def write_unary_zeros(self, n: int) -> None:
        """n zero bits (the gamma-code prefix)."""
        while n > 60:
            self.write(0, 60)
            n -= 60
        if n:
            self.write(0, n)

    @property
    def nbits(self) -> int:
        return self._nbits

    def to_words(self) -> np.ndarray:
        """Pack into a uint64 array, MSB-first within each word."""
        n_words = (self._nbits + 63) // 64
        words = np.zeros(n_words, np.uint64)
        pos = 0
        for value, nbits in self._chunks:
            # write bits [pos, pos+nbits) MSB-first
            remaining = nbits
            v = value
            while remaining > 0:
                w = pos // 64
                off = pos % 64
                take = min(64 - off, remaining)
                shift = remaining - take
                part = (v >> shift) & ((1 << take) - 1)
                words[w] |= np.uint64(part << (64 - off - take))
                pos += take
                remaining -= take
        return words


class BitReader:
    """Random-access MSB-first reader over packed uint64 words."""

    def __init__(self, words: np.ndarray, nbits: int):
        self.words = words.astype(np.uint64)
        self.nbits = int(nbits)

    def read(self, pos: int, nbits: int) -> int:
        """Read ``nbits`` starting at absolute bit position ``pos``."""
        if nbits == 0:
            return 0
        out = 0
        remaining = nbits
        while remaining > 0:
            w = pos // 64
            off = pos % 64
            take = min(64 - off, remaining)
            word = int(self.words[w])
            part = (word >> (64 - off - take)) & ((1 << take) - 1)
            out = (out << take) | part
            pos += take
            remaining -= take
        return out

    def count_leading_zeros(self, pos: int, limit: int = 64) -> int:
        """Zeros starting at ``pos`` before the first 1 (gamma prefix)."""
        n = 0
        while n < limit and pos + n < self.nbits:
            if self.read(pos + n, 1):
                return n
            n += 1
        return n


# --------------------------------------------------------------------------
# bit vector with O(1) rank
# --------------------------------------------------------------------------

SUPER = 512
BLOCK = 64


class BitVector:
    """Packed bit vector with a two-level rank dictionary.

    ``rank1(j)`` = number of 1s in positions [0, j)  (exclusive — the
    convention matching formula (3): F[i] nonzero at global bit p maps to
    Psi[rank1(p)]).
    """

    def __init__(self, bits: np.ndarray):
        """``bits``: uint8/bool array of 0/1 values."""
        bits = np.asarray(bits).astype(np.uint8)
        self.n = int(bits.shape[0])
        pad = (-self.n) % 64
        padded = np.pad(bits, (0, pad))
        # pack MSB-first into uint64 words
        b8 = np.packbits(padded)  # MSB-first uint8 bytes
        pad8 = (-len(b8)) % 8
        b8 = np.pad(b8, (0, pad8))
        self.words = b8.view(">u8").astype(np.uint64)
        self._build_rank()

    # two-level rank dictionary (Jacobson): int64 superblock counts every
    # SUPER bits + uint16 intra-superblock offsets every BLOCK bits
    def _build_rank(self) -> None:
        pc = _popcount64(self.words)
        self._word_pop = pc
        cum = np.concatenate([[0], np.cumsum(pc)]).astype(np.int64)
        self._cum = cum                      # per-word cumulative (query fast path)
        wps = SUPER // 64                    # words per superblock
        n_super = (len(self.words) + wps - 1) // wps
        sup = np.zeros(n_super + 1, np.int64)
        if len(self.words):
            sup[1:] = np.add.reduceat(
                pc, np.arange(0, len(self.words), wps)).cumsum()
        self._super = sup
        # intra-superblock offsets of each word (<= 512, 10 bits each)
        base = np.repeat(sup[:-1], wps)[:len(self.words)]
        self._block_off = (cum[:-1] - base).astype(np.uint16)

    def rank1(self, j: int) -> int:
        """Number of ones in [0, j)."""
        if j <= 0:
            return 0
        j = min(j, self.n)
        w = j // 64
        r = int(self._cum[w])
        rem = j % 64
        if rem:
            word = int(self.words[w])
            r += bin(word >> (64 - rem)).count("1")
        return r

    def rank1_bulk(self, idx: np.ndarray) -> np.ndarray:
        """Vectorised rank for many positions."""
        idx = np.minimum(np.maximum(np.asarray(idx, np.int64), 0), self.n)
        w = idx // 64
        rem = idx % 64
        base = self._cum[w]
        words = self.words[np.minimum(w, len(self.words) - 1)]
        shifted = np.where(rem > 0,
                           words >> (64 - rem).astype(np.uint64),
                           np.uint64(0))
        extra = _popcount64(shifted)
        return base + np.where(rem > 0, extra, 0)

    def get(self, j: int) -> int:
        if j < 0 or j >= self.n:
            return 0
        w, off = divmod(j, 64)
        return (int(self.words[w]) >> (63 - off)) & 1

    def get_bulk(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, np.int64)
        valid = (idx >= 0) & (idx < self.n)
        safe = np.where(valid, idx, 0)
        w = safe // 64
        off = safe % 64
        bits = (self.words[w] >> (63 - off).astype(np.uint64)) & np.uint64(1)
        return np.where(valid, bits.astype(np.int64), 0)

    def size_bits(self) -> dict:
        """Bits used: raw + the two-level rank dictionary (Section 5.4:
        |B| + o(|B|)): one int64 per 512-bit superblock (12.5%) plus one
        10-bit intra-superblock offset per 64-bit word (15.6%)."""
        raw = len(self.words) * 64
        rank_dict = len(self._super) * 64 + len(self.words) * 10
        return {"raw": raw, "rank": rank_dict, "total": raw + rank_dict}


def _popcount64(x: np.ndarray) -> np.ndarray:
    """Vectorised popcount of uint64 (SWAR)."""
    x = x.astype(np.uint64)
    m1 = np.uint64(0x5555555555555555)
    m2 = np.uint64(0x3333333333333333)
    m4 = np.uint64(0x0F0F0F0F0F0F0F0F)
    h01 = np.uint64(0x0101010101010101)
    x = x - ((x >> np.uint64(1)) & m1)
    x = (x & m2) + ((x >> np.uint64(2)) & m2)
    x = (x + (x >> np.uint64(4))) & m4
    return ((x * h01) >> np.uint64(56)).astype(np.int64)


# --------------------------------------------------------------------------
# integer coders (Table 2)
# --------------------------------------------------------------------------

def gamma_length(x: int) -> int:
    """|gamma(x)| = 2 floor(log2 x) + 1, x >= 1."""
    if x < 1:
        raise ValueError("gamma requires x >= 1")
    return 2 * (x.bit_length() - 1) + 1


def write_gamma(bw: BitWriter, x: int) -> None:
    n = x.bit_length() - 1
    bw.write_unary_zeros(n)
    bw.write(x, n + 1)


def read_gamma(br: BitReader, pos: int) -> Tuple[int, int]:
    """Returns (value, new_pos)."""
    n = br.count_leading_zeros(pos)
    val = br.read(pos + n, n + 1)
    return val, pos + 2 * n + 1


def delta_length(x: int) -> int:
    """Elias delta: gamma(floor(log2 x)+1) + floor(log2 x) bits."""
    if x < 1:
        raise ValueError("delta requires x >= 1")
    n = x.bit_length() - 1
    return gamma_length(n + 1) + n


def write_delta(bw: BitWriter, x: int) -> None:
    n = x.bit_length() - 1
    write_gamma(bw, n + 1)
    if n:
        bw.write(x & ((1 << n) - 1), n)


def read_delta(br: BitReader, pos: int) -> Tuple[int, int]:
    np1, pos = read_gamma(br, pos)
    n = np1 - 1
    if n == 0:
        return 1, pos
    rest = br.read(pos, n)
    return (1 << n) | rest, pos + n


def golomb_length(x: int, m: int) -> int:
    """Golomb code length for x >= 1 with parameter m (truncated binary)."""
    q = (x - 1) // m
    r = (x - 1) % m
    if m & (m - 1) == 0:  # power of two (Rice): exactly log2(m) bits
        return q + 1 + (m.bit_length() - 1)
    b = m.bit_length()          # ceil(log2 m) for non-powers of two
    cutoff = (1 << b) - m       # remainders below cutoff take b-1 bits
    return q + 1 + (b - 1 if r < cutoff else b)


def fixed_length(values: Sequence[int]) -> int:
    """Bits/entry of fixed-length coding of a block: floor(log2 max)+1."""
    mx = max(int(v) for v in values)
    return max(mx.bit_length(), 1)


# --------------------------------------------------------------------------
# the hybrid-encoded array (Psi_X of the paper)
# --------------------------------------------------------------------------

@dataclass
class HybridSizes:
    s_bits: int
    sb_bits: int
    flag_bits: int
    words_bits: int

    @property
    def total(self) -> int:
        return self.s_bits + self.sb_bits + self.flag_bits + self.words_bits


class HybridEncodedArray:
    """Psi stored with the paper's per-block hybrid encoding.

    Parameters:
      values: positive ints (the nonzero F entries, concatenated over nodes).
      block:  entries per block (paper's ``b``; default 16 as in Sec 7.1).
    """

    def __init__(self, values: Sequence[int], block: int = 16):
        values = np.asarray(list(values), np.int64)
        if (values < 1).any():
            raise ValueError("Psi entries must be >= 1 (nonzeros only)")
        self.n = int(values.shape[0])
        self.block = int(block)
        n_blocks = (self.n + block - 1) // block if self.n else 0

        bw = BitWriter()
        sb = np.zeros(n_blocks + 1, np.int64)
        flag_bits = np.zeros(n_blocks, np.uint8)
        words: List[int] = []
        for k in range(n_blocks):
            blk = values[k * block:(k + 1) * block]
            w = fixed_length(blk)
            fixed_cost = len(blk) * w
            gamma_cost = int(sum(gamma_length(int(v)) for v in blk))
            sb[k] = bw.nbits
            if fixed_cost <= gamma_cost:
                flag_bits[k] = 1
                words.append(w)
                for v in blk:
                    bw.write(int(v), w)
            else:
                for v in blk:
                    write_gamma(bw, int(v))
        sb[n_blocks] = bw.nbits
        self._sb = sb
        self._flag = BitVector(flag_bits)
        self._words = np.asarray(words, np.int64)
        self._s_words = bw.to_words()
        self._s_nbits = bw.nbits
        self._reader = BitReader(self._s_words, bw.nbits)

    # ---- access (formula (2)) --------------------------------------------
    def access(self, j: int) -> int:
        if j < 0 or j >= self.n:
            raise IndexError(j)
        k, r = divmod(j, self.block)
        pos = int(self._sb[k])
        if self._flag.get(k):
            w = int(self._words[self._flag.rank1(k)])
            return self._reader.read(pos + r * w, w)
        val = 0
        for _ in range(r + 1):
            val, pos = read_gamma(self._reader, pos)
        return val

    def decode_block(self, k: int) -> np.ndarray:
        """Decode one whole block (vectorised fixed path)."""
        lo = k * self.block
        hi = min(lo + self.block, self.n)
        cnt = hi - lo
        pos = int(self._sb[k])
        if self._flag.get(k):
            w = int(self._words[self._flag.rank1(k)])
            return np.array(
                [self._reader.read(pos + i * w, w) for i in range(cnt)],
                np.int64)
        out = np.zeros(cnt, np.int64)
        for i in range(cnt):
            out[i], pos = read_gamma(self._reader, pos)
        return out

    def decode_all(self) -> np.ndarray:
        if self.n == 0:
            return np.zeros(0, np.int64)
        n_blocks = (self.n + self.block - 1) // self.block
        return np.concatenate([self.decode_block(k) for k in range(n_blocks)])

    def access_bulk(self, idx: np.ndarray) -> np.ndarray:
        return np.array([self.access(int(j)) for j in np.asarray(idx)], np.int64)

    # ---- sizes (Section 5.4) ----------------------------------------------
    def size_bits(self) -> HybridSizes:
        n_blocks = (self.n + self.block - 1) // self.block if self.n else 0
        sb_entry = max(int(self._s_nbits).bit_length(), 1)
        if len(self._words):
            words_bits = len(self._words) * max(int(self._words.max()).bit_length(), 1)
        else:
            words_bits = 0
        return HybridSizes(
            s_bits=self._s_nbits,
            sb_bits=(n_blocks + 1) * sb_entry,
            flag_bits=self._flag.size_bits()["total"],
            words_bits=words_bits,
        )

    def bits_per_entry(self) -> float:
        return self.size_bits().s_bits / max(self.n, 1)


# --------------------------------------------------------------------------
# whole-array single-coder encoders (for the Table 2 comparison)
# --------------------------------------------------------------------------

def encoded_bits_per_entry(values: Sequence[int], scheme: str,
                           block: int = 16) -> float:
    """Average bits/entry of Psi under a given scheme (Table 2 columns)."""
    values = [int(v) for v in values]
    if not values:
        return 0.0
    if scheme == "fixed":
        total = 0
        for k in range(0, len(values), block):
            blk = values[k:k + block]
            total += len(blk) * fixed_length(blk)
        return total / len(values)
    if scheme == "gamma":
        return sum(gamma_length(v) for v in values) / len(values)
    if scheme == "delta":
        return sum(delta_length(v) for v in values) / len(values)
    if scheme == "golomb":
        mean = max(int(round(sum(values) / len(values))), 1)
        return sum(golomb_length(v, mean) for v in values) / len(values)
    if scheme == "hybrid":
        total = 0
        for k in range(0, len(values), block):
            blk = values[k:k + block]
            fixed_cost = len(blk) * fixed_length(blk)
            gamma_cost = sum(gamma_length(v) for v in blk)
            total += min(fixed_cost, gamma_cost)
        return total / len(values)
    if scheme == "hybrid3":
        # BEYOND-PAPER: 3-way per-block choice {fixed, gamma, golomb(m=1)}.
        # Unary (golomb m=1) wins on the 1-dominated blocks that chemistry
        # q-gram counts produce; the flag grows from 1 to 2 bits per block
        # (counted here).  See EXPERIMENTS.md §Perf (paper-side).
        total = 0
        for k in range(0, len(values), block):
            blk = values[k:k + block]
            fixed_cost = len(blk) * fixed_length(blk)
            gamma_cost = sum(gamma_length(v) for v in blk)
            unary_cost = sum(golomb_length(v, 1) for v in blk)
            total += min(fixed_cost, gamma_cost, unary_cost) + 1  # extra flag bit
        return total / len(values)
    raise ValueError(f"unknown scheme {scheme}")
