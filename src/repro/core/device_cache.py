"""DeviceSlabCache: device-resident per-bucket slab operands
(DESIGN.md §13).

Every filter backend gathers a bucket's rows out of the resident
``FilterSlab`` and — for the jax / pallas / distributed paths — uploads
the gathered operands to the device on *every* ``bounds`` call, even
though the bucket → row-set mapping is fixed for the life of the slab.
On a 5k-graph DB the dense F_D upload alone dwarfs the filter math.

This cache keys on the bucket identity (the gathered row indices plus the
pad size) and holds, per bucket, the host-side gathered sub-slab and the
backend-specific device-resident operands, so each is built/transferred
once per (bucket, layout) and reused across batches.  Entries are
LRU-bounded; query-side operands (small, per-batch) are never cached.

Ownership: one cache per ``BatchedFilterEval``, created with its slab and
dropped with it.  ``invalidate()`` empties the cache — called when the
evaluator's slab is rebuilt (``BatchedFilterEval.rebuild_slab``) or when
``FlatMSQIndex.set_filter_eval`` replaces a registered evaluator, so a
stale device copy can never outlive the slab it mirrors.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Tuple

import numpy as np


def bucket_key(idx: np.ndarray, n_pad: int) -> Tuple:
    """Cache key for one gathered bucket: exact row identity + pad size.

    The raw index bytes (not a lossy hash) — a key collision would swap
    another bucket's slab in silently, and bit-identical candidates are
    the repo's load-bearing invariant.
    """
    idx = np.ascontiguousarray(np.asarray(idx, np.int64))
    return (int(n_pad), len(idx), idx.tobytes())


class DeviceSlabCache:
    """LRU cache of per-bucket gathered sub-slabs and their device
    operands, shared by every backend path of one ``BatchedFilterEval``.
    """

    def __init__(self, max_entries: int = 16):
        self.max_entries = int(max_entries)
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, Dict[str, Any]]" = \
            OrderedDict()                       # guarded_by: self._lock
        self.stats: Dict[str, int] = {          # guarded_by: self._lock
            "hits": 0, "misses": 0, "evictions": 0, "invalidations": 0}
        # duck-typed fault injector (serve.faults.FaultInjector); builds
        # fire the ``device.cache`` point so upload/gather failures are
        # injectable without a real device (DESIGN.md §18)
        self._faults = None

    def set_faults(self, faults) -> None:
        self._faults = faults

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def snapshot(self) -> Dict[str, int]:
        """Consistent copy of the counters plus the current size —
        readers must not iterate ``stats`` while a builder commits."""
        with self._lock:
            out = dict(self.stats)
            out["entries"] = len(self._entries)
            return out

    def get_or_build(self, key: Hashable, field: str,
                     build: Callable[[], Any]) -> Any:
        """Return the cached ``field`` of the ``key`` bucket, building it
        on first use.  Distinct fields of one bucket (host gather, jax
        arrays, pallas operands, ...) share the entry and its LRU slot."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and field in entry:
                self._entries.move_to_end(key)
                self.stats["hits"] += 1
                return entry[field]
        # build outside the lock: gathers/uploads are slow and re-entrant
        # callers (a field builder using another field) must not deadlock
        if self._faults is not None:
            self._faults.fire("device.cache", field=field)
        value = build()
        with self._lock:
            entry = self._entries.setdefault(key, {})
            self._entries.move_to_end(key)
            # first writer wins so concurrent builders agree on the object
            value = entry.setdefault(field, value)
            self.stats["misses"] += 1
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats["evictions"] += 1
        return value

    def invalidate(self) -> None:
        """Drop every entry (slab rebuilt / evaluator replaced)."""
        with self._lock:
            self._entries.clear()
            self.stats["invalidations"] += 1
