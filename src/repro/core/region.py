"""Reduced query region (Section 4).

Each graph is the 2-D point (|V|, |E|).  The plane is partitioned into
45-degree-rotated square subregions A_{i,j} of diagonal length ``l`` around
an initial division point (x0, y0); the number-count filter becomes the L1
ball |x - |V_h|| + |y - |E_h|| <= tau, and the query region Q_h is the set
of subregions intersecting it — formula (1):

  i1 = floor((|E_h| - tau + |V_h| - (x0+y0)) / l)
  i2 = floor((|E_h| + tau + |V_h| - (x0+y0)) / l)
  j1 = floor((|E_h| - tau - |V_h| - (y0-x0)) / l)
  j2 = floor((|E_h| + tau - |V_h| - (y0-x0)) / l)

Subregion coordinates of a point (x, y):
  i = floor(((x+y) - (x0+y0)) / l),   j = floor(((y-x) - (y0-x0)) / l)
(the paper's 1/sqrt(2) factors cancel between offset and side length).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Tuple

import numpy as np


@dataclass(frozen=True)
class RegionPartition:
    """Partition parameters (x0, y0, l)."""

    x0: int
    y0: int
    l: int = 4

    def region_of(self, nv, ne):
        """(i, j) subregion indices; vectorised over numpy inputs."""
        nv = np.asarray(nv, np.int64)
        ne = np.asarray(ne, np.int64)
        i = np.floor_divide((nv + ne) - (self.x0 + self.y0), self.l)
        j = np.floor_divide((ne - nv) - (self.y0 - self.x0), self.l)
        return i, j

    def query_region(self, nv_h: int, ne_h: int, tau: int) -> Tuple[int, int, int, int]:
        """Formula (1): inclusive bounds (i1, i2, j1, j2)."""
        s, d = self.x0 + self.y0, self.y0 - self.x0
        i1 = (ne_h - tau + nv_h - s) // self.l
        i2 = (ne_h + tau + nv_h - s) // self.l
        j1 = (ne_h - tau - nv_h - d) // self.l
        j2 = (ne_h + tau - nv_h - d) // self.l
        return i1, i2, j1, j2

    def regions_in_query(self, nv_h: int, ne_h: int, tau: int) -> List[Tuple[int, int]]:
        i1, i2, j1, j2 = self.query_region(nv_h, ne_h, tau)
        return [(i, j) for i in range(i1, i2 + 1) for j in range(j1, j2 + 1)]


def default_partition(nv: np.ndarray, ne: np.ndarray, l: int = 4) -> RegionPartition:
    """Initial division point at the median graph — keeps |i|,|j| small."""
    x0 = int(np.median(nv)) if len(nv) else 0
    y0 = int(np.median(ne)) if len(ne) else 0
    return RegionPartition(x0=x0, y0=y0, l=l)


def group_by_region(part: RegionPartition, nv: np.ndarray, ne: np.ndarray
                    ) -> Dict[Tuple[int, int], np.ndarray]:
    """Map each subregion (i, j) to the array of graph ids inside it."""
    i, j = part.region_of(nv, ne)
    out: Dict[Tuple[int, int], List[int]] = {}
    for gid, key in enumerate(zip(i.tolist(), j.tolist())):
        out.setdefault(key, []).append(gid)
    return {k: np.asarray(v, np.int64) for k, v in out.items()}
