"""JAX (accelerator) implementations of the filter cascade.

These mirror ``repro.core.filters.batched_bounds_np`` exactly (tested) and
are jit/shard_map friendly: fixed shapes, no data-dependent control flow.

Data layout (DESIGN.md §3): the degree-q-gram frequency matrix is dense
over the frequency-ordered vocabulary (optionally only its hot prefix, in
which case the caller must add the CSR tail correction to ``c_d`` *before*
thresholding to stay admissible).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# containers + host-side builders live in the jax-free arrays module;
# re-exported here so accelerator code can keep using fj.DBArrays etc.
from repro.core.arrays import (DBArrays, QueryArrays, db_arrays_from_encoded,
                               query_arrays_from_graph)


def min_sum(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    """sum(min(a, b)) — the multiset-intersection contraction."""
    return jnp.minimum(a, b).sum(axis=axis)


def batched_bounds(db: DBArrays, q: QueryArrays,
                   c_d: Optional[jax.Array] = None) -> jax.Array:
    """Combined admissible lower bound per graph; (B,) int32.

    ``c_d`` overrides the dense F_D intersection (e.g. the Pallas kernel's
    output, or hot-prefix + tail correction).
    """
    nv = db.nv.astype(jnp.int32)
    ne = db.ne.astype(jnp.int32)
    overlap_v = min_sum(db.vhist, q.vhist[None, :]).astype(jnp.int32)
    overlap_e = min_sum(db.ehist, q.ehist[None, :]).astype(jnp.int32)
    c_l = overlap_v + overlap_e
    if c_d is None:
        c_d = min_sum(db.fd, q.fd[None, :]).astype(jnp.int32)
    max_nv = jnp.maximum(nv, q.nv)
    max_ne = jnp.maximum(ne, q.ne)

    number_count = jnp.abs(nv - q.nv) + jnp.abs(ne - q.ne)
    label_qgram = max_nv + max_ne - c_l
    # ceil((2 max_nv - overlap_v - c_d) / 2), clamped at 0
    dq_num = 2 * max_nv - overlap_v - c_d
    degree_qgram = jnp.maximum(0, (dq_num + 1) // 2)

    d = db.degseq.astype(jnp.int32) - q.sigma[None, :].astype(jnp.int32)
    s1 = jnp.maximum(d, 0).sum(axis=1)
    s2 = jnp.maximum(-d, 0).sum(axis=1)
    delta = (s1 + 1) // 2 + (s2 + 1) // 2
    min_deg = min_sum(db.degseq, q.sigma[None, :]).astype(jnp.int32)
    lam2 = jnp.maximum(q.ne + ne - min_deg, 0)
    lam = jnp.where(q.nv <= nv, delta, lam2)
    degree_sequence = max_nv - overlap_v + lam

    return jnp.maximum(
        jnp.maximum(number_count, label_qgram),
        jnp.maximum(degree_qgram, degree_sequence),
    ).astype(jnp.int32)


def region_mask(db: DBArrays, q: QueryArrays,
                x0: int, y0: int, l: int) -> jax.Array:
    """Reduced-query-region membership (formula (1)); (B,) bool."""
    s, ddiag = x0 + y0, y0 - x0
    i1 = jnp.floor_divide(q.ne - q.tau + q.nv - s, l)
    i2 = jnp.floor_divide(q.ne + q.tau + q.nv - s, l)
    j1 = jnp.floor_divide(q.ne - q.tau - q.nv - ddiag, l)
    j2 = jnp.floor_divide(q.ne + q.tau - q.nv - ddiag, l)
    return ((db.region_i >= i1) & (db.region_i <= i2)
            & (db.region_j >= j1) & (db.region_j <= j2))


def filter_pass(db: DBArrays, q: QueryArrays, x0: int, y0: int, l: int,
                c_d: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """(pass_mask, bounds): the full cascade incl. region reduction."""
    bounds = batched_bounds(db, q, c_d=c_d)
    mask = region_mask(db, q, x0, y0, l) & (bounds <= q.tau)
    return mask, bounds


def topk_candidates(mask: jax.Array, bounds: jax.Array,
                    k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-size candidate extraction (jit-able ragged->dense).

    Returns (ids, bounds, count): the (up to) k best (lowest-bound) passing
    graphs; ids are -1 beyond ``count``.
    """
    B = mask.shape[0]
    score = jnp.where(mask, -bounds.astype(jnp.int32), -(2 ** 30))
    vals, idx = jax.lax.top_k(score, min(k, B))
    valid = vals > -(2 ** 30)
    ids = jnp.where(valid, idx, -1)
    return ids, jnp.where(valid, -vals, 2 ** 30), valid.sum()


__all__ = ["DBArrays", "QueryArrays", "db_arrays_from_encoded",
           "query_arrays_from_graph", "min_sum", "batched_bounds",
           "region_mask", "filter_pass", "topk_candidates"]
