"""JAX (accelerator) implementations of the filter cascade.

These mirror ``repro.core.filters.batched_bounds_np`` exactly (tested) and
are jit/shard_map friendly: fixed shapes, no data-dependent control flow.

Data layout (DESIGN.md §3): the degree-q-gram frequency matrix is dense
over the frequency-ordered vocabulary (optionally only its hot prefix, in
which case the caller must add the CSR tail correction to ``c_d`` *before*
thresholding to stay admissible).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class DBArrays(NamedTuple):
    """Device-side database shard (all (B, ...) along the graph axis)."""

    nv: jax.Array           # (B,)   int32
    ne: jax.Array           # (B,)   int32
    degseq: jax.Array       # (B, Vmax) int32, non-increasing, zero-padded
    vhist: jax.Array        # (B, n_vlabels) int32
    ehist: jax.Array        # (B, n_elabels) int32
    fd: jax.Array           # (B, U) int32 dense degree-q-gram frequencies
    region_i: jax.Array     # (B,)   int32
    region_j: jax.Array     # (B,)   int32


class QueryArrays(NamedTuple):
    nv: jax.Array           # () int32
    ne: jax.Array           # () int32
    sigma: jax.Array        # (Vmax,) int32
    vhist: jax.Array        # (n_vlabels,) int32
    ehist: jax.Array        # (n_elabels,) int32
    fd: jax.Array           # (U,) int32
    tau: jax.Array          # () int32


def min_sum(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    """sum(min(a, b)) — the multiset-intersection contraction."""
    return jnp.minimum(a, b).sum(axis=axis)


def batched_bounds(db: DBArrays, q: QueryArrays,
                   c_d: Optional[jax.Array] = None) -> jax.Array:
    """Combined admissible lower bound per graph; (B,) int32.

    ``c_d`` overrides the dense F_D intersection (e.g. the Pallas kernel's
    output, or hot-prefix + tail correction).
    """
    nv = db.nv.astype(jnp.int32)
    ne = db.ne.astype(jnp.int32)
    overlap_v = min_sum(db.vhist, q.vhist[None, :]).astype(jnp.int32)
    overlap_e = min_sum(db.ehist, q.ehist[None, :]).astype(jnp.int32)
    c_l = overlap_v + overlap_e
    if c_d is None:
        c_d = min_sum(db.fd, q.fd[None, :]).astype(jnp.int32)
    max_nv = jnp.maximum(nv, q.nv)
    max_ne = jnp.maximum(ne, q.ne)

    number_count = jnp.abs(nv - q.nv) + jnp.abs(ne - q.ne)
    label_qgram = max_nv + max_ne - c_l
    # ceil((2 max_nv - overlap_v - c_d) / 2), clamped at 0
    dq_num = 2 * max_nv - overlap_v - c_d
    degree_qgram = jnp.maximum(0, (dq_num + 1) // 2)

    d = db.degseq.astype(jnp.int32) - q.sigma[None, :].astype(jnp.int32)
    s1 = jnp.maximum(d, 0).sum(axis=1)
    s2 = jnp.maximum(-d, 0).sum(axis=1)
    delta = (s1 + 1) // 2 + (s2 + 1) // 2
    min_deg = min_sum(db.degseq, q.sigma[None, :]).astype(jnp.int32)
    lam2 = jnp.maximum(q.ne + ne - min_deg, 0)
    lam = jnp.where(q.nv <= nv, delta, lam2)
    degree_sequence = max_nv - overlap_v + lam

    return jnp.maximum(
        jnp.maximum(number_count, label_qgram),
        jnp.maximum(degree_qgram, degree_sequence),
    ).astype(jnp.int32)


def region_mask(db: DBArrays, q: QueryArrays,
                x0: int, y0: int, l: int) -> jax.Array:
    """Reduced-query-region membership (formula (1)); (B,) bool."""
    s, ddiag = x0 + y0, y0 - x0
    i1 = jnp.floor_divide(q.ne - q.tau + q.nv - s, l)
    i2 = jnp.floor_divide(q.ne + q.tau + q.nv - s, l)
    j1 = jnp.floor_divide(q.ne - q.tau - q.nv - ddiag, l)
    j2 = jnp.floor_divide(q.ne + q.tau - q.nv - ddiag, l)
    return ((db.region_i >= i1) & (db.region_i <= i2)
            & (db.region_j >= j1) & (db.region_j <= j2))


def filter_pass(db: DBArrays, q: QueryArrays, x0: int, y0: int, l: int,
                c_d: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """(pass_mask, bounds): the full cascade incl. region reduction."""
    bounds = batched_bounds(db, q, c_d=c_d)
    mask = region_mask(db, q, x0, y0, l) & (bounds <= q.tau)
    return mask, bounds


def topk_candidates(mask: jax.Array, bounds: jax.Array,
                    k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-size candidate extraction (jit-able ragged->dense).

    Returns (ids, bounds, count): the (up to) k best (lowest-bound) passing
    graphs; ids are -1 beyond ``count``.
    """
    B = mask.shape[0]
    score = jnp.where(mask, -bounds.astype(jnp.int32), -(2 ** 30))
    vals, idx = jax.lax.top_k(score, min(k, B))
    valid = vals > -(2 ** 30)
    ids = jnp.where(valid, idx, -1)
    return ids, jnp.where(valid, -vals, 2 ** 30), valid.sum()


# --------------------------------------------------------------------------
# host <-> device conversion
# --------------------------------------------------------------------------

def db_arrays_from_encoded(enc, partition, hot: Optional[int] = None,
                           vmax: Optional[int] = None) -> DBArrays:
    """Materialise DBArrays (numpy) from an EncodedDB + RegionPartition."""
    from repro.graphs.batching import PaddedGraphBatch

    B = len(enc)
    if vmax is None:
        vmax = int(max(enc.nv.max(), 1))
    U = enc.vocab.n_degree_ids if hot is None else min(hot, enc.vocab.n_degree_ids)
    fd = np.zeros((B, max(U, 1)), np.int32)
    for i in range(B):
        ids, cnt = enc.row_degree(i)
        sel = ids < U
        fd[i, ids[sel]] = cnt[sel]
    ri, rj = partition.region_of(enc.nv, enc.ne)
    # degseq/vhist/ehist recomputed from CSR data:
    degs = np.zeros((B, vmax), np.int32)
    t_d = enc.vocab.degree_id_table()
    for i in range(B):
        ids, cnt = enc.row_degree(i)
        d = np.repeat(t_d[ids], cnt)
        d = np.sort(d)[::-1][:vmax]
        degs[i, :len(d)] = d
    nvl, nel = enc.vocab.n_vlabels, enc.vocab.n_elabels
    vhist = np.zeros((B, nvl), np.int32)
    ehist = np.zeros((B, nel), np.int32)
    for i in range(B):
        ids, cnt = enc.row_label(i)
        vsel = ids < nvl
        vhist[i, ids[vsel]] = cnt[vsel]
        esel = ~vsel
        ehist[i, ids[esel] - nvl] = cnt[esel]
    return DBArrays(
        nv=enc.nv.astype(np.int32), ne=enc.ne.astype(np.int32),
        degseq=degs, vhist=vhist, ehist=ehist, fd=fd,
        region_i=ri.astype(np.int32), region_j=rj.astype(np.int32))


def query_arrays_from_graph(h, vocab, partition, tau: int, vmax: int,
                            hot: Optional[int] = None) -> QueryArrays:
    from repro.core.tree import QueryTuple

    q = QueryTuple.from_graph(h, vocab)
    U = vocab.n_degree_ids if hot is None else min(hot, vocab.n_degree_ids)
    fd = np.zeros(max(U, 1), np.int32)
    sel = q.d_ids < U
    fd[q.d_ids[sel]] = q.d_cnt[sel]
    sigma = np.zeros(vmax, np.int32)
    sigma[:min(len(q.sigma), vmax)] = q.sigma[:vmax]
    return QueryArrays(
        nv=np.int32(h.n), ne=np.int32(h.m), sigma=sigma,
        vhist=h.vertex_label_hist(vocab.n_vlabels).astype(np.int32),
        ehist=h.edge_label_hist(vocab.n_elabels).astype(np.int32),
        fd=fd, tau=np.int32(tau))
