"""MSQ-Index: the end-to-end filter-and-verify engine (Algorithm 2).

Build:   GraphDB -> q-gram vocab -> region partition -> one succinct q-gram
         tree per subregion A_{i,j} (graphs region-sorted so each region is
         a contiguous slab — DESIGN.md §3).
Query:   reduced query region Q_h (formula (1)) -> Algorithm 1 per tree ->
         candidate ids -> exact GED verification (ged_upto with tau cutoff).

``FlatMSQIndex`` is the TPU-mode equivalent: no tree, leaf-level filters
evaluated as one vectorised pass (oracle for the Pallas path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import filters
from repro.core.qgrams import EncodedDB, QGramVocab, sparse_intersection_size
from repro.core.region import RegionPartition, default_partition, group_by_region
from repro.core.tree import (QGramTree, QueryTuple, SuccinctQGramTree,
                             leaves_from_encoded)
from repro.core.verify import ged_upto
from repro.graphs.graph import Graph, GraphDB

from repro.core.engine import (BatchedFilterEval, CandidateBatch,
                               batched_flat_candidates, bucket_queries)


@dataclass
class QueryResult:
    candidates: List[int]
    matches: List[Tuple[int, int]]          # (graph_id, ged)
    n_filtered: int                         # graphs pruned by the index
    filter_time_s: float
    verify_time_s: float
    stats: Dict[str, int] = field(default_factory=dict)


class MSQIndex:
    """The paper's index: region-partitioned succinct q-gram trees."""

    def __init__(self, db: GraphDB, l: int = 4, block: int = 16,
                 fanout: int = 8, vocab: Optional[QGramVocab] = None):
        t0 = time.perf_counter()
        self.db = db
        self.enc = EncodedDB.build(db, vocab)
        self.vocab = self.enc.vocab
        nv, ne = db.sizes()
        self.partition = default_partition(nv, ne, l=l)
        self.regions = group_by_region(self.partition, nv, ne)
        self.trees: Dict[Tuple[int, int], SuccinctQGramTree] = {}
        self._plain_trees: Dict[Tuple[int, int], QGramTree] = {}
        for key, gids in self.regions.items():
            leaves = leaves_from_encoded(self.enc, gids)
            tree = QGramTree(leaves, fanout=fanout)
            self._plain_trees[key] = tree
            self.trees[key] = SuccinctQGramTree(tree, self.vocab, block=block)
        self.build_time_s = time.perf_counter() - t0

    # ---- Algorithm 2 ------------------------------------------------------
    def candidates(self, h: Graph, tau: int,
                   collect_stats: bool = False) -> Tuple[List[int], Dict]:
        q = QueryTuple.from_graph(h, self.vocab)
        i1, i2, j1, j2 = self.partition.query_region(h.n, h.m, tau)
        cand: List[int] = []
        stats = {"regions_total": len(self.regions), "regions_visited": 0,
                 "nodes_visited": 0, "leaves_checked": 0}
        for (i, j), tree in self.trees.items():
            if not (i1 <= i <= i2 and j1 <= j <= j2):
                continue
            stats["regions_visited"] += 1
            if collect_stats:
                c, s = tree.search(q, tau, collect_stats=True)
                stats["nodes_visited"] += s["nodes_visited"]
                stats["leaves_checked"] += s["leaves_checked"]
            else:
                c = tree.search(q, tau)
            cand.extend(c)
        return sorted(cand), stats

    # ---- CandidateSource protocol -----------------------------------------
    def candidate_ids(self, h: Graph, tau: int) -> List[int]:
        return self.candidates(h, tau)[0]

    def batched_candidates(self, graphs: Sequence[Graph],
                           taus: Sequence[int],
                           qtuples: Optional[Sequence[QueryTuple]] = None
                           ) -> CandidateBatch:
        """Region-major batched search: each region's tree is visited once
        per batch, serving every query whose rectangle covers it."""
        if qtuples is None:
            qtuples = [QueryTuple.from_graph(h, self.vocab) for h in graphs]
        ids: List[List[int]] = [[] for _ in graphs]
        buckets = bucket_queries(self.partition, graphs, taus)
        for (i, j), tree in self.trees.items():
            for (i1, i2, j1, j2), qis in buckets.items():
                if not (i1 <= i <= i2 and j1 <= j <= j2):
                    continue
                for qi in qis:
                    ids[qi].extend(tree.search(qtuples[qi], int(taus[qi])))
        for qi in range(len(graphs)):
            ids[qi] = sorted(ids[qi])
        return CandidateBatch(ids=ids, bounds=[None] * len(graphs))

    def query(self, h: Graph, tau: int, verify: bool = True,
              collect_stats: bool = False) -> QueryResult:
        t0 = time.perf_counter()
        cand, stats = self.candidates(h, tau, collect_stats)
        t1 = time.perf_counter()
        matches: List[Tuple[int, int]] = []
        if verify:
            for gid in cand:
                d = ged_upto(self.db[gid], h, tau)
                if d <= tau:
                    matches.append((gid, d))
        t2 = time.perf_counter()
        return QueryResult(
            candidates=cand,
            matches=matches,
            n_filtered=len(self.db) - len(cand),
            filter_time_s=t1 - t0,
            verify_time_s=t2 - t1,
            stats=stats,
        )

    # ---- size accounting (Table 3) -----------------------------------------
    def size_bits(self) -> Dict[str, int]:
        agg = {"S_a": 0, "S_b": 0, "S_c": 0, "total": 0}
        for tree in self.trees.values():
            for k, v in tree.size_bits().items():
                agg[k] += v
        return agg

    def plain_size_bits(self) -> Dict[str, int]:
        agg = {"S_a": 0, "S_b": 0, "S_c": 0, "total": 0}
        for tree in self._plain_trees.values():
            for k, v in tree.size_bits().items():
                agg[k] += v
        return agg


class FlatMSQIndex:
    """Tree-free vectorised variant (the TPU serving mode's oracle).

    All leaf-level filters evaluated for every graph in the reduced query
    region with numpy batch ops; equivalent candidate sets to MSQIndex
    (tested) because the tree only prunes with *weaker* bounds than the
    leaves re-check.
    """

    def __init__(self, db: GraphDB, l: int = 4,
                 vocab: Optional[QGramVocab] = None):
        t0 = time.perf_counter()
        self.db = db
        self.enc = EncodedDB.build(db, vocab)
        self.vocab = self.enc.vocab
        self.nv, self.ne = db.sizes()
        self.partition = default_partition(self.nv, self.ne, l=l)
        ri, rj = self.partition.region_of(self.nv, self.ne)
        self.region_i, self.region_j = ri, rj
        vmax = int(max(self.nv.max(), 1))
        from repro.graphs.batching import PaddedGraphBatch
        self.batch = PaddedGraphBatch.from_db(db, vmax=vmax)
        self.build_time_s = time.perf_counter() - t0

    # ---- CandidateSource protocol -----------------------------------------
    def candidate_ids(self, h: Graph, tau: int) -> List[int]:
        return self.candidates(h, tau)

    def filter_eval(self, backend: str = "auto", slab: str = "dense",
                    hot_d: Optional[int] = None,
                    hot_mass: Optional[float] = None,
                    tile_table=None, assign_lb: bool = True,
                    lb_hungarian: int = 0,
                    lb_tile_table=None) -> BatchedFilterEval:
        """The batched (Q, N) filter evaluator over this index's arrays
        (built lazily once per backend x FilterSlab layout, then reused
        across batches — DESIGN.md §11)."""
        cache = getattr(self, "_filter_evals", None)
        if cache is None:
            cache = self._filter_evals = {}
        if backend in cache:    # preregistered (e.g. the mesh-bound one)
            return cache[backend]
        if backend == "distributed":
            raise ValueError(
                "the distributed evaluator carries a mesh; register it "
                "with set_filter_eval (ShardedGraphQueryEngine does)")
        if slab == "hot" and hot_d is None:
            from repro.core.slab import DEFAULT_HOT_D, hot_d_from_mass
            # resolve hot_mass to a width up front so a mass-tuned and an
            # explicit hot_d evaluator of the same H share a cache entry;
            # memoized — the selector scans the whole encoded DB and this
            # runs on every batch's filter_eval lookup
            if hot_mass is not None:
                widths = getattr(self, "_hot_mass_widths", None)
                if widths is None:
                    widths = self._hot_mass_widths = {}
                if hot_mass not in widths:
                    widths[hot_mass] = hot_d_from_mass(self.enc, hot_mass)
                hot_d = widths[hot_mass]
            else:
                hot_d = DEFAULT_HOT_D
        elif slab != "hot":
            hot_d = None              # meaningless off-hot; don't fork keys
        # assign_lb / lb_hungarian fork the key: they change what the
        # evaluator computes per batch (the stage-1.5 LB pass, §16)
        key = (backend, slab, hot_d, bool(assign_lb), int(lb_hungarian))
        if key not in cache:
            cache[key] = BatchedFilterEval(self.db, self.enc,
                                           self.partition, backend,
                                           slab=slab, hot_d=hot_d,
                                           tile_table=tile_table,
                                           assign_lb=assign_lb,
                                           lb_hungarian=lb_hungarian,
                                           lb_tile_table=lb_tile_table)
        else:
            if tile_table is not None:
                # tiles never change results, so a late table swaps in
                # without forking the evaluator cache key
                cache[key]._tile_table = tile_table
            if lb_tile_table is not None:
                cache[key]._lb_tile_table = lb_tile_table
        return cache[key]

    def set_filter_eval(self, backend: str, ev: BatchedFilterEval) -> None:
        """Register a preconstructed evaluator (e.g. the sharded engine's
        mesh-bound one) under a backend name.  A replaced evaluator's
        device-resident slab cache is invalidated — nothing may keep
        serving stale uploads of a slab that is no longer registered
        (DESIGN.md §13)."""
        cache = getattr(self, "_filter_evals", None)
        if cache is None:
            cache = self._filter_evals = {}
        # a plain backend-name registration shadows every (backend, slab,
        # hot_d) evaluator filter_eval built for that backend — those
        # become unreachable, so their device caches go too
        for key, old in list(cache.items()):
            name = key[0] if isinstance(key, tuple) else key
            if name == backend and old is not ev:
                old.device_cache.invalidate()
        cache[backend] = ev

    def batched_candidates(self, graphs: Sequence[Graph],
                           taus: Sequence[int],
                           qtuples: Optional[Sequence[QueryTuple]] = None,
                           backend: str = "auto", slab: str = "dense",
                           hot_d: Optional[int] = None,
                           hot_mass: Optional[float] = None,
                           tile_table=None, assign_lb: bool = True,
                           lb_hungarian: int = 0,
                           lb_tile_table=None, faults=None) -> CandidateBatch:
        ev = self.filter_eval(backend, slab=slab, hot_d=hot_d,
                              hot_mass=hot_mass, tile_table=tile_table,
                              assign_lb=assign_lb, lb_hungarian=lb_hungarian,
                              lb_tile_table=lb_tile_table)
        if faults is not ev.faults:
            # the serving engine's injector rides along per call: the
            # evaluator is shared across engines (one per backend/slab
            # key), so attach rather than forking the cache key
            ev.set_faults(faults)
        return batched_flat_candidates(ev, graphs, taus, qtuples)

    def candidates(self, h: Graph, tau: int) -> List[int]:
        i1, i2, j1, j2 = self.partition.query_region(h.n, h.m, tau)
        in_region = ((self.region_i >= i1) & (self.region_i <= i2)
                     & (self.region_j >= j1) & (self.region_j <= j2))
        idx = np.flatnonzero(in_region)
        if len(idx) == 0:
            return []
        q = QueryTuple.from_graph(h, self.vocab)
        c_d = np.array([
            sparse_intersection_size(*self.enc.row_degree(int(g)),
                                     q.d_ids, q.d_cnt) for g in idx
        ], np.int64)
        vmax = self.batch.vmax
        q_sigma = np.zeros(vmax, np.int64)
        q_sigma[:min(h.n, vmax)] = q.sigma[:vmax]
        b = self.batch
        bounds = filters.batched_bounds_np(
            b.nv[idx], b.ne[idx], b.degseq[idx], b.vlabel_hist[idx],
            b.elabel_hist[idx], c_d, h.n, h.m, q_sigma,
            h.vertex_label_hist(self.vocab.n_vlabels),
            h.edge_label_hist(self.vocab.n_elabels))
        keep = bounds["combined"] <= tau
        return sorted(int(g) for g in idx[keep])

    def query(self, h: Graph, tau: int, verify: bool = True) -> QueryResult:
        t0 = time.perf_counter()
        cand = self.candidates(h, tau)
        t1 = time.perf_counter()
        matches = []
        if verify:
            for gid in cand:
                d = ged_upto(self.db[gid], h, tau)
                if d <= tau:
                    matches.append((gid, d))
        t2 = time.perf_counter()
        return QueryResult(cand, matches, len(self.db) - len(cand),
                           t1 - t0, t2 - t1)
