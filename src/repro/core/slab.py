"""FilterSlab: the single serving representation a bucket's filter pass
runs against (DESIGN.md §11).

The paper's headline claim is a *succinct* index, but a serving path that
materialises the full-vocab dense F_D matrix per host is the opposite.
This module makes the resident form a choice — three interchangeable
layouts behind one gather/c_d interface, so every backend (numpy / jax /
pallas / distributed) sees the same slab protocol and produces
bit-identical candidate sets:

* ``dense``  — (B, U) int32 F_D, today's behavior; fastest on narrow
  vocabularies, 32 bits per count.
* ``hot``    — dense hot prefix (B, H) over the frequency-ordered
  vocabulary plus a CSR *tail* (ids >= H).  The device computes the
  hot-prefix min-sum; the host adds the batched CSR tail correction
  (``qgrams.csr_tail_minsum``) to C_D *before* thresholding, which keeps
  the bound admissible (DESIGN.md §3).
* ``packed`` — the hybrid bit-packed rows of ``kernels/bitunpack``
  (``PackedRows``): per-128-entry blocks at the narrowest power-of-two
  width.  The resident slab is the succinct form; the filter pass decodes
  it on device (``unpack_rows_ref`` under jit/shard_map, the Pallas
  ``unpack_hybrid`` kernel on the pallas backend).

The non-F_D arrays (sizes, degree sequences, label histograms, region
coordinates) are identical across layouts; only the F_D carrier differs,
and ``size_bits()`` accounts for exactly that difference.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.arrays import DBArrays
from repro.core.qgrams import EncodedDB

LAYOUTS = ("dense", "hot", "packed")
DEFAULT_HOT_D = 128                  # keep in sync with MSQConfig.hot_d
_IMPOSSIBLE = -(2 ** 20)


def hot_d_from_mass(enc: EncodedDB, mass: float) -> int:
    """Data-tuned hot-prefix width: the smallest H whose frequency-ordered
    columns ``[0, H)`` cover at least ``mass`` of the database's total
    degree-q-gram count mass (``MSQConfig.hot_mass``; replaces the fixed
    ``DEFAULT_HOT_D`` when set).  The vocabulary is frequency-ordered
    (most frequent id 0), so the cumulative mass curve is concave and the
    smallest covering prefix is well-defined."""
    U = max(enc.vocab.n_degree_ids, 1)
    if len(enc.d_ids) == 0 or mass <= 0.0:
        return 1
    counts = np.bincount(np.asarray(enc.d_ids, np.int64),
                         weights=np.asarray(enc.d_cnt, np.float64),
                         minlength=U)
    total = float(counts.sum())
    if total <= 0.0:
        return 1
    target = min(float(mass), 1.0) * total
    cum = np.cumsum(counts)
    # smallest H with cum[H-1] >= target (epsilon guards float equality)
    H = int(np.searchsorted(cum, target - 1e-9, side="left")) + 1
    return max(1, min(H, U))


def branch_features(graphs, n_elabels: int, vmax: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-vertex *branch* structures for the assignment lower bound
    (DESIGN.md §16): for every vertex its label, degree, and incident
    edge-label histogram.  Padded to ``vmax`` with label -1 / degree 0 /
    zero histograms — pad slots then price exactly like the ε
    (insert/delete) column of the branch cost matrix, so the batched
    min-reduce needs no explicit pad masking on the min axes.

    Returns ``(vlab (B, vmax) int32, deg (B, vmax) int32,
    ehist (B, vmax, n_elabels) int32)``.
    """
    B = len(graphs)
    vlab = np.full((B, vmax), -1, np.int32)
    deg = np.zeros((B, vmax), np.int32)
    eh = np.zeros((B, vmax, max(n_elabels, 1)), np.int32)
    for i, g in enumerate(graphs):
        n = min(int(g.n), vmax)
        vlab[i, :n] = np.asarray(g.vlabels[:n], np.int32)
        if g.m:
            edges = np.asarray(g.edges, np.int64)
            elab = np.asarray(g.elabels, np.int64)
            np.add.at(deg[i], edges.ravel(), 1)
            np.add.at(eh[i], (edges.ravel(), np.repeat(elab, 2)), 1)
    return vlab, deg, eh


def _ragged_take(off: np.ndarray, ids: np.ndarray, cnt: np.ndarray,
                 rows: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Gather CSR rows: new (off, ids, cnt) for ``rows`` in order."""
    rows = np.asarray(rows, np.int64)
    lengths = (off[rows + 1] - off[rows]).astype(np.int64)
    new_off = np.zeros(len(rows) + 1, np.int64)
    np.cumsum(lengths, out=new_off[1:])
    pos = (np.repeat(off[rows], lengths)
           + np.arange(int(new_off[-1]), dtype=np.int64)
           - np.repeat(new_off[:-1], lengths))
    return new_off, ids[pos], cnt[pos]


@dataclass
class FilterSlab:
    """One bucket-servable database slab in a chosen F_D layout.

    Always-dense per-graph arrays (the filter cascade's small operands)
    plus exactly one F_D carrier: ``fd`` (dense (B, U) or hot (B, H)),
    the ``hot`` tail CSR (``t_off``/``t_ids``/``t_cnt``, ids >= hot_d),
    or ``packed`` (``PackedRows``).
    """

    layout: str
    nv: np.ndarray
    ne: np.ndarray
    degseq: np.ndarray
    vhist: np.ndarray
    ehist: np.ndarray
    region_i: np.ndarray
    region_j: np.ndarray
    U: int                       # full degree-vocabulary width
    hot_d: int                   # == U for dense/packed
    vmax: int
    fd: Optional[np.ndarray] = None
    t_off: Optional[np.ndarray] = None
    t_ids: Optional[np.ndarray] = None
    t_cnt: Optional[np.ndarray] = None
    packed: Optional["PackedRows"] = None        # noqa: F821
    # per-vertex branch structures for the stage-1.5 assignment lower
    # bound (DESIGN.md §16) — layout-independent, like nv/degseq
    bvlab: Optional[np.ndarray] = None           # (B, vmax), pad -1
    bdeg: Optional[np.ndarray] = None            # (B, vmax), pad 0
    behist: Optional[np.ndarray] = None          # (B, vmax, NE), pad 0
    _fd_cache: Optional[np.ndarray] = None       # lazy packed host decode
    _t_rows: Optional[np.ndarray] = None         # lazy tail entry -> row map

    # ---- construction -----------------------------------------------------
    @classmethod
    def build(cls, db, enc: EncodedDB, partition, *, layout: str = "dense",
              hot_d: Optional[int] = None,
              hot_mass: Optional[float] = None) -> "FilterSlab":
        if layout not in LAYOUTS:
            raise ValueError(f"unknown slab layout {layout!r} "
                             f"(one of {LAYOUTS})")
        from repro.graphs.batching import PaddedGraphBatch
        nv, ne = db.sizes()
        vmax = int(max(nv.max(), 1)) if len(nv) else 1
        batch = PaddedGraphBatch.from_db(db, vmax=vmax)
        U = max(enc.vocab.n_degree_ids, 1)
        ri, rj = partition.region_of(nv, ne)
        slab = cls(
            layout=layout,
            nv=batch.nv.astype(np.int32), ne=batch.ne.astype(np.int32),
            degseq=batch.degseq.astype(np.int32),
            vhist=batch.vlabel_hist.astype(np.int32),
            ehist=batch.elabel_hist.astype(np.int32),
            region_i=ri.astype(np.int32), region_j=rj.astype(np.int32),
            U=U, hot_d=U, vmax=vmax)
        slab.bvlab, slab.bdeg, slab.behist = branch_features(
            db.graphs, db.n_elabels, vmax)
        if layout == "dense":
            fd, _ = enc.dense_hot(U)
            slab.fd = fd.astype(np.int32)
        elif layout == "hot":
            # explicit hot_d wins; else a hot_mass target picks H from the
            # data; else the fixed default — hot without any width must
            # not silently degenerate to the dense slab
            if hot_d is not None:
                H = int(hot_d)
            elif hot_mass is not None:
                H = hot_d_from_mass(enc, hot_mass)
            else:
                H = DEFAULT_HOT_D
            H = max(1, min(H, U))
            slab.hot_d = H
            fd, _ = enc.dense_hot(H)
            slab.fd = fd.astype(np.int32)
            mask = enc.d_ids >= H
            row_of = np.repeat(np.arange(len(enc)), np.diff(enc.d_off))
            slab.t_ids = enc.d_ids[mask].astype(np.int32)
            slab.t_cnt = enc.d_cnt[mask].astype(np.int32)
            t_off = np.zeros(len(enc) + 1, np.int64)
            np.cumsum(np.bincount(row_of[mask], minlength=len(enc)),
                      out=t_off[1:])
            slab.t_off = t_off
        else:  # packed
            from repro.kernels.bitunpack.ops import pack_hybrid_rows
            fd, _ = enc.dense_hot(U)
            slab.packed = pack_hybrid_rows(fd)
        return slab

    @property
    def B(self) -> int:
        return len(self.nv)

    # ---- bucket gather ----------------------------------------------------
    def gather(self, idx: np.ndarray,
               n_pad: Optional[int] = None) -> "FilterSlab":
        """Row-gather a bucket sub-slab, optionally padded to ``n_pad``
        with impossible graphs (never in-region, zero F_D)."""
        idx = np.asarray(idx, np.int64)
        n_pad = len(idx) if n_pad is None else int(n_pad)
        pad = n_pad - len(idx)

        def take(x, fill=0):
            sub = np.asarray(x)[idx]
            if pad:
                widths = [(0, pad)] + [(0, 0)] * (sub.ndim - 1)
                sub = np.pad(sub, widths, constant_values=fill)
            return sub

        sub = replace(
            self,
            _fd_cache=None, _t_rows=None,
            nv=take(self.nv), ne=take(self.ne), degseq=take(self.degseq),
            vhist=take(self.vhist), ehist=take(self.ehist),
            region_i=take(self.region_i, _IMPOSSIBLE),
            region_j=take(self.region_j, _IMPOSSIBLE),
            bvlab=None if self.bvlab is None else take(self.bvlab, -1),
            bdeg=None if self.bdeg is None else take(self.bdeg),
            behist=None if self.behist is None else take(self.behist))
        if self.fd is not None:
            sub.fd = take(self.fd)
        if self.layout == "hot":
            t_off, t_ids, t_cnt = _ragged_take(self.t_off, self.t_ids,
                                               self.t_cnt, idx)
            if pad:          # pad rows have empty tails
                t_off = np.concatenate(
                    [t_off, np.full(pad, t_off[-1], np.int64)])
            sub.t_off, sub.t_ids, sub.t_cnt = t_off, t_ids, t_cnt
        if self.layout == "packed":
            from repro.kernels.bitunpack.ops import WIDTHS, PackedRows
            pk = self.packed
            words = pk.words[idx]
            sb = pk.sb[idx]
            widths = pk.widths[idx]
            if pad:
                KB = sb.shape[1]
                # a pad row decodes to zeros: zero words at the narrowest
                # width (4*w words per block, so offsets fit any real W)
                w0 = WIDTHS[0]
                zero_sb = (np.arange(KB, dtype=np.int32) * 4 * w0)[None, :]
                words = np.vstack(
                    [words, np.zeros((pad, words.shape[1]), words.dtype)])
                sb = np.vstack([sb, np.repeat(zero_sb, pad, axis=0)])
                widths = np.vstack(
                    [widths, np.full((pad, KB), w0, widths.dtype)])
            sub.packed = PackedRows(words=words, sb=sb, widths=widths,
                                    n_entries=pk.n_entries)
        return sub

    def in_rect(self, rect: Tuple[int, int, int, int]) -> np.ndarray:
        i1, i2, j1, j2 = rect
        m = ((self.region_i >= i1) & (self.region_i <= i2)
             & (self.region_j >= j1) & (self.region_j <= j2))
        return np.flatnonzero(m)

    # ---- device-side view -------------------------------------------------
    def base_arrays(self) -> DBArrays:
        """The DBArrays a filter pass consumes.  ``fd`` is the layout's
        dense carrier: full matrix (dense), hot prefix (hot), or a (B, 1)
        placeholder (packed — the pass decodes ``self.packed`` itself and
        supplies C_D explicitly)."""
        fd = self.fd
        if fd is None:
            fd = np.zeros((self.B, 1), np.int32)
        return DBArrays(nv=self.nv, ne=self.ne, degseq=self.degseq,
                        vhist=self.vhist, ehist=self.ehist, fd=fd,
                        region_i=self.region_i, region_j=self.region_j)

    # ---- host C_D (numpy backend + overflow fallback) ---------------------
    def fd_dense_np(self) -> np.ndarray:
        """Full-width dense F_D (decodes packed once per gathered slab;
        rebuilding hot tails is the caller's job via ``cd_one`` — hot
        keeps no dense tail on purpose)."""
        if self.layout == "packed":
            if self._fd_cache is None:
                from repro.kernels.bitunpack.ops import unpack_rows_np
                self._fd_cache = unpack_rows_np(self.packed)
            return self._fd_cache
        return self.fd

    def cd_one(self, qfd: np.ndarray) -> np.ndarray:
        """(B,) exact C_D against one full-width dense query F_D.

        Query-sparse (DESIGN.md §13): only the query's nonzero columns are
        gathered — ``min(F_D, 0) = 0`` makes the rest a guaranteed no-op,
        so this is bit-identical to the full-width sweep at a fraction of
        the work (queries touch a few dozen of potentially thousands of
        vocabulary columns)."""
        qfd = np.asarray(qfd, np.int64)
        if self.layout == "hot":
            ids = np.flatnonzero(qfd[:self.hot_d] > 0)
            hot = np.minimum(self.fd[:, ids].astype(np.int64),
                             qfd[ids][None, :]).sum(axis=1)
            return hot + self.tail_minsum_one(qfd)
        fd = self.fd_dense_np()
        ids = np.flatnonzero(qfd[:fd.shape[1]] > 0)
        return np.minimum(fd[:, ids].astype(np.int64),
                          qfd[ids][None, :]).sum(axis=1)

    def tail_minsum_one(self, qfd: np.ndarray) -> np.ndarray:
        """(B,) batched CSR tail correction for one dense query F_D.

        The tail CSR already holds only ids >= hot_d, and the query is
        dense, so this is one gather + bincount over the tail nnz; the
        query-independent entry->row map is computed once per slab.
        """
        if self._t_rows is None:
            self._t_rows = np.repeat(np.arange(self.B),
                                     np.diff(self.t_off))
        qfd = np.asarray(qfd, np.int64)
        contrib = np.minimum(self.t_cnt.astype(np.int64),
                             qfd[self.t_ids])
        return np.bincount(self._t_rows, weights=contrib,
                           minlength=self.B).astype(np.int64)

    def tail_minsum_batch(self, qfds: np.ndarray) -> np.ndarray:
        """(Q, B) tail corrections for a stacked query block."""
        return np.stack([self.tail_minsum_one(q) for q in qfds])

    # ---- size accounting (DESIGN.md §11) ----------------------------------
    def size_bits(self) -> Dict[str, int]:
        """Bits of the layout-specific F_D carrier (the slab parts shared
        by every layout are excluded — they don't differentiate)."""
        if self.layout == "dense":
            fd_bits = self.fd.size * 32
            return {"fd": fd_bits, "total": fd_bits}
        if self.layout == "hot":
            fd_bits = self.fd.size * 32
            tail_bits = (len(self.t_ids) * 32 + len(self.t_cnt) * 32
                         + len(self.t_off) * 64)
            return {"fd": fd_bits, "tail": tail_bits,
                    "total": fd_bits + tail_bits}
        from repro.kernels.bitunpack.ops import packed_rows_size_bits
        s = packed_rows_size_bits(self.packed)
        return {"words": s["words"], "sb": s["sb"], "widths": s["widths"],
                "ragged_payload": s["ragged_payload"], "total": s["total"]}

    def bits_per_graph(self) -> float:
        return self.size_bits()["total"] / max(self.B, 1)
