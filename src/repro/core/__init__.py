# The paper's primary contribution — the MSQ-Index system:
#   qgrams     — degree-/label-based q-gram extraction + vocabularies
#   filters    — the admissible lower-bound filters (Lemmas 2, 5)
#   succinct   — bit vectors, rank, Elias/Golomb coders, hybrid blocks
#   tree       — q-gram tree + succinct representation (Algorithm 1)
#   region     — reduced query region (Section 4, formula (1))
#   search     — MSQIndex / FlatMSQIndex end-to-end engines (Algorithm 2)
#   verify     — exact GED (A* with cutoff)
#   baselines  — C-Star / Branch / path q-grams / kappa-AT competitors
#   filters_jax, distributed — accelerator + multi-pod paths

#   engine     — batched multi-query candidate generation (CandidateSource)

from repro.core.search import MSQIndex, FlatMSQIndex, QueryResult
from repro.core.engine import (BatchedFilterEval, CandidateBatch,
                               CandidateSource, bucket_queries)

__all__ = ["MSQIndex", "FlatMSQIndex", "QueryResult", "BatchedFilterEval",
           "CandidateBatch", "CandidateSource", "bucket_queries"]
