"""Exact graph edit distance — the verification phase (Section 6.2).

``ged_upto(g, h, tau)`` is the production entry point: A* over vertex
mappings with an admissible label-count heuristic and an f-cost cutoff at
``tau`` (verification only needs to decide ged <= tau; the cutoff keeps the
NP-hard search tractable for the candidate sets the filters leave).
Returns the exact GED if <= tau, else ``tau + 1``.

``GEDSearch`` is the resumable form the serving worklist uses
(DESIGN.md §12): one instance holds the A* frontier for one
(db graph, query, tau) pair, and ``run`` accepts an expansion budget
and/or a wall-clock deadline — an undecided search keeps its heap and a
later ``run`` continues exactly where it stopped, so verifier workers can
timeslice expensive pairs and honor per-query deadlines without losing
work.  ``min_f`` exposes the frontier's cheapest f-cost, the honest
worklist priority of a partially-run search.

``ged_exact`` runs without cutoff (tiny graphs / tests).
``ged_bruteforce`` is an independent oracle by exhaustive enumeration over
padded vertex bijections (tests only).

Cost model (the paper's six primitives, unit costs): vertex ins/del/sub,
edge ins/del/sub; substitution is free when labels match.
"""
from __future__ import annotations

import heapq
import itertools
import os
import time
from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graphs.graph import Graph

INF = 10 ** 9


def _edge_dict(g: Graph) -> Dict[Tuple[int, int], int]:
    return {(int(u), int(v)): int(l) for (u, v), l in zip(g.edges, g.elabels)}


def _order_query_vertices(h: Graph) -> List[int]:
    """Connectivity-aware, high-degree-first processing order."""
    if h.n == 0:
        return []
    deg = h.degrees()
    adj = [set() for _ in range(h.n)]
    for (u, v) in h.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    order: List[int] = []
    seen = set()
    while len(order) < h.n:
        # seed: highest-degree unseen vertex
        cand = [v for v in range(h.n) if v not in seen]
        seed = max(cand, key=lambda v: deg[v])
        frontier = [seed]
        seen.add(seed)
        order.append(seed)
        while True:
            nbrs = sorted(
                {w for v in order for w in adj[v] if w not in seen},
                key=lambda v: -deg[v])
            if not nbrs:
                break
            v = nbrs[0]
            seen.add(v)
            order.append(v)
    return order


def _heuristic(g: Graph, h: Graph, order: List[int], k: int,
               used_g: int, vlab_h_rem: Counter, elab_h_rem: Counter,
               g_vlab_all: Counter, g_elab_all: Counter,
               mapped_g_vlab: Counter, scored_g_edges: Counter) -> int:
    """Admissible label-count estimate of the remaining cost."""
    n_h_rem = h.n - k
    n_g_rem = g.n - bin(used_g).count("1")
    g_vlab_rem = g_vlab_all - mapped_g_vlab
    ov_v = sum(min(vlab_h_rem[l], g_vlab_rem[l]) for l in vlab_h_rem)
    v_cost = max(n_h_rem, n_g_rem) - ov_v
    e_h_rem = sum(elab_h_rem.values())
    g_elab_rem = g_elab_all - scored_g_edges
    e_g_rem = sum(g_elab_rem.values())
    ov_e = sum(min(elab_h_rem[l], g_elab_rem[l]) for l in elab_h_rem)
    e_cost = max(e_h_rem, e_g_rem) - ov_e
    return max(v_cost, 0) + max(e_cost, 0)


class GEDSearch:
    """Resumable, budgeted A* deciding ``ged(g, h) <= tau`` (DESIGN.md §12).

    ``run`` pops frontier states until the search decides, the expansion
    budget runs out, or the wall-clock deadline passes; an undecided run
    returns ``None`` and a later ``run`` resumes from the saved heap.  The
    decision (exact GED if <= tau, else ``tau + 1``) is identical to the
    unbudgeted search regardless of how the work was sliced.
    """

    __slots__ = ("g", "h", "tau", "lb", "order", "h_edges", "g_edges",
                 "g_vlab_all", "g_elab_all", "vlab_suffix", "elab_suffix",
                 "heap", "result", "expansions")

    def __init__(self, g: Graph, h: Graph, tau: int, *,
                 initial_bound: int = 0):
        """``initial_bound`` is an externally proven GED lower bound (the
        stage-1.5 assignment LB, DESIGN.md §16): ``initial_bound > tau``
        decides ``tau + 1`` with zero expansions, and ``min_f`` never
        reports below it — the search's own frontier usually starts
        looser, so the seeded bound keeps the worklist priority honest.
        Decisions are unchanged: a provable bound can only shortcut work
        A* would have done anyway."""
        self.g, self.h, self.tau = g, h, int(tau)
        self.lb = int(initial_bound)
        tau = self.tau
        self.order = order = _order_query_vertices(h)
        self.h_edges = h_edges = _edge_dict(h)
        self.g_edges = _edge_dict(g)
        self.g_vlab_all = Counter(int(x) for x in g.vlabels)
        self.g_elab_all = Counter(int(x) for x in g.elabels)

        # per-depth remaining h label multisets (precomputed suffix counters)
        vlab_suffix: List[Counter] = [Counter() for _ in range(h.n + 1)]
        for k in range(h.n - 1, -1, -1):
            vlab_suffix[k] = vlab_suffix[k + 1].copy()
            vlab_suffix[k][int(h.vlabels[order[k]])] += 1
        # h edges become "scored" when their second endpoint is processed
        pos_in_order = {v: i for i, v in enumerate(order)}
        elab_suffix: List[Counter] = [Counter() for _ in range(h.n + 1)]
        for k in range(h.n - 1, -1, -1):
            elab_suffix[k] = elab_suffix[k + 1].copy()
            for (a, b), l in h_edges.items():
                if max(pos_in_order[a], pos_in_order[b]) == k:
                    elab_suffix[k][l] += 1
        self.vlab_suffix, self.elab_suffix = vlab_suffix, elab_suffix

        self.expansions = 0
        self.result: Optional[int] = None
        self.heap: list = []
        start_h = _heuristic(g, h, order, 0, 0, vlab_suffix[0],
                             elab_suffix[0], self.g_vlab_all,
                             self.g_elab_all, Counter(), Counter())
        if max(start_h, self.lb) > tau:
            self.result = tau + 1
        elif h.n == 0:
            c = self._completion_cost(0)
            self.result = c if c <= tau else tau + 1
        else:
            # state: (f, cost, depth, used_g bitmask, mapping tuple)
            self.heap = [(start_h, 0, 0, 0, ())]

    @property
    def done(self) -> bool:
        return self.result is not None

    def min_f(self) -> int:
        """Best lower bound on the final answer so far: the decision when
        done, else the frontier's cheapest f-cost (the honest worklist
        priority of a partially-run search)."""
        if self.result is not None:
            return self.result
        f = self.heap[0][0] if self.heap else self.tau + 1
        return max(f, self.lb)

    def frontier(self) -> Tuple[int, int]:
        """``(expansions, open_nodes)`` — where a paused search stands.

        Used by the scheduler's pool-recovery path (and its tests) to
        assert that a re-enqueued search resumes from its last frontier
        instead of restarting from scratch."""
        return self.expansions, len(self.heap)

    def _completion_cost(self, used_g: int) -> int:
        """Insert the unmatched g vertices and all their incident edges."""
        rem = [v for v in range(self.g.n) if not (used_g >> v) & 1]
        total = len(rem)
        rem_set = set(rem)
        for (a, b) in self.g_edges:
            if a in rem_set or b in rem_set:
                total += 1
        return total

    def run(self, max_expansions: Optional[int] = None,
            deadline: Optional[float] = None) -> Optional[int]:
        """Continue the search.  Returns the decision (exact GED if <= tau,
        else ``tau + 1``), or ``None`` when the budget/deadline ran out
        first (call ``run`` again to resume)."""
        if self.result is not None:
            return self.result
        g, h, tau = self.g, self.h, self.tau
        order, h_edges, g_edges = self.order, self.h_edges, self.g_edges
        g_vlab_all, g_elab_all = self.g_vlab_all, self.g_elab_all
        vlab_suffix, elab_suffix = self.vlab_suffix, self.elab_suffix
        heap = self.heap
        popped = 0
        while heap:
            if max_expansions is not None and popped >= max_expansions:
                return None
            if deadline is not None and time.perf_counter() >= deadline:
                return None
            f, cost, k, used_g, mapping = heapq.heappop(heap)
            popped += 1
            self.expansions += 1
            if f > tau:
                self.result = tau + 1
                return self.result
            if k == h.n:
                self.result = cost  # completion cost folded in at push time
                return self.result
            u = order[k]
            lu = int(h.vlabels[u])
            # counters describing already-scored material (for the heuristic)
            mapped_g_vlab = Counter(int(g.vlabels[v])
                                    for v in mapping if v >= 0)
            scored_g_edges: Counter = Counter()
            mapped_pairs = [(order[i], mapping[i]) for i in range(k)
                            if mapping[i] >= 0]
            for i in range(len(mapped_pairs)):
                for j in range(i + 1, len(mapped_pairs)):
                    va, vb = mapped_pairs[i][1], mapped_pairs[j][1]
                    a, b = (va, vb) if va < vb else (vb, va)
                    if (a, b) in g_edges:
                        scored_g_edges[g_edges[(a, b)]] += 1

            def edge_delta(v: int) -> int:
                d = 0
                for i in range(k):
                    uj, vj = order[i], mapping[i]
                    a, b = (u, uj) if u < uj else (uj, u)
                    hl = h_edges.get((a, b))
                    if v < 0 or vj < 0:
                        if hl is not None:
                            d += 1  # edge to a deleted endpoint gets deleted
                        continue
                    ga, gb = (v, vj) if v < vj else (vj, v)
                    gl = g_edges.get((ga, gb))
                    if hl is not None and gl is not None:
                        d += int(hl != gl)
                    elif hl is not None or gl is not None:
                        d += 1
                return d

            children = []
            for v in range(g.n):
                if (used_g >> v) & 1:
                    continue
                c = cost + int(lu != int(g.vlabels[v])) + edge_delta(v)
                children.append((c, v))
            children.append((cost + 1 + edge_delta(-1), -1))  # deletion

            for c, v in children:
                if c > tau:
                    continue
                new_used = used_g | (1 << v) if v >= 0 else used_g
                new_mapping = mapping + (v,)
                m_vlab = mapped_g_vlab.copy()
                s_edges = scored_g_edges.copy()
                if v >= 0:
                    m_vlab[int(g.vlabels[v])] += 1
                    for i in range(k):
                        vj = mapping[i]
                        if vj >= 0:
                            a, b = (v, vj) if v < vj else (vj, v)
                            if (a, b) in g_edges:
                                s_edges[g_edges[(a, b)]] += 1
                if k + 1 == h.n:
                    total = c + self._completion_cost(new_used)
                    if total <= tau:
                        heapq.heappush(heap, (total, total, k + 1, new_used,
                                              new_mapping))
                    continue
                hh = _heuristic(g, h, order, k + 1, new_used,
                                vlab_suffix[k + 1], elab_suffix[k + 1],
                                g_vlab_all, g_elab_all, m_vlab, s_edges)
                if c + hh <= tau:
                    heapq.heappush(heap, (c + hh, c, k + 1, new_used,
                                          new_mapping))
        self.result = tau + 1
        return self.result


def run_search_slice(search: GEDSearch, max_expansions: Optional[int],
                     deadline: Optional[float], want_span: bool = False):
    """One worker-side A* timeslice: run the (picklable) search and send
    it back with its decision — the ``VerifyScheduler`` process-pool
    executor's unit of work (DESIGN.md §12).  The returned search carries
    the advanced frontier, so an undecided slice resumes exactly like the
    in-process path.  ``deadline`` stays comparable across processes
    because ``time.perf_counter`` is CLOCK_MONOTONIC (system-wide) on the
    Linux hosts the pool runs on — which is also what lets the
    ``want_span`` timing fragment ``(t0, t1, pid)`` land on the host
    span timeline (DESIGN.md §17) without clock translation."""
    if not want_span:
        d = search.run(max_expansions=max_expansions, deadline=deadline)
        return d, search
    t0 = time.perf_counter()
    d = search.run(max_expansions=max_expansions, deadline=deadline)
    t1 = time.perf_counter()
    return d, search, (t0, t1, os.getpid())


def ged_upto(g: Graph, h: Graph, tau: int, *,
             max_expansions: Optional[int] = None,
             deadline: Optional[float] = None) -> Optional[int]:
    """Exact GED if <= tau, else tau + 1.  A* with cutoff pruning.

    With a budget (``max_expansions`` heap pops and/or an absolute
    ``deadline`` from ``time.perf_counter()``), returns ``None`` when the
    budget ran out before the search decided — resume via ``GEDSearch``.
    """
    return GEDSearch(g, h, tau).run(max_expansions=max_expansions,
                                    deadline=deadline)


def ged_exact(g: Graph, h: Graph) -> int:
    """Exact GED without a caller-supplied cutoff (tiny graphs only).

    Iterative deepening keeps the cutoff pruning of ``ged_upto`` effective.
    """
    tau = 0
    hi = g.n + h.n + g.m + h.m  # delete everything, insert everything
    while tau <= hi:
        r = ged_upto(g, h, tau)
        if r <= tau:
            return r
        tau = max(tau + 1, min(2 * max(tau, 1), hi))
    return hi


def ged_bruteforce(g: Graph, h: Graph) -> int:
    """Independent exhaustive oracle (pads with epsilon vertices)."""
    n_g, n_h = g.n, h.n
    g_edges = _edge_dict(g)
    h_edges = _edge_dict(h)
    best = INF
    # images: injective map from h vertices to g vertices or eps (-1)
    g_slots = list(range(n_g)) + [-1] * n_h
    seen = set()
    for perm in itertools.permutations(g_slots, n_h):
        if perm in seen:
            continue
        seen.add(perm)
        cost = 0
        for u in range(n_h):
            v = perm[u]
            if v < 0:
                cost += 1
            elif int(h.vlabels[u]) != int(g.vlabels[v]):
                cost += 1
        used = {v for v in perm if v >= 0}
        cost += n_g - len(used)  # inserted g vertices
        # h edges
        for (a, b), hl in h_edges.items():
            va, vb = perm[a], perm[b]
            if va < 0 or vb < 0:
                cost += 1
                continue
            x, y = (va, vb) if va < vb else (vb, va)
            gl = g_edges.get((x, y))
            cost += 1 if gl is None else int(gl != hl)
        # g edges with no h counterpart
        inv = {v: u for u, v in enumerate(perm) if v >= 0}
        for (x, y) in g_edges:
            if x in inv and y in inv:
                a, b = inv[x], inv[y]
                a, b = (a, b) if a < b else (b, a)
                if (a, b) not in h_edges:
                    cost += 1
            else:
                cost += 1
        best = min(best, cost)
    return best
