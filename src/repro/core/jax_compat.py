"""Version-compat shims over the moving jax sharding API surface.

The repo targets the newest jax (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType`` / ``set_mesh``) but must also run on the older
release baked into the CI container (0.4.x: ``jax.experimental.shard_map``
with ``check_rep``, no AxisType, no mesh context manager).  Everything that
touches those APIs goes through here so the difference lives in one place.

Usage:
    from repro.core import jax_compat as jc
    mesh = jc.make_mesh((2, 4), ("data", "model"))
    fn = jc.shard_map(f, mesh=mesh, in_specs=..., out_specs=...)
    with jc.set_mesh(mesh):
        ...
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n}`` on new jax, ``{}`` on old.

    Old jax has neither ``jax.sharding.AxisType`` nor the ``axis_types``
    parameter on ``jax.make_mesh``; every mesh there behaves like Auto.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **axis_types_kwargs(len(axes)))


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map(..., check_vma=False)`` or the experimental fallback.

    Replication checking is disabled on both paths (the repo's collectives
    are explicit; the check only costs tracing time and has been renamed
    between releases).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def set_mesh(mesh):
    """Context manager form of ``jax.sharding.set_mesh`` (no-op on old jax).

    On old jax all our distributed entry points pass explicit shardings to
    ``jax.jit``, so there is nothing the ambient-mesh context needs to do.
    """
    ctx = (getattr(jax.sharding, "set_mesh", None)
           or getattr(jax.sharding, "use_mesh", None))
    if ctx is None:
        return contextlib.nullcontext(mesh)
    return ctx(mesh)


def axis_size(mesh, axis_name: str) -> int:
    """Static mesh-axis size (``jax.lax.axis_size`` is newer than 0.4.x)."""
    return int(mesh.shape[axis_name])


def named_axis_size(axis_name: str):
    """``jax.lax.axis_size`` inside a shard_map/pmap body, with the classic
    ``psum(1, axis)`` fallback (constant-folds to a static int)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
