"""Batched multi-query candidate generation — the GraphQueryEngine core.

The per-query path (``MSQIndex.query`` / ``FlatMSQIndex.query``) walks the
index once per request: region reduction, then a Python sweep over the
region's graphs.  Serving batches of queries, that repeats all of the
region bookkeeping and — worse — re-touches every region graph once per
query.  This module amortises both (Nass / EmbAssi style):

Stage 1 — ``bucket_queries``: group requests by their reduced query region
  rectangle (formula (1)).  Every query in a bucket prunes against the
  *identical* set of region graphs, so that set is gathered once per batch.

Stage 2 — ``BatchedFilterEval``: evaluate the full leaf-level filter
  cascade for a whole bucket in one padded (Q, N) pass.  Backends:
  ``jax`` (jit + vmap over ``filters_jax.batched_bounds``), ``numpy``
  (vectorised per-query rows, no device round-trip), and ``pallas``
  (the fused q-gram filter kernel per query; interpret mode off-TPU).

Stage 3 (shared verification worklist) lives in
``repro.serve.graph_engine``; the ``CandidateSource`` protocol below is
what lets that engine run tree-backed (``MSQIndex``) or flat
(``FlatMSQIndex``) without caring which.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core import arrays, filters
from repro.core.arrays import DBArrays, QueryArrays
from repro.core.qgrams import EncodedDB, QGramVocab
from repro.core.region import RegionPartition
from repro.core.tree import QueryTuple
from repro.graphs.graph import Graph, GraphDB

Rect = Tuple[int, int, int, int]          # inclusive (i1, i2, j1, j2)

# shape buckets for the jit'd (Q, N) pass: pad to these multiples so the
# number of distinct compiled programs stays small across buckets
_Q_PAD = 8
_N_PAD = 512
_IMPOSSIBLE = -(2 ** 20)


@runtime_checkable
class CandidateSource(Protocol):
    """What the serving engine needs from an index (tree or flat)."""

    db: GraphDB
    vocab: QGramVocab
    partition: RegionPartition

    def candidate_ids(self, h: Graph, tau: int) -> List[int]:
        """Sorted candidate graph ids for one query."""
        ...

    def batched_candidates(self, graphs: Sequence[Graph],
                           taus: Sequence[int],
                           qtuples: Optional[Sequence[QueryTuple]] = None
                           ) -> "CandidateBatch":
        """Candidates for a whole batch; per-query order preserved."""
        ...


@dataclass
class CandidateBatch:
    """Per-query candidate ids plus (when the source computes them) the
    filter lower bounds, used to order the shared verification worklist."""

    ids: List[List[int]]
    bounds: List[Optional[np.ndarray]]     # aligned with ids; None for trees


def bucket_queries(partition: RegionPartition, graphs: Sequence[Graph],
                   taus: Sequence[int]) -> Dict[Rect, List[int]]:
    """Stage 1: query indices grouped by reduced-query-region rectangle."""
    buckets: Dict[Rect, List[int]] = {}
    for qi, (h, tau) in enumerate(zip(graphs, taus)):
        rect = partition.query_region(h.n, h.m, int(tau))
        buckets.setdefault(rect, []).append(qi)
    return buckets


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def resolve_backend() -> str:
    """Best default for the host: the jit/vmap pass on an accelerator,
    plain vectorised numpy on CPU (no compile latency, same candidates)."""
    from repro.kernels.qgram_filter.ops import on_tpu
    return "jax" if on_tpu() else "numpy"


@functools.lru_cache(maxsize=None)
def _bounds_multi_jit():
    """jit'd (Q, N) filter pass: vmap of the single-query cascade."""
    import jax

    from repro.core import filters_jax as fj

    def multi(db: DBArrays, qb: QueryArrays) -> "jax.Array":
        return jax.vmap(lambda q: fj.batched_bounds(db, q))(qb)

    return jax.jit(multi)


class BatchedFilterEval:
    """Stage 2: the padded (Q, N) leaf-level filter pass.

    Holds the database-side arrays (built once, reused across batches) and
    evaluates the combined admissible bound for every (query, graph) pair
    of a bucket.  Inputs are bit-identical to what ``FlatMSQIndex`` feeds
    ``filters.batched_bounds_np``, so candidate sets match exactly.
    """

    def __init__(self, db: GraphDB, enc: EncodedDB,
                 partition: RegionPartition, backend: str = "auto"):
        if backend == "auto":
            backend = resolve_backend()
        if backend not in ("jax", "numpy", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.vocab = enc.vocab
        self.partition = partition
        from repro.graphs.batching import PaddedGraphBatch
        nv, ne = db.sizes()
        self.vmax = int(max(nv.max(), 1)) if len(nv) else 1
        batch = PaddedGraphBatch.from_db(db, vmax=self.vmax)
        U = max(self.vocab.n_degree_ids, 1)
        fd, _ = enc.dense_hot(U)
        ri, rj = partition.region_of(nv, ne)
        self.arrays = DBArrays(
            nv=batch.nv.astype(np.int32), ne=batch.ne.astype(np.int32),
            degseq=batch.degseq.astype(np.int32),
            vhist=batch.vlabel_hist.astype(np.int32),
            ehist=batch.elabel_hist.astype(np.int32),
            fd=fd.astype(np.int32),
            region_i=ri.astype(np.int32), region_j=rj.astype(np.int32))

    # ---- query-side arrays ------------------------------------------------
    def query_arrays(self, h: Graph, tau: int,
                     qt: Optional[QueryTuple] = None) -> QueryArrays:
        return arrays.query_arrays_from_graph(h, self.vocab, self.partition,
                                              tau, self.vmax, qt=qt)

    def stack_queries(self, qs: Sequence[QueryArrays]) -> QueryArrays:
        """(Q, ...) stacked query arrays (leading axis = query)."""
        return QueryArrays(*[np.stack([np.asarray(getattr(q, f))
                                       for q in qs])
                             for f in QueryArrays._fields])

    def graphs_in_rect(self, rect: Rect) -> np.ndarray:
        i1, i2, j1, j2 = rect
        m = ((self.arrays.region_i >= i1) & (self.arrays.region_i <= i2)
             & (self.arrays.region_j >= j1) & (self.arrays.region_j <= j2))
        return np.flatnonzero(m)

    # ---- the (Q, N) pass --------------------------------------------------
    def bounds(self, idx: np.ndarray,
               qs: Sequence[QueryArrays]) -> np.ndarray:
        """(Q, len(idx)) combined lower bounds for the bucket."""
        Q, N = len(qs), len(idx)
        if Q == 0 or N == 0:
            return np.zeros((Q, N), np.int32)
        if self.backend == "numpy":
            return self._bounds_np(idx, qs)
        if self.backend == "pallas":
            return self._bounds_pallas(idx, qs)
        return self._bounds_jax(idx, qs)

    def _gather(self, idx: np.ndarray, n_pad: int) -> DBArrays:
        a = self.arrays
        pad = n_pad - len(idx)

        def take(x, fill=0):
            sub = np.asarray(x)[idx]
            if pad:
                widths = [(0, pad)] + [(0, 0)] * (sub.ndim - 1)
                sub = np.pad(sub, widths, constant_values=fill)
            return sub

        # pad rows are sliced off after the pass; values don't matter as
        # long as the arithmetic stays in int32 range
        return DBArrays(nv=take(a.nv), ne=take(a.ne),
                        degseq=take(a.degseq), vhist=take(a.vhist),
                        ehist=take(a.ehist), fd=take(a.fd),
                        region_i=take(a.region_i, _IMPOSSIBLE),
                        region_j=take(a.region_j, _IMPOSSIBLE))

    def _bounds_jax(self, idx: np.ndarray,
                    qs: Sequence[QueryArrays]) -> np.ndarray:
        import jax.numpy as jnp

        Q, N = len(qs), len(idx)
        qp = _pad_to(Q, _Q_PAD)
        np_ = _pad_to(N, _N_PAD)
        db = self._gather(idx, np_)
        qs = list(qs) + [qs[-1]] * (qp - Q)          # pad with a repeat
        qb = self.stack_queries(qs)
        out = _bounds_multi_jit()(
            DBArrays(*[jnp.asarray(x) for x in db]),
            QueryArrays(*[jnp.asarray(x) for x in qb]))
        return np.asarray(out)[:Q, :N]

    def _bounds_np(self, idx: np.ndarray,
                   qs: Sequence[QueryArrays]) -> np.ndarray:
        db = self._gather(idx, len(idx))
        out = np.empty((len(qs), len(idx)), np.int64)
        for i, q in enumerate(qs):
            c_d = np.minimum(db.fd, np.asarray(q.fd)[None, :]).sum(axis=1)
            b = filters.batched_bounds_np(
                db.nv, db.ne, db.degseq, db.vhist, db.ehist, c_d,
                int(q.nv), int(q.ne), np.asarray(q.sigma),
                np.asarray(q.vhist), np.asarray(q.ehist))
            out[i] = b["combined"]
        return out

    def _bounds_pallas(self, idx: np.ndarray,
                       qs: Sequence[QueryArrays]) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels.qgram_filter.ops import (fused_filter_bounds,
                                                    make_aux, make_scalars)
        db = self._gather(idx, len(idx))
        aux = make_aux(jnp.asarray(db.nv), jnp.asarray(db.ne),
                       jnp.asarray(db.region_i), jnp.asarray(db.region_j))
        p = self.partition
        out = np.empty((len(qs), len(idx)), np.int64)
        for i, q in enumerate(qs):
            sc = make_scalars(int(q.nv), int(q.ne), int(q.tau), p.x0, p.y0,
                              p.l)
            b, _ = fused_filter_bounds(
                sc, jnp.asarray(db.fd), jnp.asarray(q.fd),
                jnp.asarray(db.vhist), jnp.asarray(q.vhist),
                jnp.asarray(db.ehist), jnp.asarray(q.ehist),
                jnp.asarray(db.degseq), jnp.asarray(q.sigma), aux)
            out[i] = np.asarray(b)
        return out


def batched_flat_candidates(ev: BatchedFilterEval, graphs: Sequence[Graph],
                            taus: Sequence[int],
                            qtuples: Optional[Sequence[QueryTuple]] = None
                            ) -> CandidateBatch:
    """Stages 1+2 for a flat source: bucket, gather once, one padded pass
    per bucket, threshold per query."""
    Qn = len(graphs)
    ids: List[List[int]] = [[] for _ in range(Qn)]
    bnds: List[Optional[np.ndarray]] = [None] * Qn
    for rect, qis in bucket_queries(ev.partition, graphs, taus).items():
        idx = ev.graphs_in_rect(rect)
        if len(idx) == 0:
            for qi in qis:
                ids[qi] = []
                bnds[qi] = np.zeros(0, np.int64)
            continue
        qs = [ev.query_arrays(graphs[qi], int(taus[qi]),
                              None if qtuples is None else qtuples[qi])
              for qi in qis]
        bounds = ev.bounds(idx, qs)
        for row, qi in enumerate(qis):
            keep = bounds[row] <= int(taus[qi])
            # idx is ascending (flatnonzero), so the kept ids stay sorted
            ids[qi] = [int(g) for g in idx[keep]]
            bnds[qi] = np.asarray(bounds[row][keep])
    return CandidateBatch(ids=ids, bounds=bnds)
