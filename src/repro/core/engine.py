"""Batched multi-query candidate generation — the GraphQueryEngine core.

The per-query path (``MSQIndex.query`` / ``FlatMSQIndex.query``) walks the
index once per request: region reduction, then a Python sweep over the
region's graphs.  Serving batches of queries, that repeats all of the
region bookkeeping and — worse — re-touches every region graph once per
query.  This module amortises both (Nass / EmbAssi style):

Stage 1 — **bucket** (``bucket_queries``): group requests by their reduced
  query region rectangle (formula (1)).  Every query in a bucket prunes
  against the *identical* set of region graphs, so that set is gathered
  once per batch.

Stage 2 — **shard**: lay the bucket's ``FilterSlab`` out for the filter
  pass.  The slab's F_D carrier is one of three layouts (DESIGN.md §11):
  ``dense`` (full-vocab matrix), ``hot`` (hot prefix + batched CSR tail
  correction added to C_D before thresholding), or ``packed`` (hybrid
  bit-packed rows decoded on device inside the pass).  Single-host
  backends gather the slab into one padded (Q, N) block; the
  ``distributed`` backend block-partitions the slab (hot prefixes /
  packed words instead of dense F_D) over the mesh's batch axes and
  replicates the padded query block to every device (graph-sharded),
  optionally also splitting the dense/hot F_D over the ``'model'`` axis
  (vocab-sharded) — see DESIGN.md §10.

Stage 3 — **filter** (``BatchedFilterEval``): evaluate the full leaf-level
  filter cascade for the whole bucket.  Backends: ``jax`` (jit + vmap over
  ``filters_jax.batched_bounds``), ``numpy`` (vectorised per-query rows,
  no device round-trip), ``pallas`` (the fused q-gram filter kernel per
  query; interpret mode off-TPU), and ``distributed`` (the cascade inside
  shard_map per device, all-gathering fixed-size top-k candidate blocks;
  overflowing blocks fall back to exact per-device ids so truncation is
  recall-safe).

Stage 4 — **worklist** (shared verification) lives in
``repro.serve.graph_engine``; the ``CandidateSource`` protocol below is
what lets that engine run tree-backed (``MSQIndex``) or flat
(``FlatMSQIndex``) without caring which.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.obs import current_obs, device_annotation
from repro.obs.health import FAILING, StageHealth

from repro.core import arrays, filters
from repro.core.arrays import DBArrays, QueryArrays
from repro.core.device_cache import DeviceSlabCache, bucket_key
from repro.core.qgrams import EncodedDB, QGramVocab
from repro.core.region import RegionPartition
from repro.core.slab import FilterSlab
from repro.core.tree import QueryTuple
from repro.graphs.graph import Graph, GraphDB

Rect = Tuple[int, int, int, int]          # inclusive (i1, i2, j1, j2)

# shape buckets for the jit'd (Q, N) pass: pad to these multiples so the
# number of distinct compiled programs stays small across buckets
_Q_PAD = 8
_N_PAD = 512
# per-device candidate-block size of the distributed backend
_K_DEFAULT = 256

# the recall-safe degradation ladders (DESIGN.md §18).  Every backend
# computes bit-identical bounds and every slab layout decodes to the
# same F_D, so stepping down a rung changes cost, never candidates.
_BACKEND_LADDER = {
    "pallas": ("pallas", "jax", "numpy"),
    "jax": ("jax", "numpy"),
    "numpy": ("numpy",),
    "distributed": ("distributed", "numpy"),
}
_SLAB_LADDER = {"packed": "hot", "hot": "dense"}


@runtime_checkable
class CandidateSource(Protocol):
    """What the serving engine needs from an index (tree or flat)."""

    db: GraphDB
    vocab: QGramVocab
    partition: RegionPartition

    def candidate_ids(self, h: Graph, tau: int) -> List[int]:
        """Sorted candidate graph ids for one query."""
        ...

    def batched_candidates(self, graphs: Sequence[Graph],
                           taus: Sequence[int],
                           qtuples: Optional[Sequence[QueryTuple]] = None
                           ) -> "CandidateBatch":
        """Candidates for a whole batch; per-query order preserved."""
        ...


@dataclass
class CandidateBatch:
    """Per-query candidate ids plus (when the source computes them) the
    filter lower bounds, used to order the shared verification worklist.

    ``lbs`` carries the stage-1.5 assignment lower bounds (DESIGN.md
    §16), aligned with ``ids`` like ``bounds``.  The LB never drops a
    candidate — ``ids`` stays bit-identical with the stage off — it only
    tightens what verification sees: the serving engine prunes pairs
    whose LB exceeds the working radius from the worklist and seeds the
    survivors' A* with ``max(bound, lb)``.
    """

    ids: List[List[int]]
    bounds: List[Optional[np.ndarray]]     # aligned with ids; None for trees
    lbs: Optional[List[Optional[np.ndarray]]] = None
    # per-query share of the assignment-LB wall time (seconds), for the
    # serving engine's stage breakdown (DESIGN.md §17); None when the
    # stage is off
    lb_s: Optional[List[float]] = None


def bucket_queries(partition: RegionPartition, graphs: Sequence[Graph],
                   taus: Sequence[int]) -> Dict[Rect, List[int]]:
    """Stage 1: query indices grouped by reduced-query-region rectangle."""
    buckets: Dict[Rect, List[int]] = {}
    for qi, (h, tau) in enumerate(zip(graphs, taus)):
        rect = partition.query_region(h.n, h.m, int(tau))
        buckets.setdefault(rect, []).append(qi)
    return buckets


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def resolve_backend() -> str:
    """Best default for the host: the jit/vmap pass on an accelerator,
    plain vectorised numpy on CPU (no compile latency, same candidates)."""
    from repro.kernels.qgram_filter.ops import on_tpu
    return "jax" if on_tpu() else "numpy"


@functools.lru_cache(maxsize=None)
def _bounds_multi_jit(layout: str = "dense"):
    """jit'd (Q, N) filter pass per slab layout: vmap of the single-query
    cascade, with the layout's C_D construction fused in (DESIGN.md §11).

    C_D is evaluated *query-sparse* (DESIGN.md §13): a query graph touches
    a few dozen degree-q-gram ids, and ``min(F_D[:, j], 0) = 0`` for every
    column the query misses, so the min-sum gathers only the query's
    nonzero columns (``qids``/``qcnt``, zero-padded — pad slots contribute
    ``min(fd, 0) = 0``).  Bit-identical to the dense sweep, ~U/K times
    less work on the serving-dominant wide-vocabulary slabs.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import filters_jax as fj

    def sparse_cd(fd, ids, cnt):
        return jnp.minimum(fd[:, ids], cnt[None, :]).astype(
            jnp.int32).sum(axis=1)

    if layout == "dense":
        def multi(db: DBArrays, qb: QueryArrays, qids, qcnt) -> "jax.Array":
            def one(q, ids, cnt):
                return fj.batched_bounds(db, q, c_d=sparse_cd(db.fd, ids,
                                                              cnt))
            return jax.vmap(one)(qb, qids, qcnt)
    elif layout == "hot":
        # db.fd is the (N, H) hot prefix, qids/qcnt the query's nonzero
        # entries within it, and cdt the host-computed (Q, N) CSR tail
        # correction — added to C_D before thresholding so the bound
        # stays admissible (DESIGN.md §3)
        def multi(db: DBArrays, qb: QueryArrays, cdt, qids,
                  qcnt) -> "jax.Array":
            def one(q, t, ids, cnt):
                return fj.batched_bounds(db, q,
                                         c_d=sparse_cd(db.fd, ids, cnt) + t)
            return jax.vmap(one)(qb, cdt, qids, qcnt)
    elif layout == "packed":
        # the resident slab is the packed form; decode on device, then the
        # usual cascade.  db.fd is a (N, 1) placeholder — C_D is supplied.
        def multi(words, sb, widths, db: DBArrays, qb: QueryArrays,
                  qids, qcnt) -> "jax.Array":
            from repro.kernels.bitunpack.ref import unpack_rows_ref
            fd = unpack_rows_ref(words, sb, widths)

            def one(q, ids, cnt):
                return fj.batched_bounds(db, q, c_d=sparse_cd(fd, ids, cnt))
            return jax.vmap(one)(qb, qids, qcnt)
    else:
        raise ValueError(f"unknown slab layout {layout!r}")

    return jax.jit(multi)


@functools.lru_cache(maxsize=1)
def _assign_lb_jit():
    """jit'd (Q, N) assignment-LB pass (the jax backend's stage 1.5) —
    the reference body under jit, on shape-bucketed operands."""
    import jax

    from repro.kernels.assign_lb.ref import batched_assign_lb_ref
    return jax.jit(batched_assign_lb_ref)


def sparse_query_fd(qfd: np.ndarray, pad: int = 16
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(Q, K) nonzero ids + counts of a stacked query F_D block, K rounded
    up a power-of-two ladder from ``pad`` (a raw max would retrace the jit
    pass for every distinct batch-max nonzero count — the same
    per-batch-shape churn the kernel's shape buckets kill).  Pad slots are
    id 0 with count 0 — a no-op for the min-sum."""
    qfd = np.asarray(qfd)
    nz = qfd > 0
    kmax = max(int(nz.sum(axis=1).max(initial=0)), 1)
    K = pad
    while K < kmax:
        K *= 2
    ids = np.zeros((qfd.shape[0], K), np.int32)
    cnt = np.zeros((qfd.shape[0], K), np.int32)
    for r in range(qfd.shape[0]):
        j = np.flatnonzero(nz[r])
        ids[r, :len(j)] = j
        cnt[r, :len(j)] = qfd[r, j]
    return ids, cnt


class BatchedFilterEval:
    """Stages 2+3: slab layout plus the leaf-level filter pass per bucket.

    Holds the database-side ``FilterSlab`` (built once in the configured
    layout, reused across batches) and evaluates the combined admissible
    bound for every (query, graph) pair of a bucket.  Inputs are
    bit-identical to what ``FlatMSQIndex`` feeds
    ``filters.batched_bounds_np``, so candidate sets match exactly across
    every ``slab`` layout ('dense' | 'hot' | 'packed', DESIGN.md §11) and
    every backend.

    The ``distributed`` backend additionally needs a ``mesh``; it shards
    each bucket slab over the mesh (``layout``: 'graph' | 'vocab', see
    DESIGN.md §10) and drains fixed-size per-device top-k candidate blocks
    of size ``k`` instead of materialising the full (Q, N) bounds matrix.
    The vocab-sharded layout splits the dense or hot F_D over ``'model'``;
    the packed slab shards its words rows like any graph-sharded array.
    """

    def __init__(self, db: GraphDB, enc: EncodedDB,
                 partition: RegionPartition, backend: str = "auto", *,
                 mesh=None, layout: str = "graph", k: int = _K_DEFAULT,
                 shard_pad: int = _N_PAD, slab: str = "dense",
                 hot_d: Optional[int] = None,
                 hot_mass: Optional[float] = None,
                 tile_table=None, device_cache_entries: int = 16,
                 assign_lb: bool = True, lb_hungarian: int = 0,
                 lb_tile_table=None, faults=None):
        if backend == "auto":
            backend = resolve_backend()
        if backend not in ("jax", "numpy", "pallas", "distributed"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "distributed" and mesh is None:
            raise ValueError("backend='distributed' needs a mesh")
        self.backend = backend
        self.db = db
        self.enc = enc
        self.vocab = enc.vocab
        self.partition = partition
        self.slab = FilterSlab.build(db, enc, partition, layout=slab,
                                     hot_d=hot_d, hot_mass=hot_mass)
        self.slab_layout = self.slab.layout
        self.vmax = self.slab.vmax
        # per-bucket gathered sub-slabs + their device-resident operands,
        # shared by every backend path (DESIGN.md §13)
        self.device_cache = DeviceSlabCache(device_cache_entries)
        self._tile_table = tile_table
        # stage 1.5: batched assignment lower bounds (DESIGN.md §16)
        self.assign_lb = bool(assign_lb)
        self.lb_hungarian = int(lb_hungarian)
        self._lb_tile_table = lb_tile_table
        self._lb_dist_fn = None
        # fault injection (duck-typed: anything with .fire(point, **ctx);
        # serve.faults.FaultInjector in practice) + the per-stage health
        # machines driving the degradation ladder (DESIGN.md §18)
        self.faults = None
        self.backend_health = StageHealth("filter_backend")
        self.slab_health = StageHealth("slab_decode", fail_threshold=2)
        self._health_reg = None
        self._ladder_lock = threading.Lock()
        self.ladder_stats: Dict[str, int] = {
            "backend_fallbacks": 0, "slab_fallbacks": 0, "primary_skips": 0}
        self.set_faults(faults)
        if backend == "distributed":
            self._init_distributed(mesh, layout, k, shard_pad)

    def set_faults(self, faults) -> None:
        """(Re)attach a fault injector; threads into the device cache so
        upload builds fire ``device.cache`` too.  ``None`` disarms."""
        self.faults = faults
        self.device_cache.set_faults(faults)

    # ---- slab lifecycle ----------------------------------------------------
    def rebuild_slab(self, *, layout: Optional[str] = None,
                     hot_d: Optional[int] = None,
                     hot_mass: Optional[float] = None) -> None:
        """Rebuild the resident FilterSlab (layout / hot-width change) and
        invalidate every cached device copy of the old one — a stale
        upload must never serve another batch (DESIGN.md §13)."""
        self.slab = FilterSlab.build(
            self.db, self.enc, self.partition,
            layout=self.slab_layout if layout is None else layout,
            hot_d=hot_d, hot_mass=hot_mass)
        self.slab_layout = self.slab.layout
        self.vmax = self.slab.vmax
        self.device_cache.invalidate()

    # ---- pallas tile selection (autotuned, DESIGN.md §13) ------------------
    @property
    def tile_table(self):
        """(qb, bb, bu) per shape bucket; the persisted autotune table
        with the built-in defaults as fallback (lazy — numpy/jax paths
        never pay the load)."""
        if self._tile_table is None:
            from repro.kernels.qgram_filter import autotune
            self._tile_table = autotune.default_table()
        return self._tile_table

    def autotune_tiles(self, qs=(8, 64), save_path=None, **kw):
        """Sweep kernel tiles on this slab's real bucket shapes and adopt
        the result (``kernels.qgram_filter.autotune``)."""
        from repro.kernels.qgram_filter import autotune
        self._tile_table = autotune.autotune_slab(
            self.slab, qs=qs, save_path=save_path, **kw)
        return self._tile_table

    def _gather_cached(self, idx: np.ndarray, n_pad: int):
        """(cache key, gathered sub-slab) for one bucket; the host gather
        is cached across batches alongside the device operands."""
        key = bucket_key(idx, n_pad)
        return key, self.device_cache.get_or_build(
            key, "sub", lambda: self.slab.gather(idx, n_pad))

    # ---- stage 1.5: batched assignment lower bounds (DESIGN.md §16) -------
    @property
    def lb_tile_table(self):
        """(qb, bb) per shape bucket for the assign_lb kernel (lazy, like
        ``tile_table``)."""
        if self._lb_tile_table is None:
            from repro.kernels.assign_lb import autotune
            self._lb_tile_table = autotune.default_table()
        return self._lb_tile_table

    def bucket_assign_lbs(self, hs: Sequence[Graph],
                          cand_ids: Sequence[List[int]]
                          ) -> List[np.ndarray]:
        """Per-query assignment LBs aligned with each query's candidate
        list, computed in one batched pass over the bucket's *union* of
        surviving ids (post-filter survivors are a small fraction of the
        bucket, and coalescing the union keeps it one device launch)."""
        union = sorted(set().union(*(set(c) for c in cand_ids)))
        if not union:
            return [np.zeros(0, np.int64) for _ in cand_ids]
        uidx = np.asarray(union, np.int64)
        from repro.core.slab import branch_features
        vmq = max((h.n for h in hs), default=1)
        qv, qd, qeh = branch_features(hs, self.db.n_elabels, max(vmq, 1))
        qn = np.asarray([h.n for h in hs], np.int32)
        lbm = self._assign_lb_matrix(uidx, qv, qd, qeh, qn)
        pos = {g: i for i, g in enumerate(union)}
        out = []
        for r, ids in enumerate(cand_ids):
            out.append(np.asarray(
                lbm[r, [pos[g] for g in ids]], np.int64))
        if self.lb_hungarian > 0:
            self._hungarian_refine(hs, cand_ids, out)
        return out

    def _hungarian_refine(self, hs, cand_ids, lbs) -> None:
        """Tighten the ``lb_hungarian`` highest-LB survivors per query
        with the exact assignment relaxation (still a provable bound, so
        still recall-safe) — the pairs closest to the radius are the ones
        an exact assignment is most likely to push over it."""
        from repro.kernels.assign_lb.ops import hungarian_lb_pair
        slab = self.slab
        for r, (h, ids) in enumerate(zip(hs, cand_ids)):
            if not len(ids):
                continue
            from repro.core.slab import branch_features
            hv, hd, heh = branch_features([h], self.db.n_elabels,
                                          max(h.n, 1))
            top = np.argsort(lbs[r], kind="stable")[-self.lb_hungarian:]
            for t in top:
                g = int(ids[int(t)])
                n = int(slab.nv[g])
                hung = hungarian_lb_pair(
                    hv[0][:h.n], hd[0][:h.n], heh[0][:h.n],
                    slab.bvlab[g][:n], slab.bdeg[g][:n], slab.behist[g][:n])
                if hung is not None:
                    lbs[r][int(t)] = max(int(lbs[r][int(t)]), hung)

    def _assign_lb_matrix(self, uidx: np.ndarray, qv, qd, qeh, qn
                          ) -> np.ndarray:
        """(Q, |union|) LB matrix on the configured backend.  All
        backends compute the same integers (the bound is provable and the
        paths share one padding contract), so downstream verification
        decisions are bit-identical across backend x layout x mesh."""
        from repro.kernels.assign_lb import ops as aops
        Q, N = len(qn), len(uidx)
        if self.backend == "numpy":
            _, sub = self._gather_cached(uidx, N)
            return aops.assign_lb_np(qv, qd, qeh, qn, sub.bvlab, sub.bdeg,
                                     sub.behist, sub.nv)
        import jax.numpy as jnp
        np_ = aops.shape_bucket(max(N, 1), aops.N_BASE, aops.N_CAP)
        if self.backend == "distributed":
            np_ = _pad_to(np_, self.n_shards)
        key, sub = self._gather_cached(uidx, np_)
        dev = self.device_cache.get_or_build(
            key, "lb_db",
            lambda: tuple(jnp.asarray(x) for x in
                          (sub.bvlab, sub.bdeg, sub.behist, sub.nv)))
        qvp, qdp, qehp, qnp = aops.pad_query_block(qv, qd, qeh, qn)
        qargs = tuple(jnp.asarray(x) for x in (qvp, qdp, qehp, qnp))
        if self.backend == "pallas":
            qb_t, bb_t = self.lb_tile_table.lookup(
                qvp.shape[0], np_, qvp.shape[1], sub.bvlab.shape[1])
            out = aops.assign_lb_bounds_batched(*qargs, *dev,
                                                qb=qb_t, bb=bb_t)
        elif self.backend == "distributed":
            from repro.core import jax_compat as jc
            if self._lb_dist_fn is None:
                from repro.core import distributed as dist
                self._lb_dist_fn = dist.make_sharded_assign_lb(
                    self.mesh, self._batch_axes)
            with jc.set_mesh(self.mesh):
                out = self._lb_dist_fn(*qargs, *dev)
        else:
            out = _assign_lb_jit()(*qargs, *dev)
        return np.asarray(out)[:Q, :N]

    # ---- distributed slab-shard bookkeeping -------------------------------
    def _init_distributed(self, mesh, layout: str, k: int,
                          shard_pad: int) -> None:
        from repro.core import distributed as dist
        self.mesh = mesh
        self.layout = layout
        self.k = int(k)
        self.shard_pad = int(shard_pad)
        batch_axes, model_axis = dist.layout_axes(mesh, layout)
        if model_axis is not None and self.slab_layout == "packed":
            raise ValueError(
                "the packed slab cannot split its vocabulary over 'model'; "
                "use the hot or dense slab with the vocab-sharded layout")
        self._batch_axes = batch_axes
        self._model_axis = model_axis
        self.n_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))
        self._model_size = (1 if model_axis is None
                            else int(mesh.shape[model_axis]))
        self._dist_fn, _, _ = dist.make_sharded_multi_search(
            mesh, self.partition.x0, self.partition.y0, self.partition.l,
            self.k, batch_axes=batch_axes, model_axis=model_axis,
            slab=self.slab_layout, n_entries=self.slab.U)
        self.dist_stats: Dict[str, int] = {"blocks": 0, "overflow_blocks": 0}

    # ---- query-side arrays ------------------------------------------------
    def query_arrays(self, h: Graph, tau: int,
                     qt: Optional[QueryTuple] = None) -> QueryArrays:
        return arrays.query_arrays_from_graph(h, self.vocab, self.partition,
                                              tau, self.vmax, qt=qt)

    def stack_queries(self, qs: Sequence[QueryArrays]) -> QueryArrays:
        """(Q, ...) stacked query arrays (leading axis = query)."""
        return QueryArrays(*[np.stack([np.asarray(getattr(q, f))
                                       for q in qs])
                             for f in QueryArrays._fields])

    def graphs_in_rect(self, rect: Rect) -> np.ndarray:
        return self.slab.in_rect(rect)

    # ---- the (Q, N) pass --------------------------------------------------
    def bounds(self, idx: np.ndarray,
               qs: Sequence[QueryArrays]) -> np.ndarray:
        """(Q, len(idx)) combined lower bounds for the bucket."""
        Q, N = len(qs), len(idx)
        if Q == 0 or N == 0:
            return np.zeros((Q, N), np.int32)
        if self.backend == "distributed":
            raise ValueError("the distributed backend emits candidate "
                             "blocks, not dense bounds; use "
                             "bucket_candidates()")
        return self._bounds_ladder(idx, qs)

    def _bounds_backend(self, backend: str, idx: np.ndarray,
                        qs: Sequence[QueryArrays]) -> np.ndarray:
        if backend == "numpy":
            return self._bounds_np(idx, qs)
        if backend == "pallas":
            return self._bounds_pallas(idx, qs)
        return self._bounds_jax(idx, qs)

    # ---- the degradation ladder (DESIGN.md §18) ---------------------------
    def _attach_health(self) -> None:
        """Bind the health gauges to the ambient registry: the serving
        engines wrap every filter pass in ``use_obs``, so ladder state
        lands in the same snapshot as the serving stats."""
        obs = current_obs()
        reg = None if obs is None else obs.metrics
        if reg is not self._health_reg:
            self._health_reg = reg
            self.backend_health.attach(reg)
            self.slab_health.attach(reg)

    def _note_degrade(self, counter: str, **fields) -> None:
        with self._ladder_lock:
            self.ladder_stats[counter] += 1
        obs = current_obs()
        if obs is not None:
            obs.metrics.counter_add(f"filter.{counter}")
            if obs.spans.enabled:
                now = time.perf_counter()
                obs.spans.record("degrade", now, now, kind=counter,
                                 **fields)

    def _fire_device_faults(self, backend: str) -> None:
        if self.faults is not None and backend != "numpy":
            self.faults.fire("device.filter", backend=backend)
            if self.slab_layout in _SLAB_LADDER:
                self.faults.fire("device.decode", layout=self.slab_layout)

    def _record_ladder_failure(self, backend: str, err: BaseException,
                               primary: bool) -> None:
        """Account one rung failure; step the slab ladder when repeated
        failures attribute to the packed/hot decode path."""
        if getattr(err, "slab_decode", False):
            self.slab_health.record_failure()
            nxt = _SLAB_LADDER.get(self.slab_layout)
            if self.slab_health.state == FAILING and nxt is not None:
                # packed -> hot -> dense: rebuild the resident slab one
                # rung denser (identical F_D content, no decode step) and
                # drop the stale device uploads with it
                self.rebuild_slab(layout=nxt)
                self.slab_health.record_success()
                self._note_degrade("slab_fallbacks", to_layout=nxt)
        elif primary:
            self.backend_health.record_failure()
        self._note_degrade("backend_fallbacks", backend=backend)

    def _bounds_ladder(self, idx: np.ndarray,
                       qs: Sequence[QueryArrays]) -> np.ndarray:
        """Walk pallas→jax→numpy (or the backend's suffix) until a rung
        succeeds.  Candidates are bit-identical on every rung, so the
        ladder trades latency for availability, never recall.  A FAILING
        primary is sticky-skipped until its next probe; numpy is the
        floor and its failure propagates (nothing recall-safe is left)."""
        ladder = _BACKEND_LADDER[self.backend]
        if len(ladder) == 1:        # numpy primary: no ladder, no faults
            return self._bounds_np(idx, qs)
        self._attach_health()
        last_err: Optional[BaseException] = None
        for rung, be in enumerate(ladder):
            primary = rung == 0
            if primary and not self.backend_health.allow_primary():
                self._note_degrade("primary_skips", backend=be)
                continue
            try:
                self._fire_device_faults(be)
                out = self._bounds_backend(be, idx, qs)
            except Exception as e:      # noqa: BLE001 — ladder containment
                last_err = e
                self._record_ladder_failure(be, e, primary)
                continue
            if primary:
                self.backend_health.record_success()
            return out
        raise last_err  # type: ignore[misc]

    def bucket_candidates(self, idx: np.ndarray, qs: Sequence[QueryArrays],
                          taus: Sequence[int]
                          ) -> List[Tuple[List[int], np.ndarray]]:
        """Per-query (sorted candidate ids, aligned bounds) for one bucket.

        Single-host backends threshold the dense (Q, N) bounds; the
        distributed backend drains the all-gathered candidate blocks.
        Both sit on the degradation ladder: device failures fall back to
        the exact numpy pass (bit-identical candidates, DESIGN.md §18).
        """
        if self.backend == "distributed":
            self._attach_health()
            if self.backend_health.allow_primary():
                try:
                    self._fire_device_faults("distributed")
                    out = self._bucket_candidates_dist(idx, qs, taus)
                    self.backend_health.record_success()
                    return out
                except Exception as e:  # noqa: BLE001 — ladder containment
                    self._record_ladder_failure("distributed", e, True)
            else:
                self._note_degrade("primary_skips", backend="distributed")
            bounds = self._bounds_np(idx, qs)
        else:
            bounds = self.bounds(idx, qs)
        out: List[Tuple[List[int], np.ndarray]] = []
        for row in range(len(qs)):
            keep = bounds[row] <= int(taus[row])
            # idx is ascending (flatnonzero), so the kept ids stay sorted
            out.append(([int(g) for g in idx[keep]],
                        np.asarray(bounds[row][keep])))
        return out

    def _bounds_jax(self, idx: np.ndarray,
                    qs: Sequence[QueryArrays]) -> np.ndarray:
        import jax.numpy as jnp

        Q, N = len(qs), len(idx)
        qp = _pad_to(Q, _Q_PAD)
        np_ = _pad_to(N, _N_PAD)
        key, sub = self._gather_cached(idx, np_)
        db = self.device_cache.get_or_build(
            key, "jax_db",
            lambda: DBArrays(*[jnp.asarray(x) for x in sub.base_arrays()]))
        qs = list(qs) + [qs[-1]] * (qp - Q)          # pad with a repeat
        qb = self.stack_queries(qs)
        lay = self.slab_layout
        if lay == "hot":
            cdt = sub.tail_minsum_batch(qb.fd).astype(np.int32)
            qb = qb._replace(fd=qb.fd[:, :sub.hot_d])
            qids, qcnt = sparse_query_fd(qb.fd)
            out = _bounds_multi_jit("hot")(
                db, QueryArrays(*[jnp.asarray(x) for x in qb]),
                jnp.asarray(cdt), jnp.asarray(qids), jnp.asarray(qcnt))
        elif lay == "packed":
            words, sb, widths = self.device_cache.get_or_build(
                key, "jax_packed",
                lambda: tuple(jnp.asarray(x) for x in
                              (sub.packed.words, sub.packed.sb,
                               sub.packed.widths)))
            qids, qcnt = sparse_query_fd(qb.fd)
            out = _bounds_multi_jit("packed")(
                words, sb, widths, db,
                QueryArrays(*[jnp.asarray(x) for x in qb]),
                jnp.asarray(qids), jnp.asarray(qcnt))
        else:
            qids, qcnt = sparse_query_fd(qb.fd)
            out = _bounds_multi_jit("dense")(
                db, QueryArrays(*[jnp.asarray(x) for x in qb]),
                jnp.asarray(qids), jnp.asarray(qcnt))
        return np.asarray(out)[:Q, :N]

    def _bounds_np(self, idx: np.ndarray,
                   qs: Sequence[QueryArrays]) -> np.ndarray:
        _, sub = self._gather_cached(idx, len(idx))
        db = sub.base_arrays()
        out = np.empty((len(qs), len(idx)), np.int64)
        for i, q in enumerate(qs):
            c_d = sub.cd_one(np.asarray(q.fd))
            b = filters.batched_bounds_np(
                db.nv, db.ne, db.degseq, db.vhist, db.ehist, c_d,
                int(q.nv), int(q.ne), np.asarray(q.sigma),
                np.asarray(q.vhist), np.asarray(q.ehist))
            out[i] = b["combined"]
        return out

    def _bounds_pallas(self, idx: np.ndarray,
                       qs: Sequence[QueryArrays]) -> np.ndarray:
        """One query-batched kernel launch per bucket (DESIGN.md §13): the
        padded query block rides a leading Q axis, every db-side operand
        comes from the device-resident cache, and the (qb, bb, bu) tiles
        come from the autotune table."""
        import jax.numpy as jnp

        from repro.kernels.qgram_filter import ops

        lay = self.slab_layout
        Q, N = len(qs), len(idx)
        np_ = ops.shape_bucket(max(N, 1), ops.B_BASE, ops.B_CAP)
        key, sub = self._gather_cached(idx, np_)
        if lay == "packed":
            # the cached device residency is the succinct packed form;
            # the dense F_D exists only transiently, decoded per launch
            from repro.kernels.bitunpack.ops import (flatten_packed_rows,
                                                     unpack_hybrid)

            def _upload_packed():
                words, sb, widths = flatten_packed_rows(sub.packed)
                return (jnp.asarray(words), jnp.asarray(sb),
                        jnp.asarray(widths))
            words, sb, widths = self.device_cache.get_or_build(
                key, "pallas_packed", _upload_packed)
            KB = sub.packed.sb.shape[1]
            fd_dev = unpack_hybrid(sb, widths, words).reshape(np_, KB * 128)
        else:
            fd_dev = self.device_cache.get_or_build(
                key, "pallas_fd", lambda: jnp.asarray(sub.fd))

        def _upload_small():
            aux = np.stack([sub.nv, sub.ne, sub.region_i, sub.region_j],
                           axis=1).astype(np.int32)
            return (jnp.asarray(sub.vhist), jnp.asarray(sub.ehist),
                    jnp.asarray(sub.degseq), jnp.asarray(aux))
        vhist_d, ehist_d, degseq_d, aux_d = self.device_cache.get_or_build(
            key, "pallas_small", _upload_small)

        qb = self.stack_queries(qs)
        cdt = None
        if lay == "hot":
            # sparse-tail C_D correction seeds the kernel's C_D scratch
            # (DESIGN.md §3) — per (query, graph), so it is the one
            # db-side operand rebuilt per batch
            cdt = jnp.asarray(sub.tail_minsum_batch(qb.fd).astype(np.int32))
            qb = qb._replace(fd=qb.fd[:, :sub.hot_d])
        p = self.partition
        sc = ops.make_scalars_batch(qs, p.x0, p.y0, p.l)
        qb_t, bb_t, bu_t = self.tile_table.lookup(Q, np_, fd_dev.shape[1])
        with device_annotation("msq.qgram_filter.pallas"):
            b, _ = ops.fused_filter_bounds_batched(
                jnp.asarray(sc), fd_dev, jnp.asarray(qb.fd),
                vhist_d, jnp.asarray(qb.vhist), ehist_d, jnp.asarray(qb.ehist),
                degseq_d, jnp.asarray(qb.sigma), aux_d, cdt,
                qb=qb_t, bb=bb_t, bu=bu_t)
        return np.asarray(b)[:Q, :N]

    # ---- the distributed per-bucket step ----------------------------------
    def _bucket_candidates_dist(self, idx: np.ndarray,
                                qs: Sequence[QueryArrays],
                                taus: Sequence[int]
                                ) -> List[Tuple[List[int], np.ndarray]]:
        """Shard the bucket slab, run the cascade per device, drain the
        all-gathered candidate blocks (DESIGN.md §10).

        Recall safety: a device block holds at most k ids.  ``n_pass`` is
        the true per-shard pass count, so ``n_pass > k`` (a truncated
        block) triggers an exact host-side re-evaluation of that shard's
        slab rows for that query — candidates are never silently dropped.
        """
        import jax
        import jax.numpy as jnp

        from repro.core import jax_compat as jc

        S = self.n_shards
        Q = len(qs)
        n_pad = _pad_to(max(len(idx), 1), S * self.shard_pad)
        key, sub = self._gather_cached(idx, n_pad)
        qp = _pad_to(Q, _Q_PAD)
        qb = self.stack_queries(list(qs) + [qs[-1]] * (qp - Q))
        extra: Tuple = ()
        if self.slab_layout == "hot":
            # batched CSR tail correction, sharded with the slab rows —
            # per (query, graph), so rebuilt per batch (never cached)
            cdt = sub.tail_minsum_batch(qb.fd).astype(np.int32)
            qb = qb._replace(fd=qb.fd[:, :sub.hot_d])
            extra = (jnp.asarray(cdt),)
        elif self.slab_layout == "packed":
            extra = self.device_cache.get_or_build(
                key, "dist_packed",
                lambda: tuple(jnp.asarray(x) for x in
                              (sub.packed.words, sub.packed.sb,
                               sub.packed.widths)))
        # vocab dim must divide 'model' on the vocab-sharded layout
        upad = (0 if self._model_axis is None
                else (-sub.fd.shape[1]) % self._model_size)

        def _upload_db():
            db = sub.base_arrays()
            if upad:
                db = db._replace(fd=np.pad(db.fd, [(0, 0), (0, upad)]))
            return DBArrays(*[jnp.asarray(x) for x in db])
        db_dev = self.device_cache.get_or_build(key, "dist_db", _upload_db)
        if upad:
            qb = qb._replace(fd=np.pad(qb.fd, [(0, 0), (0, upad)]))
        with jc.set_mesh(self.mesh):
            sids, bnds, n_pass = self._dist_fn(
                db_dev, QueryArrays(*[jnp.asarray(x) for x in qb]),
                *extra)
        sids = np.asarray(sids)
        bnds = np.asarray(bnds)
        n_pass = np.asarray(n_pass)
        shard_b = n_pad // S

        # overflow fallback, batched per shard: one exact numpy pass over a
        # shard's slab rows covers every query whose block truncated there
        self.dist_stats["blocks"] += S * Q
        fallback: Dict[int, Dict[int, np.ndarray]] = {}
        for s in range(S):
            rows = [r for r in range(Q) if int(n_pass[s, r]) > self.k]
            if not rows:
                continue
            self.dist_stats["overflow_blocks"] += len(rows)
            lo, hi = s * shard_b, min((s + 1) * shard_b, len(idx))
            b = self._bounds_np(idx[lo:hi], [qs[r] for r in rows])
            fallback[s] = {r: np.asarray(b[i]) for i, r in enumerate(rows)}

        out: List[Tuple[List[int], np.ndarray]] = []
        for row in range(Q):
            tau = int(taus[row])
            pos_parts: List[np.ndarray] = []
            bnd_parts: List[np.ndarray] = []
            for s in range(S):
                fb = fallback.get(s, {}).get(row)
                if fb is not None:
                    lo = s * shard_b
                    keep = fb <= tau
                    pos_parts.append(np.arange(lo, lo + len(fb))[keep])
                    bnd_parts.append(fb[keep])
                else:
                    g = sids[s, row]
                    sel = g >= 0
                    pos_parts.append(g[sel].astype(np.int64))
                    bnd_parts.append(bnds[s, row][sel].astype(np.int64))
            pos = np.concatenate(pos_parts)
            bnd = np.concatenate(bnd_parts)
            # slab positions -> global ids: shards are disjoint contiguous
            # ranges of the ascending idx, so sorting by position restores
            # the single-host ascending-id order; pad rows never pass the
            # region mask, so every position indexes a real slab row
            order = np.argsort(pos, kind="stable")
            gids = idx[pos[order].astype(np.int64)]
            out.append(([int(g) for g in gids],
                        np.asarray(bnd[order], np.int64)))
        return out


def batched_flat_candidates(ev: BatchedFilterEval, graphs: Sequence[Graph],
                            taus: Sequence[int],
                            qtuples: Optional[Sequence[QueryTuple]] = None
                            ) -> CandidateBatch:
    """Stages 1-3 for a flat source: bucket, lay the slab out (gathered or
    sharded), one filter pass per bucket, per-query candidate lists, then
    (when ``ev.assign_lb``) the stage-1.5 assignment LB pass over each
    bucket's surviving candidates (DESIGN.md §16)."""
    obs = current_obs()
    spans_on = obs is not None and obs.spans.enabled
    Qn = len(graphs)
    ids: List[List[int]] = [[] for _ in range(Qn)]
    bnds: List[Optional[np.ndarray]] = [None] * Qn
    lbs: Optional[List[Optional[np.ndarray]]] = \
        [None] * Qn if ev.assign_lb else None
    lb_s: Optional[List[float]] = [0.0] * Qn if ev.assign_lb else None
    t_b = time.perf_counter() if spans_on else 0.0
    buckets = bucket_queries(ev.partition, graphs, taus)
    if spans_on:
        obs.spans.record("bucket", t_b, time.perf_counter(),
                         n_queries=Qn, n_buckets=len(buckets))
    for rect, qis in buckets.items():
        idx = ev.graphs_in_rect(rect)
        if len(idx) == 0:
            for qi in qis:
                ids[qi] = []
                bnds[qi] = np.zeros(0, np.int64)
                if lbs is not None:
                    lbs[qi] = np.zeros(0, np.int64)
            continue
        qs = [ev.query_arrays(graphs[qi], int(taus[qi]),
                              None if qtuples is None else qtuples[qi])
              for qi in qis]
        t_f = time.perf_counter() if spans_on else 0.0
        cands = ev.bucket_candidates(idx, qs, [int(taus[qi]) for qi in qis])
        if spans_on:
            obs.spans.record("filter_bucket", t_f, time.perf_counter(),
                             n_queries=len(qis), n_graphs=int(len(idx)),
                             backend=ev.backend)
        for row, qi in enumerate(qis):
            ids[qi], bnds[qi] = cands[row]
        if lbs is not None:
            t0 = time.perf_counter()
            blbs = ev.bucket_assign_lbs([graphs[qi] for qi in qis],
                                        [cands[row][0]
                                         for row in range(len(qis))])
            t1 = time.perf_counter()
            if spans_on:
                obs.spans.record("assign_lb", t0, t1, n_queries=len(qis),
                                 n_pairs=sum(len(c[0]) for c in cands))
            share = (t1 - t0) / len(qis)
            for row, qi in enumerate(qis):
                lbs[qi] = blbs[row]
                lb_s[qi] = share
    return CandidateBatch(ids=ids, bounds=bnds, lbs=lbs, lb_s=lb_s)
