"""Distributed MSQ-Index search: shard_map over the production mesh.

Layouts (DESIGN.md §5):

* **Graph-sharded** (default): the region-sorted DB slab is block-partitioned
  over the ``('pod', 'data')`` axes; query replicated; each device filters
  its shard locally and emits a fixed-size top-k candidate block; candidate
  blocks are all-gathered.  No cross-device traffic proportional to |G| —
  only k ids per device.
* **Vocab-sharded** (TP analogue): additionally the dense F_D matrix is
  sharded over the vocabulary dim on the ``'model'`` axis; the min-sum
  contraction computes partial C_D per device and psums over ``'model'``.
  This is what makes very wide q-gram vocabularies (PubChem-scale) fit.

Both paths are pure jnp + lax collectives inside shard_map, so they lower
and compile for any mesh (exercised by the multi-pod dry-run).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import filters_jax as fj
from repro.core import jax_compat as jc


def _device_bounds(db: fj.DBArrays, q: fj.QueryArrays, x0: int, y0: int,
                   l: int, model_axis: Optional[str]) -> Tuple[jax.Array, jax.Array]:
    """Per-shard filter cascade; psums partial C_D over the model axis."""
    if model_axis is not None:
        # fd is vocab-sharded: partial min-sum then psum.
        c_d_partial = fj.min_sum(db.fd, q.fd[None, :]).astype(jnp.int32)
        c_d = jax.lax.psum(c_d_partial, model_axis)
    else:
        c_d = None
    return fj.filter_pass(db, q, x0, y0, l, c_d=c_d)


def make_sharded_search(mesh: Mesh, x0: int, y0: int, l: int, k: int,
                        batch_axes: Sequence[str] = ("data",),
                        model_axis: Optional[str] = None):
    """Build a jitted distributed search step for the given mesh.

    Returns (fn, in_shardings, out_shardings).  ``fn(db, q)`` returns
    (global_ids, bounds, counts): per-device top-k candidate blocks
    all-gathered to a ((devices*k),) id vector (id -1 = empty slot), with
    ids already offset into global graph numbering.
    """
    batch_axes = tuple(batch_axes)
    spec_b = P(batch_axes)                     # (B,) sharded over batch axes
    spec_b2 = P(batch_axes, None)              # (B, X) row-sharded
    if model_axis is not None:
        spec_fd = P(batch_axes, model_axis)    # (B, U) row+vocab sharded
        spec_qfd = P(model_axis)
    else:
        spec_fd = spec_b2
        spec_qfd = P(None)

    db_spec = fj.DBArrays(nv=spec_b, ne=spec_b, degseq=spec_b2,
                          vhist=spec_b2, ehist=spec_b2, fd=spec_fd,
                          region_i=spec_b, region_j=spec_b)
    q_spec = fj.QueryArrays(nv=P(), ne=P(), sigma=P(None), vhist=P(None),
                            ehist=P(None), fd=spec_qfd, tau=P())
    out_spec = (P(batch_axes, None), P(batch_axes, None), P(batch_axes))

    n_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))

    def local_step(db: fj.DBArrays, q: fj.QueryArrays):
        mask, bounds = _device_bounds(db, q, x0, y0, l, model_axis)
        ids, bnd, cnt = fj.topk_candidates(mask, bounds, k)
        # globalise ids: offset by this shard's slab start.
        axis_index = jnp.int32(0)
        stride = 1
        for a in reversed(batch_axes):
            axis_index = axis_index + jax.lax.axis_index(a) * stride
            stride *= jc.axis_size(mesh, a)
        shard_b = db.nv.shape[0]
        gids = jnp.where(ids >= 0, ids + axis_index * shard_b, -1)
        return gids[None, :], bnd[None, :], cnt[None]

    shmap = jc.shard_map(
        local_step, mesh=mesh, in_specs=(db_spec, q_spec),
        out_specs=out_spec)

    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), db_spec,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), q_spec,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    fn = jax.jit(shmap)
    return fn, in_shardings, out_spec


def pad_db_to_shards(db: fj.DBArrays, n_shards: int) -> fj.DBArrays:
    """Pad the graph axis so it divides evenly across shards.

    Pads with impossible graphs (nv = -1) so they never pass the region
    mask or the bounds threshold.
    """
    B = db.nv.shape[0]
    pad = (-B) % n_shards
    if pad == 0:
        return db

    def pad_arr(a, fill=0):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return np.pad(np.asarray(a), widths, constant_values=fill)

    return fj.DBArrays(
        nv=pad_arr(db.nv, -(10 ** 6)), ne=pad_arr(db.ne, -(10 ** 6)),
        degseq=pad_arr(db.degseq), vhist=pad_arr(db.vhist),
        ehist=pad_arr(db.ehist), fd=pad_arr(db.fd),
        region_i=pad_arr(db.region_i, 2 ** 30),
        region_j=pad_arr(db.region_j, 2 ** 30))


def pad_vocab(db: fj.DBArrays, q: fj.QueryArrays, multiple: int
              ) -> Tuple[fj.DBArrays, fj.QueryArrays]:
    """Pad the F_D vocabulary dim to a multiple (zero counts = no-op for
    the min-sum contraction)."""
    U = db.fd.shape[1]
    pad = (-U) % multiple
    if pad == 0:
        return db, q
    fd = np.pad(np.asarray(db.fd), [(0, 0), (0, pad)])
    qfd = np.pad(np.asarray(q.fd), [(0, pad)])
    return db._replace(fd=fd), q._replace(fd=qfd)


def gather_candidates(gids: np.ndarray, bounds: np.ndarray,
                      counts: np.ndarray) -> np.ndarray:
    """Host-side: flatten per-device candidate blocks to a sorted id list."""
    gids = np.asarray(gids).reshape(-1)
    return np.sort(gids[gids >= 0])
