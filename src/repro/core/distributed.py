"""Distributed MSQ-Index search: shard_map over the production mesh.

Layouts (DESIGN.md §5):

* **Graph-sharded** (default): the region-sorted DB slab is block-partitioned
  over the ``('pod', 'data')`` axes; query replicated; each device filters
  its shard locally and emits a fixed-size top-k candidate block; candidate
  blocks are all-gathered.  No cross-device traffic proportional to |G| —
  only k ids per device.
* **Vocab-sharded** (TP analogue): additionally the dense F_D matrix is
  sharded over the vocabulary dim on the ``'model'`` axis; the min-sum
  contraction computes partial C_D per device and psums over ``'model'``.
  This is what makes very wide q-gram vocabularies (PubChem-scale) fit.

Both paths are pure jnp + lax collectives inside shard_map, so they lower
and compile for any mesh (exercised by the multi-pod dry-run).

Two entry points share the layouts:

* ``make_sharded_search`` — one query, the dry-run / example unit;
* ``make_sharded_multi_search`` — a whole *padded query block* (Q, ...)
  replicated to every device, the batched engine's per-bucket step
  (DESIGN.md §10): every device runs the full cascade for all Q queries
  of a bucket against its slab shard and emits per-query fixed-size
  candidate blocks plus the true per-shard pass count, so the host can
  detect block overflow and fall back to exact per-device ids.

The multi-search step is FilterSlab-aware (DESIGN.md §11): the sharded
F_D carrier is the dense matrix, the hot prefix (with the batched CSR
tail correction row-sharded alongside and added to C_D after the psum),
or the hybrid bit-packed words rows (decoded per device inside
shard_map; graph-sharded only).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import filters_jax as fj
from repro.core import jax_compat as jc


def _device_bounds(db: fj.DBArrays, q: fj.QueryArrays, x0: int, y0: int,
                   l: int, model_axis: Optional[str],
                   cd_extra: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Per-shard filter cascade; psums partial C_D over the model axis and
    adds ``cd_extra`` (the hot slab's CSR tail correction) afterwards, so
    the correction lands exactly once per C_D."""
    if model_axis is not None or cd_extra is not None:
        c_d = fj.min_sum(db.fd, q.fd[None, :]).astype(jnp.int32)
        if model_axis is not None:
            # fd is vocab-sharded: partial min-sum then psum.
            c_d = jax.lax.psum(c_d, model_axis)
        if cd_extra is not None:
            c_d = c_d + cd_extra.astype(jnp.int32)
    else:
        c_d = None
    return fj.filter_pass(db, q, x0, y0, l, c_d=c_d)


def layout_axes(mesh: Mesh, layout: str) -> Tuple[Tuple[str, ...], Optional[str]]:
    """(batch_axes, model_axis) for a serving layout on this mesh.

    ``graph``: every mesh axis block-partitions the graph dim (the model
    axis, when present, just adds more graph shards).  ``vocab``: graphs
    shard over the ('pod', 'data') axes and the dense F_D vocabulary dim
    shards over 'model'.
    """
    if layout == "graph":
        return tuple(mesh.axis_names), None
    if layout == "vocab":
        if "model" not in mesh.axis_names:
            raise ValueError("vocab-sharded layout needs a 'model' mesh axis")
        return tuple(a for a in mesh.axis_names if a != "model"), "model"
    raise ValueError(f"unknown layout {layout!r} (graph | vocab)")


def multi_search_specs(batch_axes: Sequence[str], model_axis: Optional[str],
                       slab: str = "dense"
                       ) -> Tuple[fj.DBArrays, fj.QueryArrays, Tuple, Tuple]:
    """PartitionSpecs for the multi-query step: DB slab shards, the
    replicated stacked (Q, ...) query block, the per-device candidate
    blocks (ids, bounds, pass counts), and the slab layout's extra
    operands (DESIGN.md §11) — ``()`` for dense, the (Q, B) tail
    correction for ``hot``, the (B, ...) packed words/sb/widths triple
    for ``packed``.
    """
    batch_axes = tuple(batch_axes)
    spec_b = P(batch_axes)
    spec_b2 = P(batch_axes, None)
    if model_axis is not None:
        if slab == "packed":
            raise ValueError("packed slab has no vocab dim to shard over "
                             "'model'; use the hot or dense slab")
        spec_fd = P(batch_axes, model_axis)
        spec_qfd = P(None, model_axis)
    else:
        spec_fd = spec_b2
        spec_qfd = P(None, None)
    if slab == "packed":
        spec_fd = spec_b2                 # (B, 1) placeholder rides along
    db_spec = fj.DBArrays(nv=spec_b, ne=spec_b, degseq=spec_b2,
                          vhist=spec_b2, ehist=spec_b2, fd=spec_fd,
                          region_i=spec_b, region_j=spec_b)
    q_spec = fj.QueryArrays(nv=P(None), ne=P(None), sigma=P(None, None),
                            vhist=P(None, None), ehist=P(None, None),
                            fd=spec_qfd, tau=P(None))
    out_spec = (P(batch_axes, None, None), P(batch_axes, None, None),
                P(batch_axes, None))
    if slab == "hot":
        extra_spec: Tuple = (P(None, batch_axes),)
    elif slab == "packed":
        extra_spec = (spec_b2, spec_b2, spec_b2)
    else:
        extra_spec = ()
    return db_spec, q_spec, out_spec, extra_spec


def make_sharded_multi_search(mesh: Mesh, x0: int, y0: int, l: int, k: int,
                              batch_axes: Sequence[str] = ("data",),
                              model_axis: Optional[str] = None,
                              slab: str = "dense",
                              n_entries: Optional[int] = None):
    """Build the jitted per-bucket step of the sharded engine.

    ``fn(db, qb, *extra)`` takes slab-sharded ``DBArrays``, a replicated
    stacked query block (every ``QueryArrays`` field with a leading Q
    axis), and the slab layout's extra operands — nothing for ``dense``,
    the (Q, B) row-sharded CSR tail correction for ``hot``, the packed
    words/sb/widths rows for ``packed`` (``n_entries`` = decoded F_D
    width; decoded per device inside shard_map, DESIGN.md §11) — and
    returns, all-gathered over the S batch shards:

      slab_ids (S, Q, k) int32 — positions into the *padded slab* of the
               (up to) k lowest-bound passing graphs per shard (-1 = empty);
      bounds   (S, Q, k) int32 — their filter lower bounds;
      n_pass   (S, Q)    int32 — the TRUE number of passing graphs on that
               shard, so ``n_pass > k`` flags a truncated (overflowing)
               block and the host falls back to exact per-device ids
               instead of silently dropping candidates.
    """
    batch_axes = tuple(batch_axes)
    db_spec, q_spec, out_spec, extra_spec = multi_search_specs(
        batch_axes, model_axis, slab)

    def _step(db: fj.DBArrays, qb: fj.QueryArrays, cdt):
        shard_b = db.nv.shape[0]
        axis_index = jnp.int32(0)
        stride = 1
        for a in reversed(batch_axes):
            axis_index = axis_index + jax.lax.axis_index(a) * stride
            stride *= jc.axis_size(mesh, a)

        def one(q: fj.QueryArrays, t):
            mask, bounds = _device_bounds(db, q, x0, y0, l, model_axis,
                                          cd_extra=t)
            ids, bnd, _ = fj.topk_candidates(mask, bounds, k)
            pad = k - ids.shape[0]          # shard smaller than k
            if pad:
                ids = jnp.concatenate(
                    [ids, jnp.full((pad,), -1, ids.dtype)])
                bnd = jnp.concatenate(
                    [bnd, jnp.full((pad,), 2 ** 30, bnd.dtype)])
            sids = jnp.where(ids >= 0, ids + axis_index * shard_b, -1)
            return sids, bnd, mask.sum().astype(jnp.int32)

        if cdt is None:
            sids, bnd, n_pass = jax.vmap(lambda q: one(q, None))(qb)
        else:
            sids, bnd, n_pass = jax.vmap(one)(qb, cdt)
        return sids[None], bnd[None], n_pass[None]

    if slab == "hot":
        def local_step(db, qb, cdt):
            return _step(db, qb, cdt)
    elif slab == "packed":
        from repro.kernels.bitunpack.ref import unpack_rows_ref

        def local_step(db, qb, words, sb, widths):
            # the resident shard is the packed form; decode in-device
            fd = unpack_rows_ref(words, sb, widths)[:, :n_entries]
            return _step(db._replace(fd=fd), qb, None)
    else:
        def local_step(db, qb):
            return _step(db, qb, None)

    shmap = jc.shard_map(local_step, mesh=mesh,
                         in_specs=(db_spec, q_spec) + extra_spec,
                         out_specs=out_spec)
    return jax.jit(shmap), (db_spec, q_spec) + extra_spec, out_spec


def assign_lb_specs(batch_axes: Sequence[str]) -> Tuple[Tuple, Tuple]:
    """PartitionSpecs for the stage-1.5 assignment-LB operands
    (DESIGN.md §16): the replicated stacked query branch block
    ``(qv, qd, qeh, qn)`` and the row-sharded db branch block
    ``(dv, dd, deh, dn)`` — (N, VM) labels/degrees, the (N, VM, NE)
    incident edge-label histograms, and the (N,) vertex counts, all
    block-partitioned over the batch axes like every other slab row."""
    batch_axes = tuple(batch_axes)
    q_specs = (P(None, None), P(None, None), P(None, None, None), P(None))
    db_specs = (P(batch_axes, None), P(batch_axes, None),
                P(batch_axes, None, None), P(batch_axes))
    return q_specs, db_specs


def make_sharded_assign_lb(mesh: Mesh,
                           batch_axes: Sequence[str] = ("data",)):
    """Jitted sharded assignment-LB pass: each device prices its slab
    shard's branch rows against the replicated query block and emits its
    (Q, N/S) slice of the LB matrix — column-sharded output, no
    collectives (the min-reduce is per (query, graph) pair, so shards
    are independent).  Bit-identical to the single-host paths."""
    from repro.kernels.assign_lb.ref import batched_assign_lb_ref
    q_specs, db_specs = assign_lb_specs(batch_axes)

    def local_step(qv, qd, qeh, qn, dv, dd, deh, dn):
        return batched_assign_lb_ref(qv, qd, qeh, qn, dv, dd, deh, dn)

    shmap = jc.shard_map(local_step, mesh=mesh,
                         in_specs=q_specs + db_specs,
                         out_specs=P(None, tuple(batch_axes)))
    return jax.jit(shmap)


def make_sharded_search(mesh: Mesh, x0: int, y0: int, l: int, k: int,
                        batch_axes: Sequence[str] = ("data",),
                        model_axis: Optional[str] = None):
    """Build a jitted distributed search step for the given mesh.

    Returns (fn, in_shardings, out_shardings).  ``fn(db, q)`` returns
    (global_ids, bounds, counts): per-device top-k candidate blocks
    all-gathered to a ((devices*k),) id vector (id -1 = empty slot), with
    ids already offset into global graph numbering.
    """
    batch_axes = tuple(batch_axes)
    spec_b = P(batch_axes)                     # (B,) sharded over batch axes
    spec_b2 = P(batch_axes, None)              # (B, X) row-sharded
    if model_axis is not None:
        spec_fd = P(batch_axes, model_axis)    # (B, U) row+vocab sharded
        spec_qfd = P(model_axis)
    else:
        spec_fd = spec_b2
        spec_qfd = P(None)

    db_spec = fj.DBArrays(nv=spec_b, ne=spec_b, degseq=spec_b2,
                          vhist=spec_b2, ehist=spec_b2, fd=spec_fd,
                          region_i=spec_b, region_j=spec_b)
    q_spec = fj.QueryArrays(nv=P(), ne=P(), sigma=P(None), vhist=P(None),
                            ehist=P(None), fd=spec_qfd, tau=P())
    out_spec = (P(batch_axes, None), P(batch_axes, None), P(batch_axes))

    n_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))

    def local_step(db: fj.DBArrays, q: fj.QueryArrays):
        mask, bounds = _device_bounds(db, q, x0, y0, l, model_axis)
        ids, bnd, cnt = fj.topk_candidates(mask, bounds, k)
        # globalise ids: offset by this shard's slab start.
        axis_index = jnp.int32(0)
        stride = 1
        for a in reversed(batch_axes):
            axis_index = axis_index + jax.lax.axis_index(a) * stride
            stride *= jc.axis_size(mesh, a)
        shard_b = db.nv.shape[0]
        gids = jnp.where(ids >= 0, ids + axis_index * shard_b, -1)
        return gids[None, :], bnd[None, :], cnt[None]

    shmap = jc.shard_map(
        local_step, mesh=mesh, in_specs=(db_spec, q_spec),
        out_specs=out_spec)

    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), db_spec,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), q_spec,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    fn = jax.jit(shmap)
    return fn, in_shardings, out_spec


def pad_db_to_shards(db: fj.DBArrays, n_shards: int) -> fj.DBArrays:
    """Pad the graph axis so it divides evenly across shards.

    Pads with impossible graphs (nv = -1) so they never pass the region
    mask or the bounds threshold.
    """
    B = db.nv.shape[0]
    pad = (-B) % n_shards
    if pad == 0:
        return db

    def pad_arr(a, fill=0):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return np.pad(np.asarray(a), widths, constant_values=fill)

    return fj.DBArrays(
        nv=pad_arr(db.nv, -(10 ** 6)), ne=pad_arr(db.ne, -(10 ** 6)),
        degseq=pad_arr(db.degseq), vhist=pad_arr(db.vhist),
        ehist=pad_arr(db.ehist), fd=pad_arr(db.fd),
        region_i=pad_arr(db.region_i, 2 ** 30),
        region_j=pad_arr(db.region_j, 2 ** 30))


def pad_vocab(db: fj.DBArrays, q: fj.QueryArrays, multiple: int
              ) -> Tuple[fj.DBArrays, fj.QueryArrays]:
    """Pad the F_D vocabulary dim to a multiple (zero counts = no-op for
    the min-sum contraction)."""
    U = db.fd.shape[1]
    pad = (-U) % multiple
    if pad == 0:
        return db, q
    fd = np.pad(np.asarray(db.fd), [(0, 0), (0, pad)])
    qfd = np.pad(np.asarray(q.fd), [(0, pad)])
    return db._replace(fd=fd), q._replace(fd=qfd)


def gather_candidates(gids: np.ndarray, bounds: np.ndarray,
                      counts: np.ndarray) -> np.ndarray:
    """Host-side: flatten per-device candidate blocks to a sorted id list."""
    gids = np.asarray(gids).reshape(-1)
    return np.sort(gids[gids >= 0])
