"""Q-gram extraction (Definitions 4 and 5) and frequency encodings.

Degree-based q-gram of vertex v:  D_v = (mu(v), multiset of adjacent edge
labels, d_v).  Label-based q-gram set: L(g) = Sigma_Vg  ∪  Sigma_Eg (as a
multiset; vertex labels and edge labels live in disjoint id ranges).

The global vocabularies U_D / U_L are frequency-ordered (most frequent
q-gram gets id 0) exactly as in Section 5.1 — this makes the per-graph
frequency arrays F_D / F_L dense at the front and zero-heavy at the tail,
which both the succinct encoding and the TPU "hot-prefix" layout exploit.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graphs.graph import Graph, GraphDB

DegreeQGram = Tuple[int, Tuple[int, ...], int]  # (vlabel, sorted adj elabels, degree)


def degree_qgrams(g: Graph) -> List[DegreeQGram]:
    """D(g): one degree-based q-gram per vertex."""
    adj: List[List[int]] = [[] for _ in range(g.n)]
    for (u, v), l in zip(g.edges, g.elabels):
        adj[int(u)].append(int(l))
        adj[int(v)].append(int(l))
    out: List[DegreeQGram] = []
    for v in range(g.n):
        labels = tuple(sorted(adj[v]))
        out.append((int(g.vlabels[v]), labels, len(labels)))
    return out


def label_qgrams(g: Graph, n_vlabels: int) -> List[int]:
    """L(g) as integer ids: vertex label l -> l; edge label l -> n_vlabels+l."""
    ids = [int(l) for l in g.vlabels]
    ids += [n_vlabels + int(l) for l in g.elabels]
    return ids


@dataclass
class QGramVocab:
    """Frequency-ordered vocabulary of degree-based and label-based q-grams."""

    degree_ids: Dict[DegreeQGram, int]
    n_label_ids: int  # |U_L| = n_vlabels + n_elabels (dense, already ids)
    n_vlabels: int
    n_elabels: int
    degree_order: List[DegreeQGram] = field(default_factory=list)

    @property
    def n_degree_ids(self) -> int:
        return len(self.degree_ids)

    @classmethod
    def build(cls, db: GraphDB) -> "QGramVocab":
        counts: Counter = Counter()
        for g in db:
            counts.update(degree_qgrams(g))
        # most frequent first; ties broken deterministically by key repr
        order = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        degree_ids = {k: i for i, (k, _) in enumerate(order)}
        return cls(
            degree_ids=degree_ids,
            n_label_ids=db.n_vlabels + db.n_elabels,
            n_vlabels=db.n_vlabels,
            n_elabels=db.n_elabels,
            degree_order=[k for k, _ in order],
        )

    # ---- per-graph encodings --------------------------------------------
    def encode_degree(self, g: Graph, allow_unknown: bool = True) -> Counter:
        """Sparse F_D as {degree-qgram-id: count}; unknown grams get id -1."""
        c: Counter = Counter()
        for q in degree_qgrams(g):
            idx = self.degree_ids.get(q, -1)
            if idx < 0 and not allow_unknown:
                raise KeyError(f"unknown degree q-gram {q}")
            c[idx] += 1
        return c

    def encode_label(self, g: Graph) -> Counter:
        c: Counter = Counter()
        for i in label_qgrams(g, self.n_vlabels):
            c[i] += 1
        return c

    def degree_of_id(self, idx: int) -> int:
        """d_v of the degree-based q-gram with this id (the T_D table of Alg 1)."""
        return self.degree_order[idx][2]

    def degree_id_table(self) -> np.ndarray:
        """T_D as an array: id -> degree."""
        return np.array([q[2] for q in self.degree_order], np.int32)


@dataclass
class EncodedDB:
    """Whole-database sparse F_D/F_L in CSR form + dense hot-prefix matrices.

    CSR arrays (host/archival):
      d_ids / d_cnt with row offsets d_off — per-graph nonzero F_D entries,
      ids ascending.  Same for l_*.

    Dense "hot" matrices (accelerator serving format, DESIGN.md §3): the
    first ``hot_d`` / ``hot_l`` vocabulary columns as (B, hot) int matrices;
    the sparse *tail* beyond the hot prefix stays CSR and is corrected on
    host.  For typical skewed vocabularies the tail is a few % of mass.
    """

    vocab: QGramVocab
    d_off: np.ndarray
    d_ids: np.ndarray
    d_cnt: np.ndarray
    l_off: np.ndarray
    l_ids: np.ndarray
    l_cnt: np.ndarray
    nv: np.ndarray
    ne: np.ndarray

    @classmethod
    def build(cls, db: GraphDB, vocab: Optional[QGramVocab] = None) -> "EncodedDB":
        if vocab is None:
            vocab = QGramVocab.build(db)
        d_off = [0]
        l_off = [0]
        d_ids: List[int] = []
        d_cnt: List[int] = []
        l_ids: List[int] = []
        l_cnt: List[int] = []
        for g in db:
            dc = vocab.encode_degree(g)
            for i in sorted(k for k in dc if k >= 0):
                d_ids.append(i)
                d_cnt.append(dc[i])
            d_off.append(len(d_ids))
            lc = vocab.encode_label(g)
            for i in sorted(lc):
                l_ids.append(i)
                l_cnt.append(lc[i])
            l_off.append(len(l_ids))
        nv, ne = db.sizes()
        return cls(
            vocab=vocab,
            d_off=np.asarray(d_off, np.int64),
            d_ids=np.asarray(d_ids, np.int32),
            d_cnt=np.asarray(d_cnt, np.int32),
            l_off=np.asarray(l_off, np.int64),
            l_ids=np.asarray(l_ids, np.int32),
            l_cnt=np.asarray(l_cnt, np.int32),
            nv=nv,
            ne=ne,
        )

    def __len__(self) -> int:
        return len(self.d_off) - 1

    def row_degree(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        return (self.d_ids[self.d_off[i]:self.d_off[i + 1]],
                self.d_cnt[self.d_off[i]:self.d_off[i + 1]])

    def row_label(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        return (self.l_ids[self.l_off[i]:self.l_off[i + 1]],
                self.l_cnt[self.l_off[i]:self.l_off[i + 1]])

    # ---- dense hot-prefix serving layout ---------------------------------
    def dense_hot(self, hot_d: int, hot_l: Optional[int] = None,
                  dtype=np.int32) -> Tuple[np.ndarray, np.ndarray]:
        """(B, hot_d) F_D prefix and (B, hot_l) F_L prefix dense matrices."""
        if hot_l is None:
            hot_l = self.vocab.n_label_ids
        B = len(self)
        FD = np.zeros((B, hot_d), dtype)
        FL = np.zeros((B, hot_l), dtype)
        for i in range(B):
            ids, cnt = self.row_degree(i)
            sel = ids < hot_d
            FD[i, ids[sel]] = cnt[sel]
            ids, cnt = self.row_label(i)
            sel = ids < hot_l
            FL[i, ids[sel]] = cnt[sel]
        return FD, FL

    def tail_intersection(self, i: int, q_sparse: Dict[int, int], hot_d: int) -> int:
        """Sum over ids >= hot_d of min(F_D[i, id], q[id]) (host correction,
        one row; the serving path uses ``tail_intersection_bulk``)."""
        ids, cnt = self.row_degree(i)
        m = ids >= hot_d
        if not m.any():
            return 0
        qv = np.array([q_sparse.get(int(x), 0) for x in ids[m]], np.int64)
        return int(np.minimum(cnt[m].astype(np.int64), qv).sum())

    def tail_intersection_bulk(self, q_ids: np.ndarray, q_cnt: np.ndarray,
                               hot_d: int) -> np.ndarray:
        """Batched CSR tail min-sum: for every graph, sum over ids >= hot_d
        of min(F_D[g, id], q[id]) — the per-batch correction the ``hot``
        FilterSlab layout adds to the device hot-prefix C_D (DESIGN.md §11).

        One vectorised sweep over the whole CSR (no per-graph Python
        loop).  Bucket-restricted corrections go through the gathered
        ``FilterSlab`` tail instead — this always costs O(whole CSR).
        """
        q_ids = np.asarray(q_ids, np.int64)
        q_cnt = np.asarray(q_cnt, np.int64)
        return csr_tail_minsum(self.d_off, self.d_ids, self.d_cnt,
                               q_ids, q_cnt, hot_d,
                               self.vocab.n_degree_ids)


def csr_tail_minsum(off: np.ndarray, ids: np.ndarray, cnt: np.ndarray,
                    q_ids: np.ndarray, q_cnt: np.ndarray, hot_d: int,
                    n_ids: int) -> np.ndarray:
    """Vectorised per-row SUM over ids >= hot_d of min(cnt, q[id]).

    ``off``/``ids``/``cnt`` are any CSR multiset slab (rows need not be
    pre-split at hot_d); the query arrives sparse.  Counts are small, so
    the bincount accumulation (float64) is exact.
    """
    B = len(off) - 1
    out = np.zeros(B, np.int64)
    tail_w = n_ids - hot_d
    if tail_w > 0 and len(ids) and len(q_ids):
        q_tail = np.zeros(tail_w, np.int64)
        sel = (q_ids >= hot_d) & (q_ids < n_ids)
        q_tail[q_ids[sel] - hot_d] = q_cnt[sel]
        row_of = np.repeat(np.arange(B), np.diff(off))
        m = ids >= hot_d
        contrib = np.minimum(cnt[m].astype(np.int64), q_tail[ids[m] - hot_d])
        out = np.bincount(row_of[m], weights=contrib,
                          minlength=B).astype(np.int64)
    return out


def sparse_intersection_size(a_ids: np.ndarray, a_cnt: np.ndarray,
                             b_ids: np.ndarray, b_cnt: np.ndarray) -> int:
    """|A ∩ B| for multisets in sorted-CSR form: sum of min counts."""
    i = j = 0
    total = 0
    na, nb = len(a_ids), len(b_ids)
    while i < na and j < nb:
        if a_ids[i] == b_ids[j]:
            total += min(int(a_cnt[i]), int(b_cnt[j]))
            i += 1
            j += 1
        elif a_ids[i] < b_ids[j]:
            i += 1
        else:
            j += 1
    return total
