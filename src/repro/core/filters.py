"""The paper's filters (Sections 2–3), host/numpy reference semantics.

Every function returns an *admissible lower bound* on ged(g, h) — a graph is
pruned iff its bound exceeds tau, so filtering never produces false
dismissals.  The vectorised accelerator versions live in
``repro.core.filters_jax`` and must agree exactly with these (tested).

Filters implemented:
  * number count (Zeng et al.)                 -> ``number_count_lb``
  * label count  (Zhao et al.)                 -> ``label_count_lb``
  * label-based q-gram counting (Sec 3.2)      -> ``label_qgram_lb``
  * degree-based q-gram counting (Lemma 2)     -> ``degree_qgram_lb``
  * degree-sequence filter (Lemma 5)           -> ``degree_sequence_lb``

Lemma 5 case II note (|V_h| > |V_g|): the paper's lambda_e minimises over
all vertex-deleted subgraphs h_1, which is combinatorial.  We use the exact
closed-form *relaxation* derived in DESIGN.md: allowing arbitrary degree
reductions of the kept vertices (a superset of achievable h_1) and dropping
the ceilings gives

    lambda_e  >=  |E_h| + |E_g| - sum_i min(sigma_g[i], sigma_h[i]),

with both sequences sorted non-increasing and the sum over the first |V_g|
entries.  This is a valid lower bound of the paper's minimum (proof in
DESIGN.md; property-tested against brute-force GED).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.graphs.graph import Graph


# --------------------------------------------------------------------------
# scalar (two-graph) forms
# --------------------------------------------------------------------------

def number_count_lb(nv_g: int, ne_g: int, nv_h: int, ne_h: int) -> int:
    """dist_N(g,h) = ||Vg|-|Vh|| + ||Eg|-|Eh||  <=  ged(g,h)."""
    return abs(nv_g - nv_h) + abs(ne_g - ne_h)


def multiset_overlap(hist_a: np.ndarray, hist_b: np.ndarray) -> int:
    """|A ∩ B| for multisets given as histograms."""
    return int(np.minimum(hist_a, hist_b).sum())


def label_count_lb(nv_g: int, ne_g: int, nv_h: int, ne_h: int,
                   overlap_v: int, overlap_e: int) -> int:
    """dist_L(g,h) <= ged(g,h) (Section 2)."""
    return max(nv_g, nv_h) - overlap_v + max(ne_g, ne_h) - overlap_e


def label_qgram_lb(nv_g: int, ne_g: int, nv_h: int, ne_h: int, c_l: int) -> int:
    """Label-based q-gram counting filter (Sec 3.2, = label count rewritten).

    C_L = |L(g) ∩ L(h)|; bound: ged >= max(|Vg|,|Vh|) + max(|Eg|,|Eh|) - C_L.
    """
    return max(nv_g, nv_h) + max(ne_g, ne_h) - c_l


def degree_qgram_lb(nv_g: int, nv_h: int, overlap_v: int, c_d: int) -> int:
    """Degree-based q-gram counting filter (Lemma 2).

    From |D(g) ∩ D(h)| >= 2 max(|Vg|,|Vh|) - overlap_v - 2 tau:
        ged >= ceil((2 max(|Vg|,|Vh|) - overlap_v - C_D) / 2).
    """
    num = 2 * max(nv_g, nv_h) - overlap_v - c_d
    return max(0, -(-num // 2))  # ceil for positive, floor-free for negative


def degseq_delta(x: np.ndarray, y: np.ndarray) -> int:
    """Definition 6: Delta(x, y) with the two ceil-halved one-sided sums.

    x, y are equal-length degree vectors (align by zero-padding).
    """
    x = np.asarray(x, np.int64)
    y = np.asarray(y, np.int64)
    if x.shape != y.shape:
        n = max(len(x), len(y))
        x = np.pad(x, (0, n - len(x)))
        y = np.pad(y, (0, n - len(y)))
    d = x - y
    s1 = int(np.maximum(d, 0).sum())    # entries where y < x
    s2 = int(np.maximum(-d, 0).sum())   # entries where y > x
    return -(-s1 // 2) + (-(-s2 // 2))


def degree_sequence_lb(nv_g: int, ne_g: int, sigma_g: np.ndarray,
                       nv_h: int, ne_h: int, sigma_h: np.ndarray,
                       overlap_v: int) -> int:
    """Degree-sequence filter (Lemma 5): ged >= max(|Vg|,|Vh|) - overlap_v + lambda_e."""
    sigma_g = np.sort(np.asarray(sigma_g, np.int64))[::-1]
    sigma_h = np.sort(np.asarray(sigma_h, np.int64))[::-1]
    if nv_h <= nv_g:
        # case I: sigma_1 = sigma_h zero-padded to |Vg| — exact.
        pad = np.pad(sigma_h, (0, nv_g - nv_h))
        lam = degseq_delta(sigma_g, pad)
    else:
        # case II: closed-form relaxation (see module docstring).
        top = sigma_h[:nv_g]
        lam = int(ne_h + ne_g - np.minimum(sigma_g, top).sum())
        lam = max(lam, 0)
    return max(nv_g, nv_h) - overlap_v + lam


# --------------------------------------------------------------------------
# convenience: all filters for a pair of graphs
# --------------------------------------------------------------------------

def pairwise_bounds(g: Graph, h: Graph, n_vlabels: int, n_elabels: int,
                    c_d: Optional[int] = None) -> Dict[str, int]:
    """All lower bounds for a (g, h) pair.  ``c_d`` (degree q-gram
    intersection size) may be supplied to avoid recomputation."""
    from repro.core.qgrams import degree_qgrams  # local import to avoid cycle
    from collections import Counter

    vh_g = g.vertex_label_hist(n_vlabels)
    vh_h = h.vertex_label_hist(n_vlabels)
    eh_g = g.edge_label_hist(n_elabels)
    eh_h = h.edge_label_hist(n_elabels)
    overlap_v = multiset_overlap(vh_g, vh_h)
    overlap_e = multiset_overlap(eh_g, eh_h)
    c_l = overlap_v + overlap_e
    if c_d is None:
        cg = Counter(degree_qgrams(g))
        ch = Counter(degree_qgrams(h))
        c_d = sum(min(cg[k], ch[k]) for k in cg.keys() & ch.keys())
    bounds = {
        "number_count": number_count_lb(g.n, g.m, h.n, h.m),
        "label_count": label_count_lb(g.n, g.m, h.n, h.m, overlap_v, overlap_e),
        "label_qgram": label_qgram_lb(g.n, g.m, h.n, h.m, c_l),
        "degree_qgram": degree_qgram_lb(g.n, h.n, overlap_v, c_d),
        "degree_sequence": degree_sequence_lb(
            g.n, g.m, g.degree_sequence(), h.n, h.m, h.degree_sequence(),
            overlap_v),
    }
    bounds["combined"] = max(bounds.values())
    return bounds


# --------------------------------------------------------------------------
# batched numpy forms (oracle for the JAX / Pallas paths)
# --------------------------------------------------------------------------

def batched_bounds_np(nv: np.ndarray, ne: np.ndarray, degseq: np.ndarray,
                      vhist: np.ndarray, ehist: np.ndarray,
                      c_d: np.ndarray,
                      q_nv: int, q_ne: int, q_degseq: np.ndarray,
                      q_vhist: np.ndarray, q_ehist: np.ndarray) -> Dict[str, np.ndarray]:
    """Vectorised filters: database batch (B, ...) against one query.

    ``degseq`` is (B, Vmax) non-increasing zero-padded; ``q_degseq`` is
    (Vmax,) likewise.  ``c_d`` is the per-graph degree-q-gram intersection
    size (computed by the q-gram kernel / CSR merge).
    """
    nv = nv.astype(np.int64)
    ne = ne.astype(np.int64)
    overlap_v = np.minimum(vhist, q_vhist[None, :]).sum(axis=1)
    overlap_e = np.minimum(ehist, q_ehist[None, :]).sum(axis=1)
    c_l = overlap_v + overlap_e
    max_nv = np.maximum(nv, q_nv)
    max_ne = np.maximum(ne, q_ne)

    number_count = np.abs(nv - q_nv) + np.abs(ne - q_ne)
    label_count = max_nv - overlap_v + max_ne - overlap_e
    label_qgram = max_nv + max_ne - c_l
    degree_qgram = np.maximum(0, -(-(2 * max_nv - overlap_v - c_d) // 2))

    # degree-sequence filter, both cases vectorised (zero-padding aligns):
    dq = degseq.astype(np.int64)
    qq = q_degseq.astype(np.int64)[None, :]
    d = dq - qq
    s1 = np.maximum(d, 0).sum(axis=1)   # query below data
    s2 = np.maximum(-d, 0).sum(axis=1)
    # case I (q_nv <= nv): Delta with zero-padded query — but only rows where
    # q_nv <= nv may use it; other rows use the case II closed form.
    delta = -(-s1 // 2) + (-(-s2 // 2))
    min_sum = np.minimum(dq, qq).sum(axis=1)
    lam_2 = np.maximum(q_ne + ne - min_sum, 0)
    lam = np.where(q_nv <= nv, delta, lam_2)
    degree_sequence = max_nv - overlap_v + lam

    out = {
        "number_count": number_count,
        "label_count": label_count,
        "label_qgram": label_qgram,
        "degree_qgram": degree_qgram,
        "degree_sequence": degree_sequence,
    }
    out["combined"] = np.maximum.reduce(list(out.values()))
    return out
