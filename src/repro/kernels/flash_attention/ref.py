"""Pure-jnp oracle: dense masked attention with GQA / causal / window."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None, causal: bool = False,
                  window: int = 0, kv_offset: int = 0) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = kv_offset + jnp.arange(Sq)[:, None]
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
