"""Blocked online-softmax attention Pallas kernel (GQA / causal / local).

The LM stack's compute hot spot.  Standard flash-attention structure tuned
for the TPU memory hierarchy:

  grid = (batch, q_heads, Sq/BQ, Skv/BK), kv innermost;
  q tile (BQ, D) stays resident across the kv sweep; k/v tiles (BK, D)
  stream HBM->VMEM; running max m, denominator l and accumulator acc live
  in VMEM scratch (f32); the MXU sees (BQ, D) x (D, BK) and (BQ, BK) x
  (BK, D) matmuls with BQ/BK multiples of 128 on real hardware.

GQA maps q head h to kv head h // group in the k/v index_maps — no
materialised head broadcast (that would multiply HBM traffic by the group
size).  Causal + sliding-window masks are iota comparisons inside the
block; fully-masked kv blocks are skipped with pl.when (block-level
causality test), which is what makes causal attention ~2x cheaper.

``kv_offset`` supports decode: query position i is global position
kv_offset + i (queries sit at the end of the KV cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, kv_offset: int,
            bq: int, bk: int):
    j = pl.program_id(3)
    nk = pl.num_programs(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: rows of this q tile span
    #   [kv_offset + i*bq, kv_offset + (i+1)*bq)
    # kv cols span [j*bk, (j+1)*bk)
    row_lo = kv_offset + i * bq
    row_hi = row_lo + bq - 1
    col_lo = j * bk
    visible = jnp.bool_(True)
    if causal:
        visible = visible & (col_lo <= row_hi)
    if window > 0:
        visible = visible & (col_lo + bk - 1 >= row_lo - window + 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = row_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _write():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "kv_offset", "bq", "bk",
                     "interpret"))
def flash_attention_call(q, k, v, *, scale: float, causal: bool,
                         window: int, kv_offset: int, bq: int, bk: int,
                         interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Shapes tile-aligned."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    grid = (B, Hq, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        kv_offset=kv_offset, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
