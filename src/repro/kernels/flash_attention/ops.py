"""Public jit'd flash-attention wrapper: padding, block sizing, backend
selection (interpret off-TPU), and the XLA fallback used by the dry-run
model path (Pallas lowers only on real TPU)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_call
from repro.kernels.flash_attention.ref import attention_ref


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "kv_offset", "bq", "bk",
                     "impl"))
def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    causal: bool = True, window: int = 0, kv_offset: int = 0,
                    bq: int = 128, bk: int = 128,
                    impl: str = "auto") -> jax.Array:
    """Attention with GQA + causal + sliding-window.

    impl: 'pallas' (real TPU), 'interpret' (kernel body on CPU — tests),
    'xla' (jnp reference path — what the dry-run lowers), 'auto' (pallas on
    TPU else xla).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return attention_ref(q, k, v, scale=scale, causal=causal,
                             window=window, kv_offset=kv_offset)
    B, Hq, Sq, D = q.shape
    bq_eff = min(bq, Sq) if Sq % min(bq, Sq) == 0 else Sq
    Skv = k.shape[2]
    bk_eff = min(bk, Skv) if Skv % min(bk, Skv) == 0 else Skv
    qp, pad_q = _pad_axis(q, bq_eff, 2)
    kp, pad_k = _pad_axis(k, bk_eff, 2)
    vp, _ = _pad_axis(v, bk_eff, 2)
    if pad_k:
        # padded kv columns must never win the softmax: causal mask handles
        # rows; for padded cols rely on the window/causal mask — enforce by
        # masking k with NEG via v zeros and q rows (handled in-kernel by
        # causal); for non-causal padding we bail to exact sizes instead.
        assert causal or window > 0, "non-causal inputs must be bk-aligned"
    out = flash_attention_call(
        qp, kp, vp, scale=scale, causal=causal, window=window,
        kv_offset=kv_offset, bq=bq_eff, bk=bk_eff,
        interpret=(impl == "interpret"))
    return out[:, :, :Sq, :]
