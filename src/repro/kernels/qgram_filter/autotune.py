"""Block-size autotuner for the query-batched fused filter kernel
(DESIGN.md §13).

The kernel's ``(qb, bb, bu)`` tile sizes trade VMEM residency against
grid overhead, and the right point depends on the serving shapes — how
many queries share a block, how many graphs a region bucket holds, how
wide the degree vocabulary is.  The ROADMAP's open item ("tune the
qgram_filter block sizes for the padded multi-query shapes") is this
module: sweep candidate tiles over the *real bucket shapes* of a built
index, keep the fastest per canonical shape bucket, persist the table to
``artifacts/tune/qgram_filter.json``, and let serving load it with the
built-in defaults as fallback (``MSQConfig.tile_table()`` /
``BatchedFilterEval``).

Off-TPU the sweep runs the kernel in interpret mode — the same code path
CI exercises — so the machinery is tested everywhere; the timings that
matter are the ones taken on a real TPU (``timed_on`` records which kind
a table holds).  Candidate tiles are powers of two so every tile evenly
divides every shape-bucket ladder value (``ops.shape_bucket``).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_TILES: Tuple[int, int, int] = (8, 128, 512)
DEFAULT_PATH = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "..",
    "artifacts", "tune", "qgram_filter.json"))

# powers of two only: shape_bucket guarantees any of these tiles an even
# grid after min(tile, bucket)
QB_CANDIDATES = (4, 8, 16)
BB_CANDIDATES = (64, 128, 256)
BU_CANDIDATES = (128, 256, 512)


def canonical_shape(Q: int, B: int, U: int) -> Tuple[int, int, int]:
    """The shape-bucket key a (Q, B, U) launch resolves to — independent
    of the tile choice, so the tuner and the serving path agree."""
    from repro.kernels.qgram_filter import ops
    return (ops.shape_bucket(Q, ops.Q_BASE, ops.Q_CAP),
            ops.shape_bucket(B, ops.B_BASE, ops.B_CAP),
            ops.shape_bucket(U, ops.U_BASE, ops.U_CAP))


def _key(shape: Tuple[int, int, int]) -> str:
    return "x".join(str(int(s)) for s in shape)


class TileTable:
    """Shape-bucket -> (qb, bb, bu) lookup with a default fallback."""

    def __init__(self, entries: Optional[Dict[str, Sequence[int]]] = None,
                 default: Tuple[int, int, int] = DEFAULT_TILES,
                 timed_on: str = ""):
        self.entries: Dict[str, Tuple[int, int, int]] = {
            k: tuple(int(x) for x in v) for k, v in (entries or {}).items()}
        self.default = tuple(int(x) for x in default)
        self.timed_on = timed_on

    def lookup(self, Q: int, B: int, U: int) -> Tuple[int, int, int]:
        return self.entries.get(_key(canonical_shape(Q, B, U)), self.default)

    def __len__(self) -> int:
        return len(self.entries)


@functools.lru_cache(maxsize=8)
def load_tile_table(path: Optional[str] = None) -> TileTable:
    """Load the persisted table; a missing/unreadable file is the default
    table (tuning is an optimisation, never a requirement)."""
    path = DEFAULT_PATH if path is None else path
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        entries = {k: v["tiles"] for k, v in doc.get("entries", {}).items()}
        return TileTable(entries, timed_on=doc.get("timed_on", ""))
    except (OSError, ValueError, KeyError, TypeError):
        return TileTable()


def default_table() -> TileTable:
    return load_tile_table(None)


def _synth_operands(rng, Q, B, U, NV, NE, VM):
    """Random tile-aligned operands of one canonical shape."""
    import jax.numpy as jnp
    sc = np.concatenate([rng.integers(1, 30, (Q, 2)),
                         rng.integers(1, 4, (Q, 1)),
                         np.full((Q, 2), 25), np.full((Q, 1), 4)],
                        axis=1).astype(np.int32)
    aux = np.concatenate([rng.integers(1, 30, (B, 2)),
                          rng.integers(-3, 4, (B, 2))], 1).astype(np.int32)
    arr = lambda *s: jnp.asarray(rng.integers(0, 4, s).astype(np.int32))
    return (jnp.asarray(sc), arr(B, U), arr(Q, U), arr(B, NV), arr(Q, NV),
            arr(B, NE), arr(Q, NE), arr(B, VM), arr(Q, VM),
            jnp.asarray(aux), jnp.asarray(np.zeros((Q, B), np.int32)))


def _time_tiles(args, qb, bb, bu, interpret: bool, repeats: int) -> float:
    from repro.kernels.qgram_filter.kernel import fused_batched_call
    run = lambda: fused_batched_call(*args, qb=qb, bb=bb, bu=bu,
                                     interpret=interpret)[0]
    run().block_until_ready()                      # compile / warm
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(shapes: Iterable[Tuple[int, int, int]], *,
          nv: int = 62, ne: int = 3, vm: int = 64,
          candidates: Optional[Iterable[Tuple[int, int, int]]] = None,
          repeats: int = 3, interpret: Optional[bool] = None,
          max_interpret_b: int = 1024, seed: int = 0,
          verbose: bool = False) -> Dict[str, Dict]:
    """Time every candidate tile on every canonical shape; return
    {shape key: {"tiles": best, "us": best time, "swept": n}}.

    Interpret mode (CPU) clamps B to ``max_interpret_b`` — the Python
    grid loop makes huge shapes pointless to time there, and the table
    those runs produce is exercise/fallback material, not a tuning claim.
    """
    from repro.kernels.qgram_filter.ops import on_tpu
    if interpret is None:
        interpret = not on_tpu()
    if candidates is None:
        candidates = [(qb, bb, bu) for qb in QB_CANDIDATES
                      for bb in BB_CANDIDATES for bu in BU_CANDIDATES]
    rng = np.random.default_rng(seed)
    out: Dict[str, Dict] = {}
    for shape in shapes:
        Q, B, U = canonical_shape(*shape)
        # the key is ALWAYS the unclamped canonical shape — serving looks
        # buckets up by their true size, so a clamp-keyed entry would
        # never be found; the clamp only shrinks what gets timed
        key = _key((Q, B, U))
        if key in out:
            continue
        B_t = min(B, max_interpret_b) if interpret else B
        args = _synth_operands(rng, Q, B_t, U, nv, ne, vm)
        best, best_t = DEFAULT_TILES, np.inf
        seen = set()
        for qb, bb, bu in candidates:
            eff = (min(qb, Q), min(bb, B_t), min(bu, U))
            if eff in seen:
                continue
            seen.add(eff)
            t = _time_tiles(args, *eff, interpret=interpret,
                            repeats=repeats)
            if verbose:
                print(f"  {key} tiles={eff}: {t * 1e6:.0f}us")
            if t < best_t:
                best, best_t = eff, t
        out[key] = {"tiles": list(best), "us": best_t * 1e6,
                    "swept": len(seen)}
        if B_t != B:
            out[key]["timed_b"] = B_t
        if verbose:
            print(f"{key} -> {best} ({best_t * 1e6:.0f}us)")
    return out


def slab_shapes(slab, qs: Sequence[int] = (8, 64),
                max_shapes: int = 4) -> List[Tuple[int, int, int]]:
    """The real bucket shapes a built FilterSlab serves: the full slab
    plus the largest distinct per-region bucket sizes, at each expected
    query-block size.  U is the layout's on-device F_D width (hot prefix
    for 'hot', the 128-block-padded decode width for 'packed')."""
    if slab.layout == "hot":
        U = slab.hot_d
    elif slab.layout == "packed":
        U = slab.packed.sb.shape[1] * 128
    else:
        U = slab.U
    sizes = {int(slab.B)}
    _, counts = np.unique(
        np.stack([slab.region_i, slab.region_j]), axis=1, return_counts=True)
    for c in sorted(counts.tolist(), reverse=True)[:max_shapes]:
        sizes.add(int(c))
    return [(int(q), b, U) for q in qs for b in sorted(sizes)]


def autotune_slab(slab, *, qs: Sequence[int] = (8, 64),
                  save_path: Optional[str] = DEFAULT_PATH,
                  **kw) -> "TileTable":
    """Index-build-time entry point: sweep the slab's real bucket shapes,
    merge into (and persist to) the on-disk table, return the merged
    TileTable.  ``save_path=None`` skips persistence."""
    results = sweep(slab_shapes(slab, qs=qs),
                    nv=slab.vhist.shape[1], ne=slab.ehist.shape[1],
                    vm=slab.degseq.shape[1], **kw)
    return save_table(results, save_path)


def save_table(results: Dict[str, Dict],
               path: Optional[str] = DEFAULT_PATH) -> TileTable:
    """Merge sweep results into the persisted table and return it.

    Merge rule: a CPU-interpret sweep (exercise/fallback material) must
    never clobber an entry timed on a real TPU — only same-or-better
    provenance replaces (``timed_on`` is kept per entry; the table-level
    field reports 'tpu' iff any entry is TPU-timed)."""
    import jax
    backend = jax.default_backend()
    doc = {"version": 1, "timed_on": backend, "entries": {}}
    if path is not None and os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                old = json.load(f)
            doc["entries"] = old.get("entries", {})
            for k, v in doc["entries"].items():   # rows predating the
                v.setdefault("timed_on", old.get("timed_on", ""))  # field
        except (OSError, ValueError):
            pass
    for k, v in results.items():
        have = doc["entries"].get(k)
        if (have is not None and have.get("timed_on") == "tpu"
                and backend != "tpu"):
            continue                  # never downgrade TPU timings
        doc["entries"][k] = {**v, "timed_on": backend}
    if any(v.get("timed_on") == "tpu" for v in doc["entries"].values()):
        doc["timed_on"] = "tpu"
    if path is not None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        load_tile_table.cache_clear()      # readers see the new table
    return TileTable({k: v["tiles"] for k, v in doc["entries"].items()},
                     timed_on=doc["timed_on"])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=2000,
                    help="graphs in the synthetic AIDS-like DB")
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "hot", "packed"])
    ap.add_argument("--hot-d", type=int, default=128)
    ap.add_argument("--q", type=int, nargs="+", default=[8, 64],
                    help="query-block sizes to tune for")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=DEFAULT_PATH)
    args = ap.parse_args()

    from repro.core.qgrams import EncodedDB
    from repro.core.region import default_partition
    from repro.core.slab import FilterSlab
    from repro.graphs.generators import aids_like_db

    db = aids_like_db(args.n, seed=0)
    enc = EncodedDB.build(db, None)
    nv, ne = db.sizes()
    partition = default_partition(nv, ne, l=4)
    slab = FilterSlab.build(db, enc, partition, layout=args.layout,
                            hot_d=args.hot_d if args.layout == "hot"
                            else None)
    table = autotune_slab(slab, qs=tuple(args.q), save_path=args.out,
                          repeats=args.repeats, verbose=True)
    print(f"{len(table)} shape buckets tuned "
          f"(timed on {table.timed_on}) -> {args.out}")


if __name__ == "__main__":
    main()
