"""Pure-jnp oracle for the fused filter kernel.

Delegates to ``repro.core.filters_jax`` (which is itself tested against the
scalar host filters and brute-force GED), so the kernel's chain of evidence
reaches the paper's lemmas.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import filters_jax as fj


def fused_filter_bounds_ref(scalars, fd, qfd, vhist, qvh, ehist, qeh,
                            degseq, qsig, aux):
    """Same signature/semantics as the kernel: returns (bounds, mask)."""
    q_nv, q_ne, tau, x0, y0, l = [scalars[i] for i in range(6)]
    db = fj.DBArrays(nv=aux[:, 0], ne=aux[:, 1], degseq=degseq, vhist=vhist,
                     ehist=ehist, fd=fd, region_i=aux[:, 2], region_j=aux[:, 3])
    q = fj.QueryArrays(nv=q_nv, ne=q_ne, sigma=qsig, vhist=qvh, ehist=qeh,
                       fd=qfd, tau=tau)
    c_d = fj.min_sum(fd, qfd[None, :]).astype(jnp.int32) + aux[:, 4]
    bounds = fj.batched_bounds(db, q, c_d=c_d)
    # region mask with traced scalars (filters_jax.region_mask takes python
    # ints for geometry; inline the traced version here)
    s, dd = x0 + y0, y0 - x0
    i1 = jnp.floor_divide(q.ne - q.tau + q.nv - s, l)
    i2 = jnp.floor_divide(q.ne + q.tau + q.nv - s, l)
    j1 = jnp.floor_divide(q.ne - q.tau - q.nv - dd, l)
    j2 = jnp.floor_divide(q.ne + q.tau - q.nv - dd, l)
    in_region = ((db.region_i >= i1) & (db.region_i <= i2)
                 & (db.region_j >= j1) & (db.region_j <= j2))
    mask = (in_region & (bounds <= q.tau)).astype(jnp.int32)
    return bounds.astype(jnp.int32), mask


def fused_batched_bounds_ref(scalars, fd, qfd, vhist, qvh, ehist, qeh,
                             degseq, qsig, aux, cdt):
    """Oracle for the query-batched kernel (DESIGN.md §13): a Python loop
    of single-query refs, the (Q, B) C_D seed ``cdt`` riding in each
    row's aux column 4.  Same (Q, B) bounds/mask contract as
    ``fused_batched_call``."""
    import numpy as np
    bs, ms = [], []
    aux4 = jnp.asarray(aux)[:, :4]
    for r in range(np.asarray(scalars).shape[0]):
        aux5 = jnp.concatenate(
            [aux4, jnp.asarray(cdt)[r][:, None].astype(jnp.int32)], axis=1)
        b, m = fused_filter_bounds_ref(
            jnp.asarray(scalars)[r], fd, jnp.asarray(qfd)[r], vhist,
            jnp.asarray(qvh)[r], ehist, jnp.asarray(qeh)[r], degseq,
            jnp.asarray(qsig)[r], aux5)
        bs.append(b)
        ms.append(m)
    return jnp.stack(bs), jnp.stack(ms)
