"""Fused MSQ filter-cascade Pallas kernel.

One pass over the dense degree-q-gram frequency matrix computes, per graph:

  C_D   = sum_j min(F_D[g, j], q_D[j])           (vocab-tiled accumulation)
  C_Lv  = sum   min(vhist, q_vhist)   (vertex-label overlap)
  C_Le  = sum   min(ehist, q_ehist)
  lam   = degree-sequence term (Lemma 5, both cases)
  bound = max(number count, label q-gram, degree q-gram, degree sequence)
  mask  = in reduced query region  &  bound <= tau

Memory behaviour: F_D tiles are streamed HBM->VMEM exactly once (this is
the bandwidth-dominant operand); the small per-graph arrays (histograms,
degree sequences, sizes) live in VMEM across the whole vocab sweep — Pallas
skips re-copies when the index map is unchanged.  The filters are
memory-bound, so the fusion (vs. separate passes per filter) is the
roofline lever: every additional pass would re-read F_D.

Grid: (B / BB, U / BU); C_D accumulates in a VMEM scratch and the cascade
finalises on the last vocab tile.

Scalar parameters (query sizes, tau, region geometry) arrive via SMEM.

The query-batched variant (``fused_batched_call``, DESIGN.md §13) amortises
the F_D stream over a whole padded query block: grid (Q/QB, B/BB, U/BU),
per-query scalars as an SMEM (QB, N_SCALARS) block, query-side operands
blocked along a leading Q axis, (QB, BB) outputs and VMEM C_D scratch.  Each
F_D tile is reused by all QB queries of the block while resident in VMEM —
the single-query kernel re-reads the whole matrix once per query.  The hot
slab's per-(query, graph) CSR tail correction arrives as a dedicated
(QB, BB) operand seeding the scratch (it no longer fits in the per-graph
aux columns once queries batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# scalar layout in the SMEM parameter vector
Q_NV, Q_NE, TAU, X0, Y0, LREG = range(6)
N_SCALARS = 6


def _kernel(scalars_ref,          # SMEM (6,) int32
            fd_ref,               # (BB, BU) int32
            qfd_ref,              # (BU,)    int32
            vhist_ref,            # (BB, NV) int32
            qvh_ref,              # (NV,)    int32
            ehist_ref,            # (BB, NE) int32
            qeh_ref,              # (NE,)    int32
            degseq_ref,           # (BB, VM) int32
            qsig_ref,             # (VM,)    int32
            aux_ref,              # (BB, 5)  int32: nv, ne, region_i, region_j,
                                  #                 cd_tail (sparse-tail C_D)
            bounds_ref,           # (BB,)    int32 out
            mask_ref,             # (BB,)    int32 out (0/1)
            cd_acc):              # VMEM (BB,) scratch
    j = pl.program_id(1)
    nu = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        # seed with the host-computed cold-vocabulary contribution so the
        # hot-prefix layout stays admissible (DESIGN.md §3)
        cd_acc[...] = aux_ref[:, 4]

    cd_acc[...] += jnp.minimum(fd_ref[...], qfd_ref[...][None, :]).sum(axis=1)

    @pl.when(j == nu - 1)
    def _finalize():
        q_nv = scalars_ref[Q_NV]
        q_ne = scalars_ref[Q_NE]
        tau = scalars_ref[TAU]
        nv = aux_ref[:, 0]
        ne = aux_ref[:, 1]
        c_d = cd_acc[...]

        overlap_v = jnp.minimum(vhist_ref[...], qvh_ref[...][None, :]).sum(axis=1)
        overlap_e = jnp.minimum(ehist_ref[...], qeh_ref[...][None, :]).sum(axis=1)
        c_l = overlap_v + overlap_e
        max_nv = jnp.maximum(nv, q_nv)
        max_ne = jnp.maximum(ne, q_ne)

        number_count = jnp.abs(nv - q_nv) + jnp.abs(ne - q_ne)
        label_qgram = max_nv + max_ne - c_l
        degree_qgram = jnp.maximum(0, (2 * max_nv - overlap_v - c_d + 1) // 2)

        d = degseq_ref[...] - qsig_ref[...][None, :]
        s1 = jnp.maximum(d, 0).sum(axis=1)
        s2 = jnp.maximum(-d, 0).sum(axis=1)
        delta = (s1 + 1) // 2 + (s2 + 1) // 2
        min_deg = jnp.minimum(degseq_ref[...], qsig_ref[...][None, :]).sum(axis=1)
        lam2 = jnp.maximum(q_ne + ne - min_deg, 0)
        lam = jnp.where(q_nv <= nv, delta, lam2)
        degree_sequence = max_nv - overlap_v + lam

        bound = jnp.maximum(jnp.maximum(number_count, label_qgram),
                            jnp.maximum(degree_qgram, degree_sequence))

        # reduced query region (formula (1)) — fused in
        x0 = scalars_ref[X0]
        y0 = scalars_ref[Y0]
        l = scalars_ref[LREG]
        s = x0 + y0
        dd = y0 - x0
        i1 = jnp.floor_divide(q_ne - tau + q_nv - s, l)
        i2 = jnp.floor_divide(q_ne + tau + q_nv - s, l)
        j1 = jnp.floor_divide(q_ne - tau - q_nv - dd, l)
        j2 = jnp.floor_divide(q_ne + tau - q_nv - dd, l)
        ri = aux_ref[:, 2]
        rj = aux_ref[:, 3]
        in_region = ((ri >= i1) & (ri <= i2) & (rj >= j1) & (rj <= j2))

        bounds_ref[...] = bound.astype(jnp.int32)
        mask_ref[...] = (in_region & (bound <= tau)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bb", "bu", "interpret"))
def fused_filter_call(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq, qsig,
                      aux, *, bb: int = 128, bu: int = 512,
                      interpret: bool = False):
    """Raw pallas_call wrapper; shapes must already be tile-aligned."""
    B, U = fd.shape
    NV = vhist.shape[1]
    NE = ehist.shape[1]
    VM = degseq.shape[1]
    assert B % bb == 0 and U % bu == 0, (B, U, bb, bu)
    grid = (B // bb, U // bu)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # scalars
            pl.BlockSpec((bb, bu), lambda i, j: (i, j)),            # fd
            pl.BlockSpec((bu,), lambda i, j: (j,)),                 # qfd
            pl.BlockSpec((bb, NV), lambda i, j: (i, 0)),            # vhist
            pl.BlockSpec((NV,), lambda i, j: (0,)),                 # qvh
            pl.BlockSpec((bb, NE), lambda i, j: (i, 0)),            # ehist
            pl.BlockSpec((NE,), lambda i, j: (0,)),                 # qeh
            pl.BlockSpec((bb, VM), lambda i, j: (i, 0)),            # degseq
            pl.BlockSpec((VM,), lambda i, j: (0,)),                 # qsig
            pl.BlockSpec((bb, 5), lambda i, j: (i, 0)),             # aux
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bb,), jnp.int32)],
        interpret=interpret,
    )(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq, qsig, aux)


# --------------------------------------------------------------------------
# query-batched kernel (DESIGN.md §13)
# --------------------------------------------------------------------------

def _batched_kernel(scalars_ref,      # SMEM (QB, N_SCALARS) int32
                    fd_ref,           # (BB, BU) int32
                    qfd_ref,          # (QB, BU) int32
                    vhist_ref,        # (BB, NV) int32
                    qvh_ref,          # (QB, NV) int32
                    ehist_ref,        # (BB, NE) int32
                    qeh_ref,          # (QB, NE) int32
                    degseq_ref,       # (BB, VM) int32
                    qsig_ref,         # (QB, VM) int32
                    aux_ref,          # (BB, 4)  int32: nv, ne, region_i/j
                    cdt_ref,          # (QB, BB) int32: host C_D seed (hot
                                      #          tail correction; else zeros)
                    bounds_ref,       # (QB, BB) int32 out
                    mask_ref,         # (QB, BB) int32 out (0/1)
                    cd_acc):          # VMEM (QB, BB) scratch
    j = pl.program_id(2)
    nu = pl.num_programs(2)
    QB = scalars_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        cd_acc[...] = cdt_ref[...]

    # (QB, BB, BU) broadcast min-sum: the F_D tile is read once and served
    # to every query of the block — the whole point of query batching.
    cd_acc[...] += jnp.minimum(fd_ref[...][None, :, :],
                               qfd_ref[...][:, None, :]).sum(axis=2)

    @pl.when(j == nu - 1)
    def _finalize():
        def scol(c):
            # per-query scalar column as a (QB, 1) vector; SMEM reads stay
            # scalar (TPU-safe), QB is static so the stack unrolls
            return jnp.stack([scalars_ref[r, c]
                              for r in range(QB)])[:, None]

        q_nv, q_ne, tau = scol(Q_NV), scol(Q_NE), scol(TAU)
        nv = aux_ref[:, 0][None, :]
        ne = aux_ref[:, 1][None, :]
        c_d = cd_acc[...]

        overlap_v = jnp.minimum(vhist_ref[...][None, :, :],
                                qvh_ref[...][:, None, :]).sum(axis=2)
        overlap_e = jnp.minimum(ehist_ref[...][None, :, :],
                                qeh_ref[...][:, None, :]).sum(axis=2)
        c_l = overlap_v + overlap_e
        max_nv = jnp.maximum(nv, q_nv)
        max_ne = jnp.maximum(ne, q_ne)

        number_count = jnp.abs(nv - q_nv) + jnp.abs(ne - q_ne)
        label_qgram = max_nv + max_ne - c_l
        degree_qgram = jnp.maximum(0, (2 * max_nv - overlap_v - c_d + 1) // 2)

        d = degseq_ref[...][None, :, :] - qsig_ref[...][:, None, :]
        s1 = jnp.maximum(d, 0).sum(axis=2)
        s2 = jnp.maximum(-d, 0).sum(axis=2)
        delta = (s1 + 1) // 2 + (s2 + 1) // 2
        min_deg = jnp.minimum(degseq_ref[...][None, :, :],
                              qsig_ref[...][:, None, :]).sum(axis=2)
        lam2 = jnp.maximum(q_ne + ne - min_deg, 0)
        lam = jnp.where(q_nv <= nv, delta, lam2)
        degree_sequence = max_nv - overlap_v + lam

        bound = jnp.maximum(jnp.maximum(number_count, label_qgram),
                            jnp.maximum(degree_qgram, degree_sequence))

        x0, y0, l = scol(X0), scol(Y0), scol(LREG)
        s = x0 + y0
        dd = y0 - x0
        i1 = jnp.floor_divide(q_ne - tau + q_nv - s, l)
        i2 = jnp.floor_divide(q_ne + tau + q_nv - s, l)
        j1 = jnp.floor_divide(q_ne - tau - q_nv - dd, l)
        j2 = jnp.floor_divide(q_ne + tau - q_nv - dd, l)
        ri = aux_ref[:, 2][None, :]
        rj = aux_ref[:, 3][None, :]
        in_region = ((ri >= i1) & (ri <= i2) & (rj >= j1) & (rj <= j2))

        bounds_ref[...] = bound.astype(jnp.int32)
        mask_ref[...] = (in_region & (bound <= tau)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("qb", "bb", "bu", "interpret"))
def fused_batched_call(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq,
                       qsig, aux, cdt, *, qb: int = 8, bb: int = 128,
                       bu: int = 512, interpret: bool = False):
    """Raw query-batched pallas_call; shapes must already be tile-aligned.

    scalars (Q, N_SCALARS); fd (B, U); qfd (Q, U); vhist (B, NV);
    qvh (Q, NV); ehist (B, NE); qeh (Q, NE); degseq (B, VM); qsig (Q, VM);
    aux (B, 4); cdt (Q, B).  Returns ((Q, B) bounds, (Q, B) mask).
    """
    Q, B, U = scalars.shape[0], fd.shape[0], fd.shape[1]
    NV = vhist.shape[1]
    NE = ehist.shape[1]
    VM = degseq.shape[1]
    assert Q % qb == 0 and B % bb == 0 and U % bu == 0, (Q, B, U, qb, bb, bu)
    grid = (Q // qb, B // bb, U // bu)
    return pl.pallas_call(
        _batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qb, N_SCALARS), lambda q, i, j: (q, 0),
                         memory_space=pltpu.SMEM),                  # scalars
            pl.BlockSpec((bb, bu), lambda q, i, j: (i, j)),         # fd
            pl.BlockSpec((qb, bu), lambda q, i, j: (q, j)),         # qfd
            pl.BlockSpec((bb, NV), lambda q, i, j: (i, 0)),         # vhist
            pl.BlockSpec((qb, NV), lambda q, i, j: (q, 0)),         # qvh
            pl.BlockSpec((bb, NE), lambda q, i, j: (i, 0)),         # ehist
            pl.BlockSpec((qb, NE), lambda q, i, j: (q, 0)),         # qeh
            pl.BlockSpec((bb, VM), lambda q, i, j: (i, 0)),         # degseq
            pl.BlockSpec((qb, VM), lambda q, i, j: (q, 0)),         # qsig
            pl.BlockSpec((bb, 4), lambda q, i, j: (i, 0)),          # aux
            pl.BlockSpec((qb, bb), lambda q, i, j: (q, i)),         # cdt
        ],
        out_specs=[
            pl.BlockSpec((qb, bb), lambda q, i, j: (q, i)),
            pl.BlockSpec((qb, bb), lambda q, i, j: (q, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, B), jnp.int32),
            jax.ShapeDtypeStruct((Q, B), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((qb, bb), jnp.int32)],
        interpret=interpret,
    )(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq, qsig, aux, cdt)
