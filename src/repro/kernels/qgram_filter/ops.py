"""Public jit'd wrapper for the fused filter kernel.

Handles tile-padding, the scalar parameter vector, backend selection
(interpret=True off-TPU), and the optional sparse-tail C_D correction that
keeps the hot-prefix layout admissible (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qgram_filter.kernel import N_SCALARS, fused_filter_call


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def make_scalars(q_nv: int, q_ne: int, tau: int, x0: int, y0: int,
                 l: int) -> jnp.ndarray:
    return jnp.asarray([q_nv, q_ne, tau, x0, y0, l], jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("bb", "bu", "interpret"))
def fused_filter_bounds(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq,
                        qsig, aux, *, bb: int = 128, bu: int = 512,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """(bounds, mask) for a database shard vs one query.

    Pads B to ``bb`` (with impossible graphs: nv = -2**20 so every bound is
    huge and the region test fails) and U to ``bu`` (zero counts: no-op for
    min-sum).  Returns unpadded (B,) arrays.
    """
    if interpret is None:
        interpret = not on_tpu()
    B, U = fd.shape
    bb = min(bb, _next_mult(B, 8))
    bu = min(bu, _next_mult(U, 128))
    fd_p = _pad_to(_pad_to(fd, bb, 0), bu, 1)
    qfd_p = _pad_to(qfd, bu, 0)
    vhist_p = _pad_to(vhist, bb, 0)
    ehist_p = _pad_to(ehist, bb, 0)
    degseq_p = _pad_to(degseq, bb, 0)
    aux_p = _pad_to(aux, bb, 0, value=-(2 ** 20))
    bounds, mask = fused_filter_call(
        scalars, fd_p, qfd_p, vhist_p, qvh, ehist_p, qeh, degseq_p, qsig,
        aux_p, bb=bb, bu=bu, interpret=interpret)
    return bounds[:B], mask[:B]


def _next_mult(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def make_aux(nv, ne, region_i, region_j, cd_tail=None) -> jnp.ndarray:
    """Pack the per-graph scalar columns; cd_tail is the host-computed
    cold-vocabulary SUM(min(F_D, q_D)) when fd holds only the hot prefix
    (zeros for the full-vocab layout)."""
    if cd_tail is None:
        cd_tail = jnp.zeros_like(nv)
    return jnp.stack([nv, ne, region_i, region_j, cd_tail], axis=1
                     ).astype(jnp.int32)


def cd_tail_host(enc, q_ids: np.ndarray, q_cnt: np.ndarray, hot: int
                 ) -> np.ndarray:
    """Host CSR merge for the cold-vocabulary C_D contribution.

    Only the query's ids >= hot participate; one vectorised sweep over the
    whole CSR (``EncodedDB.tail_intersection_bulk``) regardless of |G|.
    """
    return enc.tail_intersection_bulk(np.asarray(q_ids), np.asarray(q_cnt),
                                      hot).astype(np.int32)
