"""Public jit'd wrappers for the fused filter kernel.

Handles tile-padding, the scalar parameter vector, backend selection
(interpret=True off-TPU), and the optional sparse-tail C_D correction that
keeps the hot-prefix layout admissible (DESIGN.md §3).

Padded shapes round up to a shared shape-bucket ladder (``shape_bucket``,
powers of two up to the block size, then block-size multiples — the same
buckets ``core.engine`` pads the (Q, N) jax pass to), so nearby bucket
sizes share one compiled program instead of baking a fresh static block
size per distinct B (DESIGN.md §13).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qgram_filter.kernel import (N_SCALARS, fused_batched_call,
                                               fused_filter_call)

# shared shape-bucket ladders (keep in sync with core.engine._Q_PAD/_N_PAD)
Q_BASE, Q_CAP = 8, 64
B_BASE, B_CAP = 8, 512
U_BASE, U_CAP = 128, 512


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def shape_bucket(n: int, base: int, cap: int) -> int:
    """Round ``n`` up to the shared shape-bucket ladder: powers of two
    times ``base`` up to ``cap``, then multiples of ``cap``.  Every ladder
    value is divisible by any power-of-two block size <= itself, so
    ``min(block, bucket)`` always tiles it evenly."""
    m = base
    while m < n and m < cap:
        m *= 2
    return m if n <= m else _next_mult(n, cap)


def _pad_and_block(n: int, base: int, blk: int) -> Tuple[int, int]:
    """(padded size, effective block) for one axis: the shared shape
    bucket when the block divides it (power-of-two blocks always do), an
    exact block multiple otherwise (explicit odd blocks keep working)."""
    pad = shape_bucket(n, base, max(blk, base))
    blk = min(blk, pad)
    if pad % blk:
        pad = _next_mult(n, blk)
    return pad, blk


def make_scalars(q_nv: int, q_ne: int, tau: int, x0: int, y0: int,
                 l: int) -> jnp.ndarray:
    return jnp.asarray([q_nv, q_ne, tau, x0, y0, l], jnp.int32)


def make_scalars_batch(qs, x0: int, y0: int, l: int) -> np.ndarray:
    """(Q, N_SCALARS) scalar rows for a stacked query block."""
    return np.asarray([[int(q.nv), int(q.ne), int(q.tau), x0, y0, l]
                       for q in qs], np.int32)


@functools.partial(jax.jit,
                   static_argnames=("bb", "bu", "interpret"))
def fused_filter_bounds(scalars, fd, qfd, vhist, qvh, ehist, qeh, degseq,
                        qsig, aux, *, bb: int = 128, bu: int = 512,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """(bounds, mask) for a database shard vs one query.

    Pads B to its shape bucket (with impossible graphs: nv = -2**20 so
    every bound is huge and the region test fails) and U to a multiple of
    the vocab tile (zero counts: no-op for min-sum).  Returns unpadded
    (B,) arrays.
    """
    if interpret is None:
        interpret = not on_tpu()
    B, U = fd.shape
    b_pad, bb = _pad_and_block(B, B_BASE, bb)
    u_pad, bu = _pad_and_block(U, U_BASE, bu)
    fd_p = _pad_to(_pad_to(fd, b_pad, 0), u_pad, 1)
    qfd_p = _pad_to(qfd, u_pad, 0)
    vhist_p = _pad_to(vhist, b_pad, 0)
    ehist_p = _pad_to(ehist, b_pad, 0)
    degseq_p = _pad_to(degseq, b_pad, 0)
    aux_p = _pad_to(aux, b_pad, 0, value=-(2 ** 20))
    bounds, mask = fused_filter_call(
        scalars, fd_p, qfd_p, vhist_p, qvh, ehist_p, qeh, degseq_p, qsig,
        aux_p, bb=bb, bu=bu, interpret=interpret)
    return bounds[:B], mask[:B]


@functools.partial(jax.jit,
                   static_argnames=("qb", "bb", "bu", "interpret"))
def fused_filter_bounds_batched(scalars, fd, qfd, vhist, qvh, ehist, qeh,
                                degseq, qsig, aux, cdt=None, *,
                                qb: int = 8, bb: int = 128, bu: int = 512,
                                interpret: Optional[bool] = None
                                ) -> Tuple[jax.Array, jax.Array]:
    """(bounds, mask), both (Q, B), for a database shard vs a whole query
    block — one kernel launch for every (query, graph) pair
    (DESIGN.md §13).

    Query-side operands carry a leading Q axis (``scalars`` (Q, 6), ``qfd``
    (Q, U), ...); ``cdt`` is the (Q, B) host-computed C_D seed (the hot
    slab's CSR tail correction; omitted/None means zeros).  Q pads by
    repeating the last scalar row (always-valid geometry — padded rows are
    sliced off), B pads with impossible graphs, U with zero counts.
    """
    if interpret is None:
        interpret = not on_tpu()
    Q = scalars.shape[0]
    B, U = fd.shape
    q_pad, qb = _pad_and_block(Q, Q_BASE, qb)
    b_pad, bb = _pad_and_block(B, B_BASE, bb)
    u_pad, bu = _pad_and_block(U, U_BASE, bu)
    sc_p = jnp.concatenate(
        [scalars] + [scalars[-1:]] * (q_pad - Q)) if q_pad > Q else scalars
    fd_p = _pad_to(_pad_to(fd, b_pad, 0), u_pad, 1)
    qfd_p = _pad_to(_pad_to(qfd, q_pad, 0), u_pad, 1)
    vhist_p = _pad_to(vhist, b_pad, 0)
    qvh_p = _pad_to(qvh, q_pad, 0)
    ehist_p = _pad_to(ehist, b_pad, 0)
    qeh_p = _pad_to(qeh, q_pad, 0)
    degseq_p = _pad_to(degseq, b_pad, 0)
    qsig_p = _pad_to(qsig, q_pad, 0)
    aux_p = _pad_to(aux[:, :4], b_pad, 0, value=-(2 ** 20))
    if cdt is None:
        cdt_p = jnp.zeros((q_pad, b_pad), jnp.int32)
    else:
        cdt_p = _pad_to(_pad_to(cdt.astype(jnp.int32), q_pad, 0), b_pad, 1)
    bounds, mask = fused_batched_call(
        sc_p, fd_p, qfd_p, vhist_p, qvh_p, ehist_p, qeh_p, degseq_p,
        qsig_p, aux_p, cdt_p, qb=qb, bb=bb, bu=bu, interpret=interpret)
    return bounds[:Q, :B], mask[:Q, :B]


def _next_mult(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def make_aux(nv, ne, region_i, region_j, cd_tail=None) -> jnp.ndarray:
    """Pack the per-graph scalar columns; cd_tail is the host-computed
    cold-vocabulary SUM(min(F_D, q_D)) when fd holds only the hot prefix
    (zeros for the full-vocab layout)."""
    if cd_tail is None:
        cd_tail = jnp.zeros_like(nv)
    return jnp.stack([nv, ne, region_i, region_j, cd_tail], axis=1
                     ).astype(jnp.int32)


def cd_tail_host(enc, q_ids: np.ndarray, q_cnt: np.ndarray, hot: int
                 ) -> np.ndarray:
    """Host CSR merge for the cold-vocabulary C_D contribution.

    Only the query's ids >= hot participate; one vectorised sweep over the
    whole CSR (``EncodedDB.tail_intersection_bulk``) regardless of |G|.
    """
    return enc.tail_intersection_bulk(np.asarray(q_ids), np.asarray(q_cnt),
                                      hot).astype(np.int32)
