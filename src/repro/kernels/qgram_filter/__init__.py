from repro.kernels.qgram_filter.ops import fused_filter_bounds

__all__ = ["fused_filter_bounds"]
