# Pallas TPU kernels for the compute hot-spots (DESIGN.md §4):
#   qgram_filter    — fused MSQ filter cascade (the paper's query hot path)
#   bitunpack       — succinct block-packed frequency decode (TPU-adapted
#                     hybrid encoding; see DESIGN.md §3)
#   rank_popcount   — bitmap rank-dictionary construction
#   flash_attention — blocked online-softmax attention for the LM stack
#
# Every kernel: kernel.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
# ops.py (jit'd public wrapper; interpret=True on CPU), ref.py (pure-jnp
# oracle).  The dry-run model path uses the jnp/XLA implementations — Pallas
# lowers only on real TPU; interpret mode validates kernel bodies on CPU.
