"""Public API: host packer + jit'd unpacker for the TPU hybrid encoding.

Two packed forms share the same per-block width coding:

* the flat stream (``pack_hybrid`` / ``unpack_hybrid``) — one global word
  array with absolute block offsets, fed straight to the Pallas kernel;
* the rectangular row-wise slab (``pack_hybrid_rows`` / ``PackedRows``) —
  one row of words per *graph*, offsets relative to the row, so bucket rows
  gather and mesh shards block-partition like any other (B, X) array
  (the ``packed`` FilterSlab layout, DESIGN.md §11).
  ``flatten_packed_rows`` rebases it onto the flat form for the kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitunpack.kernel import (BLOCK_ENTRIES, MAX_WORDS, WIDTHS,
                                            bitunpack_call)


def pack_hybrid(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pack int values into the block-width hybrid format.

    Returns (words int32, sb int32, widths int32, n_valid) where the last
    block is zero-padded to 128 entries and ``words`` carries MAX_WORDS
    trailing guard words.
    """
    values = np.asarray(values, np.int64)
    if values.size and values.min() < 0:
        raise ValueError("values must be non-negative")
    n = int(values.size)
    n_blocks = max((n + BLOCK_ENTRIES - 1) // BLOCK_ENTRIES, 1)
    padded = np.zeros(n_blocks * BLOCK_ENTRIES, np.int64)
    padded[:n] = values
    sb = np.zeros(n_blocks, np.int32)
    widths = np.zeros(n_blocks, np.int32)
    words: list[int] = []
    for k in range(n_blocks):
        blk = padded[k * BLOCK_ENTRIES:(k + 1) * BLOCK_ENTRIES]
        need = max(int(blk.max()).bit_length(), 1)
        w = next(x for x in WIDTHS if x >= need)
        widths[k] = w
        sb[k] = len(words)
        per = 32 // w
        blk_u = blk.astype(np.uint64)
        for i in range(BLOCK_ENTRIES // per):
            word = 0
            for e in range(per):
                word = (word << w) | int(blk_u[i * per + e])
            words.append(word)
    words_arr = np.zeros(len(words) + MAX_WORDS, np.uint32)
    words_arr[:len(words)] = np.asarray(words, np.uint32)
    return words_arr.view(np.int32), sb, widths, n


def unpack_hybrid(sb, widths, words, n_valid: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Decode to a flat (n_valid,) int32 array (kernel + trim)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_blocks = int(sb.shape[0])
    out = bitunpack_call(jnp.asarray(sb), jnp.asarray(widths),
                         jnp.asarray(words), n_blocks=n_blocks,
                         interpret=interpret)
    flat = out.reshape(-1)
    if n_valid is not None:
        flat = flat[:n_valid]
    return flat


def packed_size_bits(words: np.ndarray, sb: np.ndarray,
                     widths: np.ndarray) -> int:
    """Index footprint of the packed representation (excl. guard words)."""
    payload = int(sb[-1]) * 32 if len(sb) else 0
    # last block payload:
    if len(sb):
        payload += BLOCK_ENTRIES // (32 // int(widths[-1])) * 32
    sb_bits = len(sb) * 32
    w_bits = len(widths) * 3  # 5 widths -> 3 bits each
    return payload + sb_bits + w_bits


# --------------------------------------------------------------------------
# rectangular row-wise packed slab (the FilterSlab 'packed' layout)
# --------------------------------------------------------------------------

class PackedRows(NamedTuple):
    """Row-wise hybrid-packed matrix: row r of the original (B, U) int
    matrix lives in ``words[r]`` as ``KB = ceil(U/128)`` width-coded blocks.

    words:     (B, W) int32 — per-row block payloads concatenated,
               zero-padded to W = max row payload words
    sb:        (B, KB) int32 — word offset of block k *within its row*
    widths:    (B, KB) int32 — bit width per block (one of WIDTHS)
    n_entries: valid entries per row (U); entries beyond are pad zeros
    """

    words: np.ndarray
    sb: np.ndarray
    widths: np.ndarray
    n_entries: int


def _block_widths(mx: np.ndarray) -> np.ndarray:
    """Narrowest width in WIDTHS holding values <= mx (vectorised)."""
    w = np.full(mx.shape, WIDTHS[0], np.int32)
    for wide in WIDTHS[1:]:
        w[mx >= (1 << (wide // 2))] = wide
    if (mx >= (1 << 32)).any():
        raise ValueError("values do not fit in 32 bits")
    return w


def pack_hybrid_rows(mat: np.ndarray) -> PackedRows:
    """Pack a (B, U) non-negative int matrix row-by-row.

    Unlike ``pack_hybrid`` the result is rectangular, so rows gather /
    shard like a dense matrix while the payload keeps the per-block hybrid
    width coding.  Decode with ``unpack_rows_np`` (host),
    ``ref.unpack_rows_ref`` (jnp, shard_map-safe), or rebase with
    ``flatten_packed_rows`` for the Pallas kernel.
    """
    mat = np.asarray(mat, np.int64)
    if mat.ndim != 2:
        raise ValueError(f"expected a (B, U) matrix, got shape {mat.shape}")
    if mat.size and mat.min() < 0:
        raise ValueError("values must be non-negative")
    B, U = mat.shape
    KB = max((U + BLOCK_ENTRIES - 1) // BLOCK_ENTRIES, 1)
    blk = np.zeros((B, KB * BLOCK_ENTRIES), np.int64)
    blk[:, :U] = mat
    blk = blk.reshape(B, KB, BLOCK_ENTRIES)
    widths = _block_widths(blk.max(axis=2)) if B else np.zeros((0, KB),
                                                               np.int32)
    # words per block = 128 * w / 32 = 4w; sb = exclusive prefix per row
    wpb = 4 * widths
    sb = np.zeros((B, KB), np.int32)
    if KB > 1:
        sb[:, 1:] = np.cumsum(wpb[:, :-1], axis=1)
    W = int((sb[:, -1] + wpb[:, -1]).max()) if B else 4 * WIDTHS[0] * KB
    words = np.zeros((B, W), np.uint32)
    for w in WIDTHS:
        rsel, ksel = np.nonzero(widths == w)
        if not len(rsel):
            continue
        per = 32 // w
        ent = blk[rsel, ksel].reshape(-1, 4 * w, per).astype(np.uint64)
        shifts = ((per - 1 - np.arange(per)) * w).astype(np.uint64)
        payload = (ent << shifts[None, None, :]).sum(axis=2).astype(np.uint32)
        # scatter each block's 4w words into its row at sb
        col = sb[rsel, ksel][:, None] + np.arange(4 * w)[None, :]
        words[rsel[:, None], col] = payload
    return PackedRows(words=words.view(np.int32), sb=sb, widths=widths,
                      n_entries=U)


def unpack_rows_np(pk: PackedRows) -> np.ndarray:
    """Host decode of ``PackedRows`` to the dense (B, U) int32 matrix."""
    B, KB = pk.sb.shape
    e = np.arange(BLOCK_ENTRIES, dtype=np.int64)[None, None, :]
    w = pk.widths[:, :, None].astype(np.int64)
    bit = pk.sb[:, :, None].astype(np.int64) * 32 + e * w
    rows = np.arange(B)[:, None, None]
    wvals = pk.words.view(np.uint32)[rows, bit // 32].astype(np.uint64)
    shift = (32 - w - bit % 32).astype(np.uint64)
    mask = (np.uint64(1) << w.astype(np.uint64)) - np.uint64(1)
    out = ((wvals >> shift) & mask).astype(np.int32)
    return out.reshape(B, KB * BLOCK_ENTRIES)[:, :pk.n_entries]


def flatten_packed_rows(pk: PackedRows
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Rebase row-relative offsets to the flat stream the kernel expects.

    Returns (words, sb, widths) for ``unpack_hybrid``: words raveled with
    MAX_WORDS trailing guard words, sb made absolute (row*W + local).
    """
    B, W = pk.words.shape
    if B * W + MAX_WORDS > np.iinfo(np.int32).max:
        # the kernel's SMEM offsets are int32; beyond this the slab must
        # be split into sub-buckets before flattening
        raise ValueError(f"packed slab too large to flatten: {B} rows x "
                         f"{W} words overflows int32 word offsets")
    words = np.concatenate([pk.words.reshape(-1),
                            np.zeros(MAX_WORDS, np.int32)])
    sb = (np.arange(B, dtype=np.int64)[:, None] * W
          + pk.sb).astype(np.int32).reshape(-1)
    return words, sb, pk.widths.reshape(-1).astype(np.int32)


def packed_rows_size_bits(pk: PackedRows) -> dict:
    """Serving-resident footprint of the rectangular packed slab — counted
    at the arrays' actual int32 residency (widths could pack into 3 bits
    each, but that is not how they sit in memory) — plus the ragged
    payload lower bound (what a length-exact stream would take)."""
    B, W = pk.words.shape
    KB = pk.sb.shape[1]
    words_bits = B * W * 32
    sb_bits = B * KB * 32
    widths_bits = B * KB * 32
    ragged_bits = int((4 * pk.widths.astype(np.int64)).sum()) * 32
    return {"words": words_bits, "sb": sb_bits, "widths": widths_bits,
            "total": words_bits + sb_bits + widths_bits,
            "ragged_payload": ragged_bits}
