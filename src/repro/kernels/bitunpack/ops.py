"""Public API: host packer + jit'd unpacker for the TPU hybrid encoding."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitunpack.kernel import (BLOCK_ENTRIES, MAX_WORDS, WIDTHS,
                                            bitunpack_call)


def pack_hybrid(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pack int values into the block-width hybrid format.

    Returns (words int32, sb int32, widths int32, n_valid) where the last
    block is zero-padded to 128 entries and ``words`` carries MAX_WORDS
    trailing guard words.
    """
    values = np.asarray(values, np.int64)
    if values.size and values.min() < 0:
        raise ValueError("values must be non-negative")
    n = int(values.size)
    n_blocks = max((n + BLOCK_ENTRIES - 1) // BLOCK_ENTRIES, 1)
    padded = np.zeros(n_blocks * BLOCK_ENTRIES, np.int64)
    padded[:n] = values
    sb = np.zeros(n_blocks, np.int32)
    widths = np.zeros(n_blocks, np.int32)
    words: list[int] = []
    for k in range(n_blocks):
        blk = padded[k * BLOCK_ENTRIES:(k + 1) * BLOCK_ENTRIES]
        need = max(int(blk.max()).bit_length(), 1)
        w = next(x for x in WIDTHS if x >= need)
        widths[k] = w
        sb[k] = len(words)
        per = 32 // w
        blk_u = blk.astype(np.uint64)
        for i in range(BLOCK_ENTRIES // per):
            word = 0
            for e in range(per):
                word = (word << w) | int(blk_u[i * per + e])
            words.append(word)
    words_arr = np.zeros(len(words) + MAX_WORDS, np.uint32)
    words_arr[:len(words)] = np.asarray(words, np.uint32)
    return words_arr.view(np.int32), sb, widths, n


def unpack_hybrid(sb, widths, words, n_valid: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Decode to a flat (n_valid,) int32 array (kernel + trim)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_blocks = int(sb.shape[0])
    out = bitunpack_call(jnp.asarray(sb), jnp.asarray(widths),
                         jnp.asarray(words), n_blocks=n_blocks,
                         interpret=interpret)
    flat = out.reshape(-1)
    if n_valid is not None:
        flat = flat[:n_valid]
    return flat


def packed_size_bits(words: np.ndarray, sb: np.ndarray,
                     widths: np.ndarray) -> int:
    """Index footprint of the packed representation (excl. guard words)."""
    payload = int(sb[-1]) * 32 if len(sb) else 0
    # last block payload:
    if len(sb):
        payload += BLOCK_ENTRIES // (32 // int(widths[-1])) * 32
    sb_bits = len(sb) * 32
    w_bits = len(widths) * 3  # 5 widths -> 3 bits each
    return payload + sb_bits + w_bits
