"""Succinct block-decode Pallas kernel (the TPU hybrid encoding).

TPU adaptation of the paper's per-block hybrid coding (DESIGN.md §3): each
block of 128 entries is stored at the narrowest power-of-two bit width in
{2, 4, 8, 16, 32} that fits its maximum value (the per-block *scheme choice*
of the paper, with vectorisable fixed-width lanes instead of bit-serial
Elias gamma).  Because 128 * w / 32 is an integer for every width, block
payloads are word-aligned: SB[k] is a word offset and no entry straddles a
word.

Kernel layout:
  * the packed word stream lives as a full-array VMEM ref — per-device
    Psi shards are ~1-2 MB for PubChem-scale DBs (25M graphs / 256 chips),
    comfortably inside the 16 MB VMEM budget (DESIGN.md §3);
  * SB (word offsets) and widths live in SMEM (scalar memory);
  * grid = one step per block; each step dynamic-slices its <=128-word
    window, unpacks all five width hypotheses with static shift/mask
    vector code, and selects by the block's width — pure VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ENTRIES = 128
WIDTHS = (2, 4, 8, 16, 32)
MAX_WORDS = BLOCK_ENTRIES * 32 // 32  # width=32 worst case: 128 words


def _unpack_width(win_u32: jax.Array, width: int) -> jax.Array:
    """Static-width unpack of the first 128*width/32 words -> (128,) int32.

    MSB-first within each word: entry e of word w sits at bit
    32 - width - e*width.
    """
    per = 32 // width
    n_words = BLOCK_ENTRIES // per
    words = win_u32[:n_words]
    shifts = (32 - width - jnp.arange(per, dtype=jnp.uint32) * width)
    vals = jax.lax.shift_right_logical(
        words[:, None], jnp.broadcast_to(shifts[None, :], (n_words, per)))
    vals = vals & jnp.uint32((1 << width) - 1)
    return vals.reshape(BLOCK_ENTRIES).astype(jnp.int32)


def _kernel(sb_ref,        # SMEM (n_blocks,) int32 — word offset per block
            w_ref,         # SMEM (n_blocks,) int32 — bit width per block
            words_ref,     # VMEM (n_words_padded,) int32 — packed stream
            out_ref):      # (1, 128) int32 — decoded block
    k = pl.program_id(0)
    start = sb_ref[k]
    width = w_ref[k]
    win = pl.load(words_ref, (pl.ds(start, MAX_WORDS),)).astype(jnp.uint32)
    out = _unpack_width(win, WIDTHS[0])
    for wbits in WIDTHS[1:]:
        out = jnp.where(width == wbits, _unpack_width(win, wbits), out)
    out_ref[0, :] = out


@functools.partial(jax.jit, static_argnames=("n_blocks", "interpret"))
def bitunpack_call(sb, widths, words, *, n_blocks: int,
                   interpret: bool = False) -> jax.Array:
    """Decode all blocks: returns (n_blocks, 128) int32.

    ``words`` must be padded with >= MAX_WORDS trailing words so the last
    window never reads out of bounds.
    """
    return pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ENTRIES), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK_ENTRIES), jnp.int32),
        interpret=interpret,
    )(sb, widths, words)
