"""Pure-jnp oracles for the bitunpack kernel (gather-based, independent)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitunpack.kernel import BLOCK_ENTRIES


def unpack_hybrid_ref(sb: jax.Array, widths: jax.Array,
                      words: jax.Array) -> jax.Array:
    """(n_blocks, 128) int32 decode via absolute bit-offset gathers.

    Entry e of block k starts at bit  sb[k]*32 + e*widths[k]; widths divide
    32, so no entry straddles a word.
    """
    n_blocks = sb.shape[0]
    e = jnp.arange(BLOCK_ENTRIES, dtype=jnp.int32)[None, :]
    w = widths[:, None].astype(jnp.int32)
    bit = sb[:, None].astype(jnp.int32) * 32 + e * w
    word_idx = bit // 32
    off = bit % 32
    wvals = words.astype(jnp.uint32)[word_idx]
    shift = (32 - w - off).astype(jnp.uint32)
    mask = jax.lax.shift_left(jnp.uint32(1), w.astype(jnp.uint32)) - jnp.uint32(1)
    return (jax.lax.shift_right_logical(wvals, shift) & mask).astype(jnp.int32)


def unpack_rows_ref(words: jax.Array, sb: jax.Array,
                    widths: jax.Array) -> jax.Array:
    """Decode the rectangular row-wise packed slab: (B, KB*128) int32.

    ``words`` is (B, W) with each row's block payloads concatenated
    (zero-padded to W); ``sb``/``widths`` are (B, KB) word offsets *within
    the row* and per-block bit widths.  Pure gathers/shifts, so this is the
    decode the distributed backend runs inside shard_map (DESIGN.md §11).
    """
    B, KB = sb.shape
    e = jnp.arange(BLOCK_ENTRIES, dtype=jnp.int32)[None, None, :]
    w = widths[:, :, None].astype(jnp.int32)
    bit = sb[:, :, None].astype(jnp.int32) * 32 + e * w
    word_idx = (bit // 32).reshape(B, KB * BLOCK_ENTRIES)
    off = (bit % 32).reshape(B, KB * BLOCK_ENTRIES)
    wvals = jnp.take_along_axis(words.astype(jnp.uint32), word_idx, axis=1)
    wflat = w.reshape(B, KB, 1).astype(jnp.int32)
    wrep = jnp.broadcast_to(wflat, (B, KB, BLOCK_ENTRIES)
                            ).reshape(B, KB * BLOCK_ENTRIES)
    shift = (32 - wrep - off).astype(jnp.uint32)
    mask = jax.lax.shift_left(jnp.uint32(1),
                              wrep.astype(jnp.uint32)) - jnp.uint32(1)
    return (jax.lax.shift_right_logical(wvals, shift) & mask).astype(jnp.int32)
