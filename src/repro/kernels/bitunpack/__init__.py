from repro.kernels.bitunpack.ops import pack_hybrid, unpack_hybrid

__all__ = ["pack_hybrid", "unpack_hybrid"]
