"""Public API: build + query the two-level rank dictionary."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rank_popcount.kernel import BLK, block_popcounts, popcount_u32


def pack_bits_u32(bits: np.ndarray) -> np.ndarray:
    """0/1 array -> uint32 words (MSB-first), zero-padded to BLK words."""
    bits = np.asarray(bits, np.uint8)
    pad = (-len(bits)) % 32
    b = np.pad(bits, (0, pad))
    bytes_ = np.packbits(b)
    pad4 = (-len(bytes_)) % 4
    bytes_ = np.pad(bytes_, (0, pad4))
    words = bytes_.view(">u4").astype(np.uint32)
    padw = (-len(words)) % BLK
    return np.pad(words, (0, padw))


def build_rank_dictionary(bits: np.ndarray, interpret: Optional[bool] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Returns (words, cum): packed words + exclusive block prefix sums."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    words = pack_bits_u32(bits)
    pc = block_popcounts(jnp.asarray(words.view(np.int32)),
                         interpret=interpret)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(pc)])
    return jnp.asarray(words.view(np.int32)), cum


@jax.jit
def rank1_query(words: jax.Array, cum: jax.Array, idx: jax.Array) -> jax.Array:
    """Vectorised rank1 (ones in [0, idx)) using the dictionary."""
    w = idx // 32
    rem = idx % 32
    blk = w // BLK
    base = cum[blk]
    # ones in whole words [blk*BLK, w): segmented popcount via cumsum-free
    # gather of <= BLK words is wasteful; instead keep a per-word cumsum
    # fallback: popcount word prefix inside the block with a scan-free trick
    # — practical arrays are queried in bulk, so precompute word prefix:
    word_pc = popcount_u32(words)
    word_cum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                jnp.cumsum(word_pc)]).astype(jnp.int32)
    mid = word_cum[w] - word_cum[blk * BLK]
    word = words[w].astype(jnp.uint32)
    head = jnp.where(
        rem > 0,
        popcount_u32(jax.lax.shift_right_logical(
            word, (32 - rem).astype(jnp.uint32))),
        0)
    return base + mid + head
