"""Pure-jnp oracle for the rank dictionary kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rank_popcount.kernel import BLK


def popcount_u32_ref(x: jax.Array) -> jax.Array:
    """Bit-by-bit popcount (independent of the SWAR trick)."""
    x = x.astype(jnp.uint32)
    total = jnp.zeros_like(x, jnp.int32)
    for b in range(32):
        total = total + ((x >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
    return total


def block_popcounts_ref(words: jax.Array) -> jax.Array:
    n = words.shape[0]
    assert n % BLK == 0
    return popcount_u32_ref(words).reshape(n // BLK, BLK).sum(axis=1)


def rank1_query_ref(words: jax.Array, idx: jax.Array) -> jax.Array:
    """rank1 by full bit expansion (MSB-first per word) — oracle only."""
    w = words.astype(jnp.uint32)
    shifts = jnp.uint32(31) - jnp.arange(32, dtype=jnp.uint32)
    bits = ((w[:, None] >> shifts[None, :]) & jnp.uint32(1)).reshape(-1)
    cum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                           jnp.cumsum(bits.astype(jnp.int32))])
    return cum[idx]
