from repro.kernels.rank_popcount.ops import build_rank_dictionary, rank1_query

__all__ = ["build_rank_dictionary", "rank1_query"]
