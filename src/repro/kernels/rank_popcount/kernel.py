"""Rank-dictionary construction Pallas kernel.

The succinct tree's B_X bitmaps need O(1) rank1.  The dictionary is a
two-level structure: per-block popcount sums (this kernel) + an exclusive
prefix sum (host/XLA).  Popcount is SWAR bit arithmetic over uint32 lanes —
pure VPU work, one HBM pass over the packed words.

Grid: one step per block of BLK words; block popcounts reduce in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 256  # words per rank block (8192 bits)


def popcount_u32(x: jax.Array) -> jax.Array:
    """SWAR popcount over uint32 lanes."""
    x = x.astype(jnp.uint32)
    m1 = jnp.uint32(0x55555555)
    m2 = jnp.uint32(0x33333333)
    m4 = jnp.uint32(0x0F0F0F0F)
    x = x - ((x >> jnp.uint32(1)) & m1)
    x = (x & m2) + ((x >> jnp.uint32(2)) & m2)
    x = (x + (x >> jnp.uint32(4))) & m4
    return ((x * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _kernel(words_ref, out_ref):
    out_ref[0] = popcount_u32(words_ref[...]).sum()


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_popcounts(words: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(n_words,) int32 (uint32 view) -> (n_blocks,) int32 block popcounts.

    ``words`` must be zero-padded to a BLK multiple.
    """
    n = words.shape[0]
    assert n % BLK == 0, n
    return pl.pallas_call(
        _kernel,
        grid=(n // BLK,),
        in_specs=[pl.BlockSpec((BLK,), lambda k: (k,))],
        out_specs=pl.BlockSpec((1,), lambda k: (k,)),
        out_shape=jax.ShapeDtypeStruct((n // BLK,), jnp.int32),
        interpret=interpret,
    )(words)
