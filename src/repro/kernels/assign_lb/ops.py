"""Host-side paths and wrappers for the assignment lower bound
(DESIGN.md §16): the vectorised numpy reference, the optional tighter
Hungarian relaxation, per-graph feature extraction, and the padded
entry point around the Pallas kernel.

All three backends (numpy / jax / pallas) compute the same integers —
the bound is provable, so candidate *verification decisions* derived
from it are bit-identical everywhere.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.kernels.qgram_filter.ops import on_tpu, shape_bucket

# shape-bucket ladders for the (Q, N) LB pass — queries are tiny, the
# candidate-union axis tracks the filter's B ladder
Q_BASE, Q_CAP = 8, 64
N_BASE, N_CAP = 8, 512
VM_BASE, VM_CAP = 8, 128


def _pairwise_c2(qv: np.ndarray, qd: np.ndarray, qeh: np.ndarray,
                 dv: np.ndarray, dd: np.ndarray, deh: np.ndarray
                 ) -> np.ndarray:
    """(..., VMq, VM) doubled branch-edit costs for one query block row
    against one database block (numpy, broadcast over leading axes)."""
    lbl = 2 * (qv[..., :, None] != dv[..., None, :]).astype(np.int64)
    dmax = np.maximum(qd[..., :, None], dd[..., None, :])
    inter = np.minimum(qeh[..., :, None, :],
                       deh[..., None, :, :]).sum(axis=-1)
    return lbl + dmax - inter


def assign_lb_np(qv, qd, qeh, qn, dv, dd, deh, dn) -> np.ndarray:
    """(Q, N) int32 Hausdorff branch lower bounds — numpy reference with
    the exact contract of ``ref.batched_assign_lb_ref``."""
    qv, qd, qeh = (np.asarray(x) for x in (qv, qd, qeh))
    dv, dd, deh = (np.asarray(x) for x in (dv, dd, deh))
    qn = np.asarray(qn, np.int64)
    dn = np.asarray(dn, np.int64)
    Q, VMq = qv.shape
    N, VM = dv.shape
    out = np.empty((Q, N), np.int32)
    vmask = np.arange(VM)[None, :] < dn[:, None]          # (N, VM)
    for r in range(Q):
        # query row (1, VMq, ...) broadcast against the db block (N, VM, ...)
        c2 = _pairwise_c2(qv[r][None, :], qd[r][None, :], qeh[r][None, :, :],
                          dv, dd, deh)                    # (N, VMq, VM)
        rowmin = np.minimum(c2.min(axis=2), (2 + qd[r])[None, :])
        rowsum = rowmin[:, :int(qn[r])].sum(axis=1)       # (N,)
        colmin = np.minimum(c2.min(axis=1), 2 + dd)       # (N, VM)
        colsum = np.where(vmask, colmin, 0).sum(axis=1)
        out[r] = (np.maximum(rowsum, colsum) + 1) // 2
    return out


def hungarian_lb_pair(qv, qd, qeh, dv, dd, deh) -> Optional[int]:
    """Exact assignment LB for one (query, graph) pair of *unpadded*
    branch features: ``ceil(min-cost-assignment(C2) / 2)``.  Tighter than
    (never below) the Hausdorff relaxation, still ``<= GED``.  Returns
    None when scipy is unavailable — callers keep the Hausdorff value.
    """
    try:
        from scipy.optimize import linear_sum_assignment
    except ImportError:                                   # pragma: no cover
        return None
    n1, n2 = len(qd), len(dd)
    if n1 == 0 and n2 == 0:
        return 0
    big = np.int64(1) << 30
    c = np.full((n1 + n2, n1 + n2), big, np.int64)
    if n1 and n2:
        c[:n1, :n2] = _pairwise_c2(qv, qd, qeh, dv, dd, deh)
    c[np.arange(n1), n2 + np.arange(n1)] = 2 + np.asarray(qd, np.int64)
    c[n1 + np.arange(n2), np.arange(n2)] = 2 + np.asarray(dd, np.int64)
    c[n1:, n2:] = 0
    r, col = linear_sum_assignment(c)
    return int((int(c[r, col].sum()) + 1) // 2)


def graph_branch_features(g, n_elabels: int, vmax: Optional[int] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unbatched per-vertex branch features of one graph:
    ``(vlab (vm,), deg (vm,), ehist (vm, NE))`` padded to ``vmax``."""
    from repro.core.slab import branch_features
    vm = max(int(g.n) if vmax is None else int(vmax), 1)
    vlab, deg, eh = branch_features([g], n_elabels, vm)
    return vlab[0], deg[0], eh[0]


def _pad_rows(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    pad = n - x.shape[0]
    if pad <= 0:
        return x
    w = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, w, constant_values=fill)


def _pad_cols(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    pad = n - x.shape[1]
    if pad <= 0:
        return x
    w = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
    return np.pad(x, w, constant_values=fill)


def pad_query_block(qv, qd, qeh, qn, vmq: Optional[int] = None
                    ) -> Tuple[np.ndarray, ...]:
    """Pad a stacked query block to the (Q, VMq) shape buckets: Q rides
    the power-of-2 ladder (rows repeat the last real query — harmless,
    sliced off), VMq likewise (pad vertices price as ε).  Keeping both on
    ladders is what keeps the jit/pallas retrace count bounded."""
    Q = qv.shape[0]
    qp = shape_bucket(max(Q, 1), Q_BASE, Q_CAP)
    vm = shape_bucket(max(qv.shape[1], 1) if vmq is None else int(vmq),
                      VM_BASE, VM_CAP)
    qv = _pad_cols(_pad_rows(np.asarray(qv, np.int32), qp, -1), vm, -1)
    qd = _pad_cols(_pad_rows(np.asarray(qd, np.int32), qp), vm)
    qeh = _pad_cols(_pad_rows(np.asarray(qeh, np.int32), qp), vm)
    qn = _pad_rows(np.asarray(qn, np.int32), qp)
    return qv, qd, qeh, qn


def assign_lb_bounds_batched(qv, qd, qeh, qn, dv, dd, deh, dn, *,
                             qb: int = 8, bb: int = 128,
                             interpret: Optional[bool] = None):
    """Tile-aligned Pallas launch: (Q, N) int32 LBs.  Shapes must already
    be padded (``pad_query_block`` / the slab gather's ``n_pad``);
    ``interpret`` defaults to off-TPU."""
    from repro.kernels.assign_lb.kernel import assign_lb_call
    if interpret is None:
        interpret = not on_tpu()
    return assign_lb_call(qv, qd, qeh, qn, dv, dd, deh, dn,
                          qb=qb, bb=bb, interpret=interpret)
