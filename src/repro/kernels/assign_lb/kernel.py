"""Pallas kernel for the batched Hausdorff branch lower bound
(DESIGN.md §16).

One (qb, bb) tile prices every query-vertex / db-vertex branch pair of
its block and reduces straight to the per-pair LB — a pure min-reduce,
no cross-tile accumulation, so the grid is just (Q/QB, N/BB) and the
kernel needs no scratch.

The db-side branch operands (labels, degrees, incident edge-label
histograms) are the device-resident slab arrays; the query block rides a
leading Q axis like the fused filter kernel (§13).  True query vertex
counts arrive as an SMEM (QB, 1) scalar block; db vertex counts as a
(BB, 1) VMEM column.  Pad vertices price exactly as the ε column (the
``branch_features`` padding contract), so only the two sums mask.

The static Python loop over the query-vertex axis keeps every
intermediate at rank 3 — (QB, BB, VM) — which the TPU vector unit
handles natively; VMq is shape-bucketed (ops.VM_BASE ladder) so the
unroll count stays bounded per compiled program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_SCALARS = 1                 # per-query scalar block: [true vertex count]


def _lb_kernel(scalars_ref,   # SMEM (QB, 1) int32: query vertex counts
               qv_ref,        # (QB, VMq) int32 query vertex labels (pad -1)
               qd_ref,        # (QB, VMq) int32 query degrees (pad 0)
               qeh_ref,       # (QB, VMq, NE) int32 incident-label hists
               dv_ref,        # (BB, VM) int32 db vertex labels (pad -1)
               dd_ref,        # (BB, VM) int32 db degrees (pad 0)
               deh_ref,       # (BB, VM, NE) int32 db incident-label hists
               dn_ref,        # (BB, 1) int32 db vertex counts
               lb_ref):       # (QB, BB) int32 out
    QB, VMq = qv_ref.shape
    dv = dv_ref[...]
    dd = dd_ref[...]
    deh = deh_ref[...]
    BB, VM = dv.shape
    NE = deh.shape[2]

    # per-query scalar column as a (QB, 1) vector; SMEM reads stay
    # scalar (TPU-safe), QB is static so the stack unrolls
    qn = jnp.stack([scalars_ref[r, 0] for r in range(QB)])[:, None]

    rowsum = jnp.zeros((QB, BB), jnp.int32)
    colmin = jnp.broadcast_to((2 + dd)[None, :, :], (QB, BB, VM))
    for u in range(VMq):
        lbl = 2 * (qv_ref[:, u][:, None, None] != dv[None, :, :]
                   ).astype(jnp.int32)
        dmax = jnp.maximum(qd_ref[:, u][:, None, None], dd[None, :, :])
        inter = jnp.zeros((QB, BB, VM), jnp.int32)
        for e in range(NE):
            inter += jnp.minimum(qeh_ref[:, u, e][:, None, None],
                                 deh[None, :, :, e])
        c2 = lbl + dmax - inter                           # (QB, BB, VM)
        rmin = jnp.minimum(c2.min(axis=2),
                           (2 + qd_ref[:, u])[:, None])   # (QB, BB)
        rowsum += jnp.where(u < qn, rmin, 0)
        colmin = jnp.minimum(colmin, c2)

    dn = dn_ref[...][:, 0]                                # (BB,)
    vvalid = (jax.lax.broadcasted_iota(jnp.int32, (BB, VM), 1)
              < dn[:, None])
    colsum = jnp.where(vvalid[None, :, :], colmin, 0).sum(axis=2)
    lb2 = jnp.maximum(rowsum, colsum)
    lb_ref[...] = ((lb2 + 1) // 2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("qb", "bb", "interpret"))
def assign_lb_call(qv, qd, qeh, qn, dv, dd, deh, dn, *, qb: int = 8,
                   bb: int = 128, interpret: bool = False):
    """Raw pallas_call; shapes must already be tile-aligned.

    qv/qd (Q, VMq); qeh (Q, VMq, NE); qn (Q,); dv/dd (N, VM);
    deh (N, VM, NE); dn (N,).  Returns (Q, N) int32 LBs.
    """
    Q, VMq = qv.shape
    N, VM = dv.shape
    NE = deh.shape[2]
    assert Q % qb == 0 and N % bb == 0, (Q, N, qb, bb)
    scalars = jnp.asarray(qn, jnp.int32).reshape(Q, N_SCALARS)
    dn2 = jnp.asarray(dn, jnp.int32).reshape(N, 1)
    grid = (Q // qb, N // bb)
    return pl.pallas_call(
        _lb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qb, N_SCALARS), lambda q, i: (q, 0),
                         memory_space=pltpu.SMEM),               # scalars
            pl.BlockSpec((qb, VMq), lambda q, i: (q, 0)),        # qv
            pl.BlockSpec((qb, VMq), lambda q, i: (q, 0)),        # qd
            pl.BlockSpec((qb, VMq, NE), lambda q, i: (q, 0, 0)),  # qeh
            pl.BlockSpec((bb, VM), lambda q, i: (i, 0)),         # dv
            pl.BlockSpec((bb, VM), lambda q, i: (i, 0)),         # dd
            pl.BlockSpec((bb, VM, NE), lambda q, i: (i, 0, 0)),  # deh
            pl.BlockSpec((bb, 1), lambda q, i: (i, 0)),          # dn
        ],
        out_specs=pl.BlockSpec((qb, bb), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(scalars, qv, qd, qeh, dv, dd, deh, dn2)
