"""Tile autotuner for the assignment-LB kernel (DESIGN.md §16) —
the (qb, bb) analogue of ``kernels.qgram_filter.autotune``.

The LB kernel has no reduction axis to tile (the whole min-reduce fits
one (qb, bb) tile), so the sweep is over query-block and candidate-block
sizes only.  Tables persist to ``artifacts/tune/assign_lb.json`` with
the same provenance rules: ``timed_on`` recorded per entry, a
CPU-interpret sweep never clobbers a TPU-timed one, and a missing table
falls back to the built-in defaults.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

DEFAULT_TILES: Tuple[int, int] = (8, 128)
DEFAULT_PATH = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "..",
    "artifacts", "tune", "assign_lb.json"))

QB_CANDIDATES = (4, 8, 16)
BB_CANDIDATES = (64, 128, 256)


def canonical_shape(Q: int, N: int, VMq: int, VM: int
                    ) -> Tuple[int, int, int, int]:
    """The shape-bucket key a (Q, N, VMq, VM) launch resolves to."""
    from repro.kernels.assign_lb import ops
    from repro.kernels.qgram_filter.ops import shape_bucket
    return (shape_bucket(Q, ops.Q_BASE, ops.Q_CAP),
            shape_bucket(N, ops.N_BASE, ops.N_CAP),
            shape_bucket(VMq, ops.VM_BASE, ops.VM_CAP), int(VM))


def _key(shape: Sequence[int]) -> str:
    return "x".join(str(int(s)) for s in shape)


class TileTable:
    """Shape-bucket -> (qb, bb) lookup with a default fallback."""

    def __init__(self, entries: Optional[Dict[str, Sequence[int]]] = None,
                 default: Tuple[int, int] = DEFAULT_TILES,
                 timed_on: str = ""):
        self.entries: Dict[str, Tuple[int, int]] = {
            k: tuple(int(x) for x in v) for k, v in (entries or {}).items()}
        self.default = tuple(int(x) for x in default)
        self.timed_on = timed_on

    def lookup(self, Q: int, N: int, VMq: int, VM: int) -> Tuple[int, int]:
        qb, bb = self.entries.get(_key(canonical_shape(Q, N, VMq, VM)),
                                  self.default)
        # the padded launch shapes always divide by a clamped tile
        return (min(qb, Q), min(bb, N))

    def __len__(self) -> int:
        return len(self.entries)


@functools.lru_cache(maxsize=8)
def load_tile_table(path: Optional[str] = None) -> TileTable:
    path = DEFAULT_PATH if path is None else path
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        entries = {k: v["tiles"] for k, v in doc.get("entries", {}).items()}
        return TileTable(entries, timed_on=doc.get("timed_on", ""))
    except (OSError, ValueError, KeyError, TypeError):
        return TileTable()


def default_table() -> TileTable:
    return load_tile_table(None)


def _synth_operands(rng, Q, N, VMq, VM, NE):
    import jax.numpy as jnp
    arr = lambda *s: jnp.asarray(rng.integers(0, 4, s).astype(np.int32))
    qn = rng.integers(1, VMq + 1, Q).astype(np.int32)
    dn = rng.integers(1, VM + 1, N).astype(np.int32)
    return (arr(Q, VMq), arr(Q, VMq), arr(Q, VMq, NE), jnp.asarray(qn),
            arr(N, VM), arr(N, VM), arr(N, VM, NE), jnp.asarray(dn))


def _time_tiles(args, qb, bb, interpret: bool, repeats: int) -> float:
    from repro.kernels.assign_lb.kernel import assign_lb_call
    run = lambda: assign_lb_call(*args, qb=qb, bb=bb, interpret=interpret)
    run().block_until_ready()                      # compile / warm
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(shapes: Iterable[Tuple[int, int, int, int]], *, ne: int = 3,
          candidates: Optional[Iterable[Tuple[int, int]]] = None,
          repeats: int = 3, interpret: Optional[bool] = None,
          max_interpret_n: int = 512, seed: int = 0,
          verbose: bool = False) -> Dict[str, Dict]:
    """Time every candidate tile on every canonical (Q, N, VMq, VM)
    shape; return {shape key: {"tiles": best, "us": ..., "swept": n}}."""
    from repro.kernels.qgram_filter.ops import on_tpu
    if interpret is None:
        interpret = not on_tpu()
    if candidates is None:
        candidates = [(qb, bb) for qb in QB_CANDIDATES
                      for bb in BB_CANDIDATES]
    rng = np.random.default_rng(seed)
    out: Dict[str, Dict] = {}
    for shape in shapes:
        Q, N, VMq, VM = canonical_shape(*shape)
        key = _key((Q, N, VMq, VM))
        if key in out:
            continue
        N_t = min(N, max_interpret_n) if interpret else N
        args = _synth_operands(rng, Q, N_t, VMq, VM, ne)
        best, best_t = DEFAULT_TILES, np.inf
        seen = set()
        for qb, bb in candidates:
            eff = (min(qb, Q), min(bb, N_t))
            if eff in seen:
                continue
            seen.add(eff)
            t = _time_tiles(args, *eff, interpret=interpret,
                            repeats=repeats)
            if verbose:
                print(f"  {key} tiles={eff}: {t * 1e6:.0f}us")
            if t < best_t:
                best, best_t = eff, t
        out[key] = {"tiles": list(best), "us": best_t * 1e6,
                    "swept": len(seen)}
        if N_t != N:
            out[key]["timed_n"] = N_t
        if verbose:
            print(f"{key} -> {best} ({best_t * 1e6:.0f}us)")
    return out


def save_table(results: Dict[str, Dict],
               path: Optional[str] = DEFAULT_PATH) -> TileTable:
    """Merge sweep results into the persisted table (same provenance
    rules as the filter-kernel table: TPU entries are never downgraded
    by a CPU-interpret sweep)."""
    import jax
    backend = jax.default_backend()
    doc = {"version": 1, "timed_on": backend, "entries": {}}
    if path is not None and os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                old = json.load(f)
            doc["entries"] = old.get("entries", {})
            for k, v in doc["entries"].items():
                v.setdefault("timed_on", old.get("timed_on", ""))
        except (OSError, ValueError):
            pass
    for k, v in results.items():
        have = doc["entries"].get(k)
        if (have is not None and have.get("timed_on") == "tpu"
                and backend != "tpu"):
            continue
        doc["entries"][k] = {**v, "timed_on": backend}
    if any(v.get("timed_on") == "tpu" for v in doc["entries"].values()):
        doc["timed_on"] = "tpu"
    if path is not None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        load_tile_table.cache_clear()
    return TileTable({k: v["tiles"] for k, v in doc["entries"].items()},
                     timed_on=doc["timed_on"])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q", type=int, nargs="+", default=[8],
                    help="query-block sizes to tune for")
    ap.add_argument("--n", type=int, nargs="+", default=[128, 512],
                    help="candidate-union sizes to tune for")
    ap.add_argument("--vmq", type=int, default=32)
    ap.add_argument("--vm", type=int, default=32)
    ap.add_argument("--ne", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=DEFAULT_PATH)
    args = ap.parse_args()
    shapes = [(q, n, args.vmq, args.vm) for q in args.q for n in args.n]
    table = save_table(sweep(shapes, ne=args.ne, repeats=args.repeats,
                             verbose=True), args.out)
    print(f"{len(table)} shape buckets tuned "
          f"(timed on {table.timed_on}) -> {args.out}")


if __name__ == "__main__":
    main()
