"""Batched branch-assignment GED lower bounds (DESIGN.md §16)."""
