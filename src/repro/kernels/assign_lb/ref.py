"""jax.numpy reference for the batched assignment lower bound — the
oracle the Pallas kernel is tested against, and the body the jit'd jax
backend path runs (DESIGN.md §16).

The bound (BRANCH family, à la EmbAssi / Nass): every vertex carries a
*branch* — its label plus the multiset of incident edge labels.  With
doubled integer costs

  C2(u, v) = 2·[l(u) != l(v)] + max(d(u), d(v)) - sum_e min(EH_u[e], EH_v[e])
  C2(u, ε) = 2 + d(u)          C2(ε, v) = 2 + d(v)

the optimal assignment of query branches to database branches (ε =
insert/delete) satisfies ``ceil(assignment(C2) / 2) <= GED``.  The
Hausdorff relaxation drops the one-to-one constraint: every row (and
every column) of *any* assignment dominates its own min, so

  LB2 = max( sum_u min_{v ∪ ε} C2(u, v),  sum_v min_{u ∪ ε} C2(u, v) )
  LB  = (LB2 + 1) // 2  <=  assignment LB  <=  GED

which is exactly a batched min-reduce — the shape the device wants.

Padding contract (``core.slab.branch_features``): pad vertices carry
label -1 / degree 0 / zero histograms, so a real-vs-pad pair prices
exactly as the ε column (2 + degree) and the min axes need no masking;
only the two *sums* mask by the true vertex counts ``qn`` / ``dn``.
"""
from __future__ import annotations

import jax.numpy as jnp


def batched_assign_lb_ref(qv, qd, qeh, qn, dv, dd, deh, dn):
    """(Q, N) int32 Hausdorff branch lower bounds.

    qv/qd (Q, VMq) int32, qeh (Q, VMq, NE) int32, qn (Q,) int32 true
    query vertex counts; dv/dd (N, VM), deh (N, VM, NE), dn (N,) the
    database side.  Pads as per the module docstring.
    """
    Q, VMq = qv.shape
    N, VM = dv.shape
    lbl = 2 * (qv[:, None, :, None] != dv[None, :, None, :]).astype(jnp.int32)
    dmax = jnp.maximum(qd[:, None, :, None], dd[None, :, None, :])
    inter = jnp.minimum(qeh[:, None, :, None, :],
                        deh[None, :, None, :, :]).sum(axis=4)
    c2 = lbl + dmax - inter                               # (Q, N, VMq, VM)

    rowmin = jnp.minimum(c2.min(axis=3), (2 + qd)[:, None, :])
    umask = jnp.arange(VMq)[None, :] < qn[:, None]        # (Q, VMq)
    rowsum = jnp.where(umask[:, None, :], rowmin, 0).sum(axis=2)

    colmin = jnp.minimum(c2.min(axis=2), (2 + dd)[None, :, :])
    vmask = jnp.arange(VM)[None, :] < dn[:, None]         # (N, VM)
    colsum = jnp.where(vmask[None, :, :], colmin, 0).sum(axis=2)

    lb2 = jnp.maximum(rowsum, colsum)
    return ((lb2 + 1) // 2).astype(jnp.int32)
