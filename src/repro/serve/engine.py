"""Batched serving engine: prefill + decode over the structured caches.

Continuous-batching-lite: requests are grouped into fixed-size decode
batches; each slot tracks its own position; finished slots are refilled
from the queue.  The decode step is a single jitted program regardless of
per-slot progress (positions are data, not shapes).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig
from repro.obs import Observability, StatsView


@dataclass
class Request:
    prompt: np.ndarray               # (P,) int32 token ids
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 obs: Optional[Observability] = None):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
        # same registry idiom as the graph engines (DESIGN.md §17): the
        # legacy dict becomes a live view over ``obs.metrics``, keys and
        # ``+=`` semantics unchanged
        self.obs = obs if obs is not None else Observability(spans=False)
        self.stats: StatsView = self.obs.metrics.view(
            "lm", initial={"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0})

    def _prefill_one(self, cache, slot: int, prompt: np.ndarray):
        """Prefill by stepping tokens through the decode path for this slot.

        (Single-slot prefill keeps cache layouts identical between phases;
        a production deployment prefers the chunked forward prefill — see
        examples/serve_requests.py for the batched-forward variant.)
        """
        t0 = time.perf_counter()
        for i, tok in enumerate(prompt[:-1]):
            token = jnp.full((self.B, 1), 0, jnp.int32).at[slot, 0].set(int(tok))
            _, cache = self._decode(self.params, token, cache, jnp.int32(i))
        self.stats["prefill_s"] += time.perf_counter() - t0
        return cache

    def run(self, requests: List[Request]) -> List[Request]:
        """Simplified same-length batching: groups requests whose prompts
        share a length, decodes greedily."""
        queue = list(requests)
        while queue:
            group = [queue.pop(0) for _ in range(min(self.B, len(queue)))]
            self._run_group(group)
        return requests

    def _run_group(self, group: List[Request]) -> None:
        cfg = self.cfg
        B = self.B
        plen = max(len(r.prompt) for r in group)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(group):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        # batched prefill via full forward, then switch to decode
        t0 = time.perf_counter()
        cache = init_cache(cfg, B, self.max_len)
        logits = None
        for i in range(plen):
            tok = jnp.asarray(prompts[:, i:i + 1])
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(i))
        self.stats["prefill_s"] += time.perf_counter() - t0
        pos = plen
        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in group)
        cur = jnp.argmax(logits[:, -1], axis=-1)
        for step in range(max_new):
            for i, r in enumerate(group):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(cur[i]))
            if pos + 1 >= self.max_len:
                break
            logits, cache = self._decode(self.params, cur[:, None].astype(jnp.int32),
                                         cache, jnp.int32(pos))
            cur = jnp.argmax(logits[:, -1], axis=-1)
            pos += 1
            self.stats["tokens"] += len(group)
        self.stats["decode_s"] += time.perf_counter() - t0
        for r in group:
            r.done = True
