"""Deterministic, seeded fault injection for the serving stack.

The serving pipeline (DESIGN.md §18) threads named injection points
through its stages; a ``FaultInjector`` installed on the pipeline fires
at those points according to declarative ``FaultSpec``s — raise on the
Nth call, add a latency spike, kill a process-pool worker, or fail a
device op.  Call counting is per-point and the worker-kill victim is
chosen with a seeded RNG, so a given (spec, seed, trace) triple replays
the same fault schedule every run: chaos tests and the ``--faults``
bench mode assert exact outcomes against it.

Standing injection points (grep for ``_fire(`` / ``.fire(``):

==================  =====================================================
point               fires
==================  =====================================================
``admit``           per ticket, on admission into the async inbox
``filter.batch``    per formed batch, before the device filter stage
``device.filter``   inside ``BatchedFilterEval`` device dispatch
``device.decode``   inside the packed/hot slab decode path
``device.cache``    inside ``DeviceSlabCache.get_or_build`` builds
``verify.slice``    per verification slice, before the A* run
``verify.pool``     before each process-pool dispatch (worker kill)
==================  =====================================================

The injector is duck-typed at the call sites (``faults.fire(point)``),
so ``repro.core`` modules never import this package; ``None`` disables
injection with zero hot-path cost.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Raised by a ``raise``/``device`` fault; carries its point."""

    def __init__(self, point: str, call_index: int, tag: str = "") -> None:
        super().__init__(f"injected fault at {point!r} (call #{call_index})")
        self.point = point
        self.call_index = call_index
        self.tag = tag
        if tag == "decode":
            # the slab ladder keys decode attribution off this flag
            self.slab_decode = True


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault schedule at a named injection point.

    ``on_calls`` fires at explicit 1-based call indices; ``every``
    fires at every Nth call — both respect ``times`` (max fires,
    ``None`` = unbounded).  ``kind``:

    * ``"raise"``       — raise :class:`InjectedFault` at the site
    * ``"delay"``       — sleep ``delay_s`` (latency spike), then return
    * ``"kill_worker"`` — SIGKILL one live process-pool worker (the
      site passes ``pool=``; victim picked by the injector's seeded RNG)
    """

    point: str
    kind: str = "raise"
    on_calls: Tuple[int, ...] = ()
    every: int = 0
    times: Optional[int] = None
    delay_s: float = 0.0
    tag: str = ""

    def __post_init__(self) -> None:
        if self.kind not in ("raise", "delay", "kill_worker"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not self.on_calls and not self.every:
            raise ValueError("FaultSpec needs on_calls or every")

    def matches(self, call_index: int) -> bool:
        if call_index in self.on_calls:
            return True
        return bool(self.every) and call_index % self.every == 0


@dataclass
class _Armed:
    spec: FaultSpec
    fires: int = 0

    def due(self, call_index: int) -> bool:
        if self.spec.times is not None and self.fires >= self.spec.times:
            return False
        return self.spec.matches(call_index)


@dataclass
class FireEvent:
    """One fault firing, recorded for assertions and bench rows."""

    point: str
    call_index: int
    kind: str
    detail: str = ""


class FaultInjector:
    """Thread-safe registry of armed fault specs + per-point counters."""

    def __init__(self, specs: Sequence[FaultSpec] = (),
                 seed: int = 0) -> None:
        self._lock = threading.Lock()
        self._rng = random.Random(seed)
        self._armed: List[_Armed] = [_Armed(s) for s in specs]
        self.calls: Dict[str, int] = {}
        self.fired: List[FireEvent] = []

    def add(self, spec: FaultSpec) -> "FaultInjector":
        with self._lock:
            self._armed.append(_Armed(spec))
        return self

    # ------------------------------------------------------------------
    def fire(self, point: str, **ctx: Any) -> None:
        """Count a pass through ``point``; act on any due spec.

        Raise faults propagate an :class:`InjectedFault` out of the
        call site; delay/kill faults act and return.  One call can fire
        at most one raise fault (after any delay/kill faults)."""
        with self._lock:
            idx = self.calls.get(point, 0) + 1
            self.calls[point] = idx
            due = [a for a in self._armed
                   if a.spec.point == point and a.due(idx)]
            for a in due:
                a.fires += 1
            events = [FireEvent(point, idx, a.spec.kind) for a in due]
            self.fired.extend(events)
            kill_rng = self._rng.random() if any(
                a.spec.kind == "kill_worker" for a in due) else 0.0
        to_raise: Optional[InjectedFault] = None
        for a, ev in zip(due, events):
            spec = a.spec
            if spec.kind == "delay":
                time.sleep(spec.delay_s)
            elif spec.kind == "kill_worker":
                ev.detail = self._kill_worker(ctx.get("pool"), kill_rng)
            elif to_raise is None:
                to_raise = InjectedFault(point, idx, tag=spec.tag)
        if to_raise is not None:
            raise to_raise

    @staticmethod
    def _kill_worker(pool: Any, pick: float) -> str:
        # the spawn pool starts workers lazily, so a kill scheduled on an
        # early call can land before any worker exists — wait out warmup
        # (bounded) so a scheduled kill deterministically kills
        procs: List[Any] = []
        for _ in range(100):
            procs = [p for p in getattr(pool, "_processes", {}).values()
                     if p.is_alive()]
            if procs:
                break
            time.sleep(0.02)
        if not procs:
            return "no-live-worker"
        victim = procs[int(pick * len(procs)) % len(procs)]
        victim.kill()
        victim.join(timeout=10.0)
        return f"killed pid {victim.pid}"

    # ------------------------------------------------------------------
    def count(self, point: str) -> int:
        with self._lock:
            return self.calls.get(point, 0)

    def fired_at(self, point: str) -> List[FireEvent]:
        with self._lock:
            return [e for e in self.fired if e.point == point]

    def summary(self) -> Dict[str, Any]:
        """Bench-row payload: calls seen and faults fired per point."""
        with self._lock:
            fires: Dict[str, int] = {}
            for e in self.fired:
                fires[f"{e.point}:{e.kind}"] = \
                    fires.get(f"{e.point}:{e.kind}", 0) + 1
            return {"calls": dict(self.calls), "fired": fires,
                    "n_fired": len(self.fired)}

    def reset(self) -> None:
        with self._lock:
            self.calls.clear()
            self.fired.clear()
            for a in self._armed:
                a.fires = 0
