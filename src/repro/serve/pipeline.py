"""Async pipelined serving: overlap device filtering with the host GED
worklist and stream matches cheapest-first (DESIGN.md §12).

``GraphQueryEngine.submit`` is strictly serial: the device sits idle while
host A* drains the verification worklist, and callers see nothing until
the whole batch completes — yet verification dominates end-to-end time on
every benchmarked config.  ``AsyncGraphQueryEngine`` decomposes serving
into pipelined stages, each on its own thread(s), none blocking another:

    submit() ──► admission inbox ──► dynamic batch former (size/deadline)
             ──► device filter pass (the wrapped engine's stages 1-3: any
                 backend / FilterSlab layout / ShardedGraphQueryEngine's
                 shard_map path)  [one admission+filter thread]
             ──► shared VerifyScheduler worklist (cheapest filter bound
                 first, budgeted/resumable A*)  [N verifier threads]
             ──► per-query QueryTicket futures + incremental match streams

While the verifier pool drains batch k's worklist, the filter thread is
already running batch k+1's device pass.  With no deadlines, a completed
ticket's result is **bit-identical** to ``engine.submit`` (same
candidates, same matches): the filter path and the A* are shared code and
match *sets* don't depend on worker count or completion order — only the
timing stats differ.  Per-query deadlines produce recall-safe partials:
candidates are never truncated, unverified pairs are counted and the
result is flagged ``partial`` (DESIGN.md §12).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.search import QueryResult
from repro.obs import use_obs
from repro.serve.errors import (AdmissionError, FilterStageError,
                                QueryError)
from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                      TopKState, VerifyScheduler)

_DONE = object()                     # stream sentinel


def _ticket_nbytes(r: GraphQuery) -> int:
    """Rough inbox footprint of one queued request: the query graph's
    arrays (vlabels + edge endpoints/labels at int64) plus fixed ticket
    overhead — an admission-accounting bound, not a measurement."""
    g = r.graph
    # defensive: a malformed request (g=None) must still admit and fail
    # *typed* at the filter stage, not blow up the submitter
    n = int(getattr(g, "n", 0) or 0)
    m = int(getattr(g, "m", 0) or 0)
    return 96 + 8 * (n + 3 * m)


class QueryTicket:
    """Per-query future plus an incremental match stream."""

    def __init__(self, request: GraphQuery):
        self.request = request
        self._events: "queue.Queue" = queue.Queue()
        self._done = threading.Event()
        # _result/_error are published under _lock by _resolve and only
        # read after _done is set (or inside _lock) — the Event is the
        # memory barrier, so they carry no guarded_by annotation
        self._result: Optional[QueryResult] = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._resolved = False            # guarded_by: self._lock
        self._callbacks: List = []        # guarded_by: self._lock
        self._streamed_live = False
        # observability context (engine-internal, DESIGN.md §17):
        # _t_submit pins the root query span's start, _t_enq the current
        # batch-former entry (reset on top-k re-entry), _queue_s the
        # accumulated former wait across rounds, _qid the engine query id
        self._t_submit: Optional[float] = None
        self._t_enq: Optional[float] = None
        self._queue_s = 0.0
        self._qid: Optional[int] = None
        # top-k escalation context (engine-internal, DESIGN.md §15): the
        # ticket re-enters the batch former once per widened-τ round, so
        # its state/encoding ride along instead of being recomputed
        self._topk: Optional[TopKState] = None
        self._topk_counted = False
        self._topk_key = None
        self._topk_qt = None
        # admission accounting (DESIGN.md §18): estimated inbox bytes,
        # stamped at submit and released when the batch former pops it
        self._nbytes = 0

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        """Block until the query completes (its last candidate pair is
        verified, expired, or it resolved from cache).  Re-raises the
        pipeline-stage exception if this query's batch failed."""
        if not self._done.wait(timeout):
            raise TimeoutError("query still in the pipeline past timeout")
        if self._error is not None:
            raise self._error
        return self._result  # type: ignore[return-value]

    def stream(self, timeout: Optional[float] = None
               ) -> Iterator[Tuple[int, int]]:
        """Yield ``(graph id, ged)`` matches as A* confirms them —
        cheapest filter bound first, before the query completes.  Ends
        when the query resolves; ``timeout`` bounds each wait
        (``TimeoutError``, same contract as ``result``)."""
        while True:
            try:
                ev = self._events.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    "no match or completion within timeout") from None
            if ev is _DONE:
                if self._error is not None:
                    raise self._error
                return
            yield ev

    def add_done_callback(self, fn) -> None:
        """``fn(result)`` on the resolving thread (immediately if done;
        ``result`` is None when the query's batch failed)."""
        self._add_callback(lambda res, err: fn(res))

    # ---- resolution (engine-internal) --------------------------------------
    def _add_callback(self, fn) -> None:
        with self._lock:
            if not self._resolved:
                self._callbacks.append(fn)
                return
        fn(self._result, self._error)

    def _push_match(self, gid: int, d: int) -> None:
        self._streamed_live = True
        self._events.put((gid, d))

    def _resolve(self, result: Optional[QueryResult],
                 error: Optional[BaseException] = None) -> bool:
        """First resolution wins (idempotent — a failed batch's blanket
        error resolution must not fight a scheduler completion)."""
        with self._lock:
            if self._resolved:
                return False
            self._resolved = True
            self._result = result
            self._error = error
            callbacks, self._callbacks = self._callbacks, []
        if error is None and not self._streamed_live:
            # cache hit / alias / verify=False: stream the final matches
            for m in result.matches:
                self._events.put(tuple(m))
        self._events.put(_DONE)
        self._done.set()
        for fn in callbacks:
            try:
                fn(result, error)
            except Exception:        # lint: disable=SRV001
                pass                 # a raising user callback must not
                                     # kill the delivering verifier
                                     # thread (the ticket is already
                                     # resolved by this point)
        return True


def as_completed(tickets: Sequence[QueryTicket],
                 timeout: Optional[float] = None
                 ) -> Iterator[Tuple[int, QueryResult]]:
    """Yield ``(index, result)`` in completion order (earliest-finished
    first — typically the cheapest worklists).  ``timeout`` bounds each
    wait (``TimeoutError``); a failed ticket re-raises its error when
    reached."""
    q: "queue.Queue" = queue.Queue()
    for idx, t in enumerate(tickets):
        t._add_callback(lambda res, err, i=idx: q.put((i, res, err)))
    for _ in tickets:
        try:
            i, res, err = q.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError(
                "no query completed within timeout") from None
        if err is not None:
            raise err
        yield i, res


class AsyncGraphQueryEngine:
    """Pipelined front-end over a ``GraphQueryEngine`` (incl. the sharded
    engine): request queue, dynamic batch former, device filter stage,
    verifier worker pool, streaming delivery (DESIGN.md §12).

    The wrapped engine supplies the source, backend, FilterSlab layout,
    and both LRU caches — the async path reuses its ``_admit`` /
    ``_batched_candidates`` / ``_assemble`` stages verbatim, which is what
    makes the no-deadline bit-identical invariant hold by construction.
    Don't call ``engine.submit`` concurrently with an open pipeline; wrap
    it instead.

    * ``max_batch`` / ``max_delay_s``: admission — a batch forms when
      ``max_batch`` requests are waiting or the oldest has waited
      ``max_delay_s``, whichever is first.
    * ``num_workers``: verifier threads draining the shared worklist.
    * ``verify_executor``: ``"thread"`` (default) runs A* slices on the
      verifier threads; ``"process"`` offloads each slice to the
      scheduler's ``ProcessPoolExecutor`` (``num_workers`` processes) so
      GED verification stops sharing the GIL with the numpy filter pass
      — bit-identical results either way (DESIGN.md §12).
    * ``slice_expansions``: A* timeslice (heap pops) per worklist run;
      undecided searches re-queue at their improved frontier bound.
    * ``default_deadline_s``: verification deadline applied to requests
      that don't carry their own ``deadline_s``.
    * ``record_intervals``: collect per-stage (start, end) busy spans in
      ``filter_intervals`` / ``verify_intervals`` for overlap accounting
      (``benchmarks/query_throughput.py --pipeline``).
    * ``inbox_limit`` / ``inbox_bytes``: admission control (DESIGN.md
      §18) — the inbox is bounded by queued tickets and/or estimated
      bytes; an arrival past either bound triggers ``shed_policy``:
      ``"reject"`` resolves the *new* ticket with ``AdmissionError``,
      ``"shed_oldest"`` evicts the oldest queued ticket of the most
      over-weight tenant (per ``tenant_weights``, default weight 1.0)
      and admits the arrival.  Rejections are fast typed outcomes, never
      hangs; in-flight top-k escalation rounds bypass the bound (they
      re-enter, they are not new load).
    * ``faults``: a ``serve.faults.FaultInjector`` threaded through every
      stage's injection points (defaults to the wrapped engine's).
    """

    def __init__(self, engine: GraphQueryEngine, *, max_batch: int = 32,
                 max_delay_s: float = 0.005, num_workers: int = 2,
                 verify_executor: str = "thread",
                 slice_expansions: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 record_intervals: bool = False, name: str = "apipe",
                 inbox_limit: Optional[int] = None,
                 inbox_bytes: Optional[int] = None,
                 shed_policy: str = "reject",
                 tenant_weights: Optional[Dict[str, float]] = None,
                 faults=None):
        if shed_policy not in ("reject", "shed_oldest"):
            raise ValueError(f"unknown shed_policy {shed_policy!r} "
                             "(reject | shed_oldest)")
        self.engine = engine
        self.max_batch = max(1, int(max_batch))
        self.max_delay_s = float(max_delay_s)
        self.default_deadline_s = default_deadline_s
        self.inbox_limit = None if inbox_limit is None else int(inbox_limit)
        self.inbox_bytes = None if inbox_bytes is None else int(inbox_bytes)
        self.shed_policy = shed_policy
        self.tenant_weights = dict(tenant_weights or {})
        # one injector for the whole pipeline: the engine threads it to
        # the filter evaluator, the scheduler to the verify points
        self.faults = faults if faults is not None else engine.faults
        engine.faults = self.faults
        self.filter_intervals: List[Tuple[float, float]] = []
        self.verify_intervals: List[Tuple[float, float]] = []
        self.obs = engine.obs           # one ring/registry per pipeline
        self.scheduler = VerifyScheduler(
            engine.source.db, slice_expansions=slice_expansions,
            interval_sink=self.verify_intervals if record_intervals else None,
            # map the thread alias; anything unknown reaches the
            # scheduler's own validation instead of silently degrading
            executor={"thread": "inline"}.get(verify_executor,
                                              verify_executor),
            workers=num_workers, obs=engine.obs, faults=self.faults)
        self._record_intervals = record_intervals
        self._cv = threading.Condition()
        self._inbox: "deque[Tuple[float, QueryTicket]]" = \
            deque()                 # guarded_by: self._cv
        self._inbox_nbytes = 0      # guarded_by: self._cv
        # admission counters + high-water marks, merged into ``stats``
        self.pstats = engine.obs.metrics.view("pipe", initial={
            "rejected": 0, "shed": 0, "inbox_hwm": 0,
            "inbox_bytes_hwm": 0})  # guarded_by: self._cv
        self._outstanding = 0       # guarded_by: self._cv
        self._topk_pending = 0      # guarded_by: self._cv
        self._closing = False       # guarded_by: self._cv
        self._closed = False        # guarded_by: self._cv
        self._filter_thread = threading.Thread(
            target=self._filter_loop, name=f"{name}-filter", daemon=True)
        self._workers = [
            threading.Thread(target=self.scheduler.worker_loop,
                             name=f"{name}-verify-{w}", daemon=True)
            for w in range(max(1, int(num_workers)))]
        self._filter_thread.start()
        for w in self._workers:
            w.start()

    # ---- submission --------------------------------------------------------
    def submit(self, request: GraphQuery) -> QueryTicket:
        return self.submit_many([request])[0]

    def submit_many(self, requests: Sequence[GraphQuery]
                    ) -> List[QueryTicket]:
        """Admit requests into the bounded inbox.  Over capacity, the
        configured ``shed_policy`` fires per arrival: rejected arrivals
        and shed victims resolve immediately with ``AdmissionError`` —
        a fast typed outcome, never a queued-forever ticket."""
        tickets = [QueryTicket(r) for r in requests]
        now = time.perf_counter()
        rejected: List[QueryTicket] = []
        shed: List[QueryTicket] = []
        failed: List[Tuple[QueryTicket, AdmissionError]] = []
        admitting = tickets
        if self.faults is not None:
            # the ``admit`` point fires outside _cv (a delay fault must
            # not stall concurrent submitters); a raise fails only the
            # struck ticket, typed, before it ever occupies the inbox
            admitting = []
            for t in tickets:
                try:
                    self.faults.fire("admit", tenant=t.request.tenant)
                    admitting.append(t)
                except Exception as e:  # noqa: BLE001 — typed containment
                    failed.append((t, AdmissionError(
                        f"admission fault: {e!r}",
                        tenant=t.request.tenant, cause=e)))
        with self._cv:
            if self._closing:
                raise RuntimeError("AsyncGraphQueryEngine is closed")
            for t in admitting:
                t._t_submit = t._t_enq = now
                t._nbytes = _ticket_nbytes(t.request)
                if self._over_locked(t._nbytes) \
                        and self.shed_policy == "shed_oldest":
                    while self._over_locked(t._nbytes):
                        victim = self._pick_victim_locked()
                        if victim is None:
                            break
                        shed.append(victim)
                        self.pstats["shed"] += 1
                if self._over_locked(t._nbytes):
                    self.pstats["rejected"] += 1
                    rejected.append(t)
                    continue
                self._inbox.append((now, t))
                self._inbox_nbytes += t._nbytes
                self._outstanding += 1
                if len(self._inbox) > self.pstats["inbox_hwm"]:
                    self.pstats["inbox_hwm"] = len(self._inbox)
                if self._inbox_nbytes > self.pstats["inbox_bytes_hwm"]:
                    self.pstats["inbox_bytes_hwm"] = self._inbox_nbytes
            self._cv.notify_all()
        # resolutions run outside _cv: _resolve takes the ticket lock and
        # fires user callbacks — never under the pipeline lock
        for t, err in failed:
            t._resolve(None, err)
        for t in rejected:
            t._resolve(None, AdmissionError(
                "inbox full: arrival rejected under overload",
                policy=self.shed_policy, tenant=t.request.tenant))
        for t in shed:
            # victims were admitted earlier (outstanding): _finish keeps
            # drain()/close() accounting exact
            self._finish(t, None, AdmissionError(
                "shed from inbox under overload", policy="shed_oldest",
                tenant=t.request.tenant, shed=True))
        return tickets

    def _over_locked(self, nbytes: int) -> bool:    # guarded_by: self._cv
        """Would admitting ``nbytes`` more exceed a bound?  An empty inbox
        always admits (one oversized request must proceed, not livelock)."""
        if not self._inbox:
            return False
        if self.inbox_limit is not None \
                and len(self._inbox) >= self.inbox_limit:
            return True
        return (self.inbox_bytes is not None
                and self._inbox_nbytes + nbytes > self.inbox_bytes)

    def _pick_victim_locked(self    # guarded_by: self._cv
                            ) -> Optional[QueryTicket]:
        """Evict the oldest queued ticket of the most over-weight tenant
        (queued count / tenant weight, ties by tenant name).  In-flight
        top-k rounds are never victims — shedding a half-escalated query
        would strand its worklist accounting."""
        occ: Dict[Optional[str], int] = {}
        for _, t in self._inbox:
            if t._topk is None:
                ten = t.request.tenant
                occ[ten] = occ.get(ten, 0) + 1
        if not occ:
            return None
        victim_tenant = max(
            occ, key=lambda ten: (occ[ten] / max(
                self.tenant_weights.get(ten, 1.0), 1e-9), str(ten)))
        for i, (_, t) in enumerate(self._inbox):
            if t._topk is None and t.request.tenant == victim_tenant:
                del self._inbox[i]
                self._inbox_nbytes -= t._nbytes
                return t
        return None

    # ---- lifecycle ---------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted query has resolved."""
        end = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while self._outstanding > 0:
                left = None if end is None else end - time.perf_counter()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"{self._outstanding} queries still in flight")
                self._cv.wait(left)

    def close(self, timeout: float = 60.0) -> None:
        """Stop admission, drain in-flight work, stop every thread.  Even
        when the drain times out, the scheduler is closed and workers are
        joined (``finally``) so a wedged pipeline never parks verifier
        threads forever; ``close`` stays retryable until every thread has
        actually exited."""
        with self._cv:
            if self._closed:
                return
            self._closing = True
            self._cv.notify_all()
        try:
            self._filter_thread.join(timeout)
            self.drain(timeout)
        finally:
            self.scheduler.close()   # workers exit once the heap is empty
            for w in self._workers:
                w.join(timeout)
            closed = not any(
                t.is_alive() for t in [self._filter_thread, *self._workers])
            with self._cv:
                self._closed = closed
            # tear the pool down even on a timed-out close: a wedged
            # worker's later dispatch falls back to in-process slices
            # (never wrong), whereas a leaked spawn pool lives forever
            self.scheduler.shutdown(wait=closed)

    def __enter__(self) -> "AsyncGraphQueryEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def stats(self) -> dict:
        """Wrapped-engine counters plus the shared worklist's.  Each side
        is copied under its own lock — sequentially, never nested, so no
        lock-order edge between the pipeline and the scheduler exists."""
        with self._cv:
            s = dict(self.engine.stats)
            s.update(dict(self.pstats))
        s.update(self.scheduler.stats_snapshot())
        return s

    # ---- stage: dynamic batch former + device filter -----------------------
    def _filter_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._process_batch(batch)
            except Exception as e:      # noqa: BLE001 — stage containment
                # a failed admission/filter pass must not kill the filter
                # thread (that would hang every future ticket): fail this
                # batch's unresolved tickets with a *typed* error and keep
                # going — other batches and in-flight queries are untouched
                err = e if isinstance(e, QueryError) else FilterStageError(
                    f"filter stage failed: {e!r}", cause=e)
                for t in batch:
                    self._finish(t, None, err)

    def _next_batch(self) -> Optional[List[QueryTicket]]:
        """Size/deadline admission: wait for ``max_batch`` requests or an
        oldest-request age of ``max_delay_s`` (close flushes what's left)."""
        with self._cv:
            while True:
                if self._inbox:
                    age = time.perf_counter() - self._inbox[0][0]
                    if (len(self._inbox) >= self.max_batch
                            or age >= self.max_delay_s or self._closing):
                        n = min(len(self._inbox), self.max_batch)
                        out = []
                        for _ in range(n):
                            _, t = self._inbox.popleft()
                            self._inbox_nbytes -= t._nbytes
                            out.append(t)
                        return out
                    self._cv.wait(self.max_delay_s - age)
                elif self._closing:
                    if self._topk_pending == 0:
                        return None
                    # in-flight top-k queries may still re-enter for a
                    # wider-τ round — the filter stage must outlive them
                    self._cv.wait()
                else:
                    self._cv.wait()

    def _process_batch(self, tickets: List[QueryTicket]) -> None:
        eng = self.engine
        if self.faults is not None:
            # per-batch injection point: a raise here fails exactly this
            # batch's tickets via _filter_loop's containment
            self.faults.fire("filter.batch", n=len(tickets))
        spans_on = eng.obs.spans.enabled
        # batch-former wait becomes a visible queue span (DESIGN.md §17):
        # submission (or top-k re-entry) -> this batch picking the ticket
        t_formed = time.perf_counter()
        for t in tickets:
            if t._t_enq is not None:
                t._queue_s += t_formed - t._t_enq
                if spans_on and t._qid is not None:   # top-k re-entry
                    eng.obs.spans.record("queue", t._t_enq, t_formed,
                                         qid=t._qid)
                t._t_enq = None
        # a re-entered top-k ticket is already admitted (cache checked,
        # encoding cached, state attached): it only needs its next filter
        # round at the widened τ, batched with fresh arrivals
        reenter = [t for t in tickets if t._topk is not None]
        new = [t for t in tickets if t._topk is None]
        # rows: (ticket, request, filter τ, qtuple, key, top-k state)
        rows: List[tuple] = []
        # the wrapped engine's counters are shared with _on_done (verifier
        # threads) and the stats property — mutate them under _cv only
        with self._cv:
            eng.stats["batches"] += 1
            eng.stats["queries"] += len(new)
        if new:
            requests = [t.request for t in new]
            results, fresh, aliases, keys, qtuples, qids = \
                eng._admit(requests)
            for i, t in enumerate(new):
                t._qid = qids[i]
            if spans_on:
                for t in new:
                    eng.obs.spans.record("queue", t._t_submit, t_formed,
                                         qid=t._qid)
            # cache hits resolve immediately — no pipeline latency at all
            for i, res in enumerate(results):
                if res is not None:
                    self._finish(new[i], res)
            # in-batch duplicates follow their source ticket (errors incl.)
            for i, src in aliases:
                new[src]._add_callback(
                    lambda res, err, t=new[i]: self._finish(t, res, err))
            now = time.perf_counter()
            for i in fresh:
                r, t = requests[i], new[i]
                if r.top_k is not None:
                    dl_s = (r.deadline_s if r.deadline_s is not None
                            else self.default_deadline_s)
                    st = TopKState(
                        int(r.top_k), int(r.tau),
                        None if dl_s is None else now + float(dl_s))
                    t._topk = st
                    t._topk_key = keys[i]
                    t._topk_qt = qtuples[i]
                    with self._cv:
                        self._topk_pending += 1
                        t._topk_counted = True
                    rows.append((t, r, st.tau, qtuples[i], keys[i], st))
                else:
                    rows.append((t, r, int(r.tau), qtuples[i], keys[i],
                                 None))
        for t in reenter:
            rows.append((t, t.request, t._topk.tau, t._topk_qt,
                         t._topk_key, t._topk))
        if not rows:
            return

        graphs = [r.graph for _, r, _, _, _, _ in rows]
        taus = [tau for _, _, tau, _, _, _ in rows]
        t0 = time.perf_counter()
        with use_obs(eng.obs):
            batch = eng._batched_candidates(
                graphs, taus, [qt for _, _, _, qt, _, _ in rows])
        t1 = time.perf_counter()
        with self._cv:
            eng.stats["filter_s"] += t1 - t0
        if spans_on:
            eng.obs.spans.record("filter", t0, t1, rows=len(rows),
                                 backend=eng.backend)
        if self._record_intervals:
            self.filter_intervals.append((t0, t1))

        n_db = len(eng.source.db)
        per_q_filter = (t1 - t0) / len(rows)
        now = time.perf_counter()
        for row, (ticket, r, tau, _qt, key, st) in enumerate(rows):
            cand = batch.ids[row]
            lb_share = eng._job_lb_share(batch, row)
            with self._cv:
                eng.stats["lb_s"] += lb_share
            if st is not None:
                st.rounds += 1
                st.filter_s += per_q_filter
                st.lb_s += lb_share
                with self._cv:
                    eng.stats["topk_rounds"] += 1
                bounds = eng._job_bounds(batch, row)
                lbs = eng._job_lbs(batch, row)
                keep = [c for c, g in enumerate(cand)
                        if int(g) not in st.seen]
                new_ids = [int(cand[c]) for c in keep]
                st.seen.update(new_ids)   # lb-pruned gids stay "seen":
                # decided (GED >= lb > cap), never resubmitted (§16)
                w_ids, w_bounds, n_pr, n_tt = eng._merge_lb(
                    new_ids, [bounds[c] for c in keep],
                    None if lbs is None else [int(lbs[c]) for c in keep],
                    st.cap)
                # pairs run at the query CAP, not the round τ: decisions
                # stay final, frontiers stay resumable in the shared heap
                # across escalation rounds (DESIGN.md §15)
                self.scheduler.add_job(
                    r.graph, st.cap, w_ids, w_bounds, deadline=st.deadline,
                    token=(ticket, key, r, st),
                    on_match=self._on_topk_match,
                    on_done=self._on_topk_round_done,
                    should_skip=st.should_skip,
                    n_lb_pruned=n_pr, n_lb_tightened=n_tt,
                    qid=ticket._qid)
                continue
            if not r.verify:
                res = eng._assemble(cand, None, n_db, per_q_filter,
                                    lb_s=lb_share)
                res.stats["queue_s"] = ticket._queue_s
                eng._cache_result(key, r, res)
                self._finish(ticket, res)
                continue
            dl_s = (r.deadline_s if r.deadline_s is not None
                    else self.default_deadline_s)
            deadline = None if dl_s is None else now + float(dl_s)
            # candidate list in the token stays the *full* row — the
            # stage-1.5 LB prunes verification work, never recall (§16)
            w_ids, w_bounds, n_pr, n_tt = eng._merge_lb(
                cand, eng._job_bounds(batch, row),
                eng._job_lbs(batch, row), tau)
            self.scheduler.add_job(
                r.graph, tau, w_ids, w_bounds, deadline=deadline,
                token=(ticket, key, r, cand, n_db, per_q_filter, lb_share),
                on_match=self._on_match, on_done=self._on_done,
                n_lb_pruned=n_pr, n_lb_tightened=n_tt, qid=ticket._qid)

    # ---- stage: top-k escalation (runs on verifier threads) ----------------
    def _reenter(self, ticket: QueryTicket) -> None:
        """Queue a top-k query's next widened-τ filter round.  Bypasses
        ``submit_many``: escalation of an in-flight query must proceed
        even while admission is closing (close() waits for it)."""
        now = time.perf_counter()
        with self._cv:
            ticket._t_enq = now        # next round's queue-wait starts now
            self._inbox.append((now, ticket))
            self._inbox_nbytes += ticket._nbytes
            self._cv.notify_all()

    def _on_topk_match(self, job, gid: int, d: int) -> None:
        # matches feed the state (so should_skip prunes live), not the
        # ticket stream: only the final k-best may be streamed, and those
        # are known only at resolution
        job.token[3].record_match(gid, d)

    def _on_topk_round_done(self, job) -> None:
        """One escalation round drained: finish the query (satisfied /
        deadline) or widen τ and re-enter the batch former."""
        ticket, key, request, st = job.token
        eng = self.engine
        try:
            st.absorb_round(job)
            if eng.obs.spans.enabled:
                eng.obs.spans.record("topk_round", job.t_enq,
                                     time.perf_counter(), qid=ticket._qid,
                                     tau=st.tau, round=st.rounds)
            with self._cv:
                eng.stats["verify_s"] += job.verify_s
            if st.unverified or (st.deadline is not None
                                 and time.perf_counter() >= st.deadline):
                st.deadline_hit = True
            if st.deadline_hit or st.satisfied():
                res = eng._assemble_topk(st, len(eng.source.db))
                res.stats["queue_s"] = ticket._queue_s
                # deadline partials are never cached (DESIGN.md §15)
                if not (st.unverified or st.deadline_hit):
                    eng._cache_result(key, request, res)
                self._finish(ticket, res)
            else:
                st.escalate()
                self._reenter(ticket)
        except Exception as e:       # noqa: BLE001 — resolve, don't kill
            self._finish(ticket, None, e)

    # ---- stage: delivery (runs on verifier threads) ------------------------
    def _on_match(self, job, gid: int, d: int) -> None:
        job.token[0]._push_match(gid, d)

    def _on_done(self, job) -> None:
        ticket, key, request, cand, n_db, per_q_filter, lb_share = job.token
        eng = self.engine
        try:
            res = eng._assemble(cand, job, n_db, per_q_filter,
                                lb_s=lb_share)
            # queue time is per-*ticket*, stamped before caching so the
            # cached entry never carries another query's wait (replays
            # zero it regardless — DESIGN.md §17)
            res.stats["queue_s"] = ticket._queue_s
            with self._cv:
                eng.stats["verify_s"] += job.verify_s
            if not job.unverified:   # deadline partials are never cached
                eng._cache_result(key, request, res)
        except Exception as e:       # noqa: BLE001 — resolve, don't kill
            self._finish(ticket, None, e)
            return
        self._finish(ticket, res)

    def _finish(self, ticket: QueryTicket, res: Optional[QueryResult],
                error: Optional[BaseException] = None) -> None:
        if not ticket._resolve(res, error):
            return                       # already resolved — keep accounting
        obs = self.engine.obs
        if obs.spans.enabled and ticket._qid is not None \
                and ticket._t_submit is not None \
                and not (res is not None and res.stats.get("cache_hit")):
            # the async root span: submission -> resolution (cache hits
            # already got theirs from _admit, zero-length by design)
            obs.spans.record(
                "query", ticket._t_submit, time.perf_counter(),
                qid=ticket._qid, error=int(error is not None),
                partial=int(bool(res is not None
                                 and res.stats.get("partial"))))
        with self._cv:
            self._outstanding -= 1
            if ticket._topk_counted:     # escalation over — release close()
                ticket._topk_counted = False
                self._topk_pending -= 1
            self._cv.notify_all()
