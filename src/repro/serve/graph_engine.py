"""GraphQueryEngine: batched multi-query graph similarity serving.

Answers a batch of (query graph, tau) requests over any ``CandidateSource``
(tree-backed ``MSQIndex`` or flat ``FlatMSQIndex``) in four stages
(DESIGN.md §10):

  1. **bucket** queries by reduced query region
     (``core.engine.bucket_queries``) so each region's graphs are gathered
     once per batch,
  2. **shard** each bucket's slab: single-host backends gather it into one
     padded block; ``ShardedGraphQueryEngine`` block-partitions it over
     the mesh and replicates the padded query block,
  3. **filter**: the leaf-level cascade per bucket
     (``core.engine.BatchedFilterEval`` — jax / numpy / pallas backends on
     one host; the ``distributed`` backend runs it inside shard_map per
     device and all-gathers fixed-size top-k candidate blocks),
  4. **worklist**: candidate blocks from all queries drain into one shared
     ``VerifyScheduler`` — a cheapest-candidate-first priority worklist
     through ``ged_upto`` (low filter bounds are both likelier matches and
     cheaper A* runs, so early results stream out first).  ``submit``
     drains it inline, the one-worker special case;
     ``serve.pipeline.AsyncGraphQueryEngine`` runs a verifier pool against
     the same scheduler and overlaps stage 4 with the next batch's filter
     pass (DESIGN.md §12).

Repeat queries hit two LRU caches: query *encodings* (the q-gram
``QueryTuple``, reusable across taus) and whole *results* (exact
(graph, tau, verify) hits, replayed with ``cache_hit`` tagged in stats and
the stale timings zeroed).  The single-query ``query()`` is a thin
wrapper over a one-element batch.
"""
from __future__ import annotations

import heapq
import inspect
import itertools
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import (Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.core.engine import CandidateSource, resolve_backend
from repro.core.search import QueryResult
from repro.core.tree import QueryTuple
from repro.core.verify import GEDSearch
from repro.graphs.graph import Graph
from repro.obs import MetricsRegistry, Observability, StatsView, use_obs
from repro.obs.health import StageHealth


class _PoolBroken(Exception):
    """Internal: the process pool died under this slice.  The search
    state is untouched (the pool round-trips a *copy*), so the caller
    re-enqueues the pair at its current frontier instead of retiring
    it — raised and caught inside this module only."""


@dataclass
class GraphQuery:
    """One similarity-search request.  ``deadline_s`` (seconds, relative
    to worklist admission) bounds verification: expired candidate pairs
    are skipped and the result is flagged ``partial`` in stats — recall
    safe, because the candidate list is never truncated (DESIGN.md §12).

    ``top_k`` switches the query modality from range-τ to k-nearest
    (DESIGN.md §15): the result's ``matches`` are the ``top_k`` graphs
    with the smallest ``(ged, gid)`` among all graphs with ged <= ``tau``
    (``tau`` becomes the search *cap*, bounding the NP-hard verification),
    sorted by ``(ged, gid)`` ascending.  Answered by adaptive-τ
    escalation: the filter cascade runs at a cheap τ first and re-enters
    at a widened τ until the kth-best confirmed distance proves no wider
    τ can help — never recomputing a decided (query, gid) pair."""

    graph: Graph
    tau: int
    verify: bool = True
    deadline_s: Optional[float] = None
    top_k: Optional[int] = None
    # admission-control identity (DESIGN.md §18): the async pipeline's
    # shed-oldest policy picks victims by per-tenant weighted occupancy;
    # None = the anonymous tenant.  Ignored by the sync path and by
    # caching (tenancy never changes an answer).
    tenant: Optional[str] = None

    def __post_init__(self):
        if self.top_k is not None:
            if int(self.top_k) < 1:
                raise ValueError("top_k must be >= 1")
            if not self.verify:
                raise ValueError(
                    "top_k requires verify=True: ranking needs exact GEDs, "
                    "filter lower bounds alone cannot order the k-nearest")


def _graph_key(g: Graph) -> bytes:
    """Content key for the caches (exact array equality, not isomorphism)."""
    e = np.asarray(g.edges, np.int64).reshape(-1)
    return b"|".join((np.asarray(g.vlabels, np.int64).tobytes(),
                      e.tobytes(),
                      np.asarray(g.elabels, np.int64).tobytes()))


def _approx_nbytes(obj) -> int:
    """Rough resident-byte estimate for cache accounting (DESIGN.md §18):
    numpy arrays by ``nbytes``, containers by recursive walk, scalars at
    CPython ballpark.  An accounting bound for eviction decisions, not a
    ``sys.getsizeof`` ground truth — both cached types (``QueryTuple``,
    ``QueryResult``) are flat bundles of arrays/lists, so the walk is
    shallow and cycle-free."""
    if obj is None:
        return 0
    if isinstance(obj, np.ndarray):
        return int(obj.nbytes) + 96
    if isinstance(obj, (bytes, bytearray)):
        return len(obj) + 33
    if isinstance(obj, str):
        return len(obj) + 49
    if isinstance(obj, (int, float, bool)):
        return 28
    if isinstance(obj, (list, tuple, set, frozenset)):
        return 56 + 8 * len(obj) + sum(_approx_nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return 64 + sum(_approx_nbytes(k) + _approx_nbytes(v)
                        for k, v in obj.items())
    d = getattr(obj, "__dict__", None)
    if d is not None:
        return 64 + _approx_nbytes(d)
    slots = getattr(type(obj), "__slots__", ())
    return 64 + sum(_approx_nbytes(getattr(obj, s, None)) for s in slots)


class _LRU:
    """Tiny LRU with a lock: the async pipeline reads from its admission
    thread while verifier workers publish finished results.

    Bounded by entry count and — when ``max_bytes``/``sizeof`` are given —
    by estimated resident bytes, whichever trips first, so a burst of
    huge graphs cannot balloon the cache past its memory budget
    (DESIGN.md §18).  High-water marks are tracked here and exported by
    the owning engine's registry; ``on_hwm`` (if set) is invoked with
    ``(bytes_hwm, entries_hwm)`` *outside* the lock after a put that
    raised either mark."""

    def __init__(self, maxsize: int, max_bytes: Optional[int] = None,
                 sizeof: Optional[Callable] = None,
                 on_hwm: Optional[Callable] = None):
        self.maxsize = maxsize
        self.max_bytes = max_bytes
        self._sizeof = sizeof
        self._on_hwm = on_hwm
        self._lock = threading.Lock()
        self._d: OrderedDict = OrderedDict()    # guarded_by: self._lock
        self._sizes: Dict = {}                  # guarded_by: self._lock
        self._bytes = 0                         # guarded_by: self._lock
        self.bytes_hwm = 0                      # guarded_by: self._lock
        self.entries_hwm = 0                    # guarded_by: self._lock
        self.hits = 0                           # guarded_by: self._lock
        self.misses = 0                         # guarded_by: self._lock

    def get(self, key):
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def _evict_locked(self, key) -> None:    # guarded_by: self._lock
        del self._d[key]
        self._bytes -= self._sizes.pop(key, 0)

    def put(self, key, value) -> None:
        sz = 0
        if self._sizeof is not None:
            sz = int(self._sizeof(value))   # size outside any eviction path
        hwm = None
        with self._lock:
            if key in self._d:
                self._bytes -= self._sizes.pop(key, 0)
            self._d[key] = value
            self._d.move_to_end(key)
            self._sizes[key] = sz
            self._bytes += sz
            while len(self._d) > self.maxsize:
                self._evict_locked(next(iter(self._d)))
            if self.max_bytes is not None:
                # may evict down to empty: one over-budget value still
                # never holds more than itself, and it ages out next put
                while self._bytes > self.max_bytes and len(self._d) > 1:
                    self._evict_locked(next(iter(self._d)))
            raised = False
            if self._bytes > self.bytes_hwm:
                self.bytes_hwm = self._bytes
                raised = True
            if len(self._d) > self.entries_hwm:
                self.entries_hwm = len(self._d)
                raised = True
            if raised and self._on_hwm is not None:
                hwm = (self.bytes_hwm, self.entries_hwm)
        if hwm is not None:
            # registry publish happens outside self._lock (lock ordering:
            # never hold a cache lock across the metrics registry's)
            self._on_hwm(*hwm)

    def usage(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._d), "bytes": self._bytes,
                    "bytes_hwm": self.bytes_hwm,
                    "entries_hwm": self.entries_hwm}


class VerifyJob:
    """One query's verification context on the shared worklist."""

    __slots__ = ("graph", "tau", "deadline", "remaining", "matches",
                 "verify_s", "unverified", "pruned", "should_skip",
                 "on_match", "on_done", "token", "qid", "t_enq")

    def __init__(self, graph: Graph, tau: int, deadline: Optional[float],
                 token=None, on_match=None, on_done=None, should_skip=None,
                 qid: Optional[int] = None):
        self.graph = graph
        self.tau = int(tau)
        self.deadline = deadline
        self.remaining = 0
        self.matches: List[Tuple[int, int]] = []
        self.verify_s = 0.0
        self.unverified = 0
        self.pruned = 0
        self.should_skip = should_skip
        self.on_match = on_match
        self.on_done = on_done
        self.token = token
        self.qid = qid                  # engine query id (span correlation)
        self.t_enq = time.perf_counter()


class TopKState:
    """Per-query adaptive-τ escalation state for ``top_k`` queries
    (DESIGN.md §15).

    The filter τ starts cheap (0) and widens each round — jumping
    straight to the kth-best confirmed distance once k matches exist —
    while every admitted (query, gid) pair runs its ``GEDSearch`` at the
    query's *cap*, never the round τ.  A round-τ cutoff would poison the
    frontier for later rounds (children pruned at ``cost > τ_r`` are
    unrecoverable), so the cap cutoff is what keeps decisions final and
    frontiers resumable across escalation: ``seen`` gids are never
    resubmitted, which is the no-recompute invariant the scheduler stats
    assert in tests.

    ``confirmed`` is fed live from verifier threads (``record_match``)
    so the worklist's ``should_skip`` hook prunes pairs that can no
    longer displace the current kth-best — sound regardless of timing,
    because a pair with ``(bound, gid)`` lexicographically above the kth
    confirmed ``(ged, gid)`` can never enter the answer set."""

    __slots__ = ("k", "cap", "tau", "deadline", "rounds", "seen",
                 "confirmed", "filter_s", "lb_s", "verify_s", "unverified",
                 "pruned", "deadline_hit", "_lock")

    def __init__(self, k: int, cap: int, deadline: Optional[float] = None):
        self.k = int(k)
        self.cap = int(cap)
        self.tau = 0                    # round τ (filter admission only)
        self.deadline = deadline
        self.rounds = 0
        self.seen: set = set()          # gids ever submitted to the worklist
        self.confirmed: Dict[int, int] = {}     # guarded_by: self._lock
        self.filter_s = 0.0
        self.lb_s = 0.0
        self.verify_s = 0.0
        self.unverified = 0
        self.pruned = 0
        self.deadline_hit = False
        self._lock = threading.Lock()

    def record_match(self, gid: int, d: int) -> None:
        with self._lock:
            self.confirmed[int(gid)] = int(d)

    def kth(self) -> Optional[Tuple[int, int]]:
        """The current kth-best confirmed ``(ged, gid)``, or None while
        fewer than k matches are confirmed."""
        with self._lock:
            if len(self.confirmed) < self.k:
                return None
            return sorted((d, g)
                          for g, d in self.confirmed.items())[self.k - 1]

    def should_skip(self, gid: int, bound: int) -> bool:
        """Worklist pruning hook: a pair whose (lower bound, gid) already
        exceeds the kth-best confirmed (ged, gid) can never enter the
        top-k (its final ged >= bound), so running it is wasted A*."""
        kth = self.kth()
        return kth is not None and (int(bound), int(gid)) > kth

    def topk_matches(self) -> List[Tuple[int, int]]:
        """The k smallest confirmed ``(ged, gid)``, as (gid, ged) tuples
        sorted by (ged, gid) ascending — the deterministic tie rule."""
        with self._lock:
            best = sorted((d, g)
                          for g, d in self.confirmed.items())[:self.k]
        return [(g, d) for d, g in best]

    def absorb_round(self, job: VerifyJob) -> None:
        """Fold one drained round's accounting into the query state (the
        match set itself arrives live via ``record_match``)."""
        self.verify_s += job.verify_s
        self.unverified += job.unverified
        self.pruned += job.pruned

    def satisfied(self) -> bool:
        """True when no wider τ can change the answer: the kth-best
        confirmed distance is covered by the τ the filter already ran at
        (every graph with a smaller (ged, gid) had a lower bound <= its
        ged <= d_k <= τ, so it was admitted and decided), or the cap has
        been reached with every candidate decided."""
        if self.tau >= self.cap:
            return True
        kth = self.kth()
        return kth is not None and kth[0] <= self.tau

    def escalate(self) -> None:
        """Widen the filter τ for the next round: geometric growth while
        fewer than k matches are confirmed, else one adaptive jump to the
        kth-best distance (the round that proves optimality)."""
        kth = self.kth()
        if kth is not None:
            self.tau = min(self.cap, max(int(kth[0]), self.tau + 1))
        else:
            self.tau = min(self.cap, max(1, 2 * self.tau))


class VerifyScheduler:
    """Stage 4: the shared cheapest-first GED worklist (DESIGN.md §12).

    One priority heap of ``(bound, seq, job, gid, search)`` items across
    every in-flight query.  ``GraphQueryEngine.submit`` drains it inline
    on the calling thread — the one-worker special case — while
    ``AsyncGraphQueryEngine`` runs N verifier threads against the same
    pop/run loop, so both paths share ordering, deadline handling and
    accounting.

    Per-pair A* runs are budgeted (``slice_expansions``) and *resumable*:
    an undecided ``GEDSearch`` is re-pushed at its improved frontier bound
    (``min_f``), which keeps the heap honestly cheapest-first as bounds
    tighten and lets many expensive pairs timeslice one worker.  A pair
    popped (or interrupted) past its job's deadline is counted
    ``unverified`` instead of run — the caller flags the query partial,
    never drops candidates.

    ``executor="process"`` offloads each A* slice to a
    ``ProcessPoolExecutor`` of ``workers`` processes
    (``core.verify.run_search_slice`` over the picklable ``GEDSearch``),
    so verification stops sharing the GIL with the numpy filter pass —
    the ROADMAP's process-pool item.  Pop order, resume semantics, and
    deadline handling are unchanged (the slice is a pure function of the
    search state), so results stay bit-identical to the thread/inline
    executor.  Call ``shutdown()`` once no more pairs will run; the pool
    must outlive every draining worker, so ``close()`` deliberately does
    not touch it.
    """

    # every counter pre-initialized (no conditional ``.get`` defaults in
    # the hot loop, and snapshot keys are stable for the engine's fold)
    STAT_KEYS = ("verified_pairs", "expired_pairs", "resumed_runs",
                 "lb_pruned", "lb_tightened", "pruned_pairs",
                 "pool_fallbacks", "pool_rebuilds", "error_pairs")

    def __init__(self, db, slice_expansions: Optional[int] = None,
                 interval_sink: Optional[List[Tuple[float, float]]] = None,
                 executor: str = "inline", workers: int = 1,
                 obs: Optional[Observability] = None, faults=None,
                 dispatch_retries: int = 2, max_pool_rebuilds: int = 2):
        if executor not in ("inline", "thread", "process"):
            raise ValueError(f"unknown executor {executor!r} "
                             "(inline | thread | process)")
        self.db = db
        # spans go to the owning engine's ring; counters live in this
        # scheduler's own registry (sync paths spin up one scheduler per
        # submit and fold its snapshot into the engine — a shared
        # registry would double-count across those folds)
        self.obs = obs
        self.metrics = MetricsRegistry()
        # <= 0 means unbudgeted: a zero-pop slice would make GEDSearch.run
        # return undecided with no progress and the re-push loop livelock
        self.slice_expansions = (int(slice_expansions)
                                 if slice_expansions and slice_expansions > 0
                                 else None)
        self.workers = max(1, int(workers))
        # duck-typed fault injector (serve.faults.FaultInjector): fires
        # ``verify.slice`` per pair and ``verify.pool`` per pool dispatch
        self.faults = faults
        self.dispatch_retries = max(0, int(dispatch_retries))
        self.max_pool_rebuilds = max(0, int(max_pool_rebuilds))
        # poisoned-pool health (DESIGN.md §18): repeated breakage trips
        # FAILING and slices go straight in-process until a probe passes
        self.pool_health = StageHealth(
            "verify_pool", fail_threshold=2, probe_interval=4,
            registry=obs.metrics if obs is not None else self.metrics)
        self._pool = None
        self._want_pool = executor == "process"
        self._pool_closed = False   # guarded_by: self._cv
        if self._want_pool:
            self._pool = self._make_pool()
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._heap: list = []       # guarded_by: self._cv
        self._inflight = 0          # guarded_by: self._cv
        self._closed = False        # guarded_by: self._cv
        self._interval_sink = interval_sink
        # a registry view, not a dict (DESIGN.md §17): same keys and
        # mutation idiom, but snapshot/merge-able with every other
        # component.  Mutations stay under self._cv as before — the view
        # only adds the registry's own lock per access.
        self.stats: StatsView = self.metrics.view(
            "sched", initial={k: 0 for k in self.STAT_KEYS})

    def stats_snapshot(self) -> Dict[str, int]:
        """Consistent copy of the worklist counters (readers must not
        iterate ``stats`` while a verifier thread is publishing)."""
        return self.stats.snapshot()

    def _make_pool(self):
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor
        # spawn, not fork: the parent usually has jax/XLA threads, and
        # the child only needs the jax-free core.verify module anyway
        return ProcessPoolExecutor(
            max_workers=self.workers,
            mp_context=multiprocessing.get_context("spawn"))

    def _on_pool_broken(self, pool) -> None:
        """A dispatch saw ``BrokenProcessPool``: retire the poisoned pool
        and — within the rebuild budget — stand up a fresh one so later
        slices regain process parallelism.  Concurrent observers of the
        same broken pool race benignly: only the first swaps it out, the
        rest see ``self._pool is not pool`` and return."""
        self.pool_health.record_failure()
        rebuild = False
        with self._cv:
            self.stats["pool_fallbacks"] += 1
            if self._pool is not pool or self._pool_closed:
                return
            self._pool = None
            if self.stats["pool_rebuilds"] < self.max_pool_rebuilds:
                self.stats["pool_rebuilds"] += 1
                rebuild = True
        pool.shutdown(wait=False)   # reap outside the lock; workers are dead
        if not rebuild:
            return
        fresh = self._make_pool()
        with self._cv:
            if self._pool is None and not self._pool_closed:
                self._pool = fresh
                fresh = None
        if fresh is not None:       # lost the race / closing: discard it
            fresh.shutdown(wait=False)

    # ---- producer side -----------------------------------------------------
    def add_job(self, graph: Graph, tau: int, ids: Sequence[int],
                bounds: Sequence[int], *, deadline: Optional[float] = None,
                token=None, on_match: Optional[Callable] = None,
                on_done: Optional[Callable] = None,
                should_skip: Optional[Callable] = None,
                n_lb_pruned: int = 0, n_lb_tightened: int = 0,
                qid: Optional[int] = None) -> VerifyJob:
        """Enqueue one query's candidate pairs (cheapest bound first is
        the heap's job).  ``on_done`` fires exactly once, on the thread
        that retires the query's last pair (immediately, on the calling
        thread, for candidate-less queries).  ``should_skip(gid, bound)``
        is consulted at pop time — a True verdict retires the pair as
        ``pruned`` without running A* (the top-k kth-best cutoff).

        ``n_lb_pruned`` / ``n_lb_tightened`` account the stage-1.5
        assignment-LB merge that happened *before* this call (DESIGN.md
        §16): pairs the LB already decided (``lb > τ``) never reach the
        heap, so the no-redecide invariant becomes
        ``verified + pruned + expired + lb_pruned == |candidates seen|``."""
        if n_lb_pruned or n_lb_tightened:
            with self._cv:
                self.stats["lb_pruned"] += int(n_lb_pruned)
                self.stats["lb_tightened"] += int(n_lb_tightened)
        job = VerifyJob(graph, tau, deadline, token=token,
                        on_match=on_match, on_done=on_done,
                        should_skip=should_skip, qid=qid)
        job.remaining = len(ids)
        if not ids:
            if on_done is not None:
                on_done(job)
            return job
        with self._cv:
            for b, gid in zip(bounds, ids):
                heapq.heappush(self._heap,
                               (int(b), next(self._seq), job, int(gid), None))
            self._cv.notify_all()
        return job

    def close(self) -> None:
        """No more jobs will be added: workers exit once the heap drains.
        (The process pool, if any, stays up — draining workers still
        dispatch into it; call ``shutdown()`` after they are joined.)"""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def shutdown(self, wait: bool = True) -> None:
        """Stop the process-pool executor (idempotent, no-op inline).
        Marks the pool closed first so a concurrent broken-pool recovery
        can never rebuild a pool that would leak past shutdown."""
        with self._cv:
            self._pool_closed = True
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)

    # ---- consumer side -----------------------------------------------------
    def _pop(self, block: bool):
        with self._cv:
            while True:
                if self._heap:
                    return heapq.heappop(self._heap)
                if not block or self._closed:
                    return None
                self._cv.wait()

    def run_until_idle(self) -> None:
        """Drain on the calling thread (the sync one-worker case).  With a
        process pool and ``workers > 1``, temporary dispatcher threads
        keep that many A* slices in flight — they only block on futures,
        so the GIL stays free for the pool to be the parallelism."""
        if self._pool is not None and self.workers > 1:
            threads = [threading.Thread(target=self._drain_cooperative,
                                        daemon=True)
                       for _ in range(self.workers)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return
        self._drain_nonblocking()

    def _drain_nonblocking(self) -> None:
        while True:
            item = self._pop(block=False)
            if item is None:
                return
            self._run_item(item)

    def _drain_cooperative(self) -> None:
        """Multi-dispatcher drain: a transiently empty heap is not done —
        an in-flight resumable slice may re-push work, so dispatchers
        wait while any peer still runs a pair and only exit when the heap
        is empty AND nothing is in flight."""
        while True:
            with self._cv:
                while True:
                    if self._heap:
                        item = heapq.heappop(self._heap)
                        self._inflight += 1
                        break
                    if self._inflight == 0:
                        return
                    self._cv.wait()
            try:
                self._run_item(item)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def worker_loop(self) -> None:
        """Blocking drain for pool threads; returns after ``close()`` once
        the heap is empty."""
        while True:
            item = self._pop(block=True)
            if item is None:
                return
            self._run_item(item)

    def _execute(self, search: GEDSearch, deadline,
                 qid: Optional[int] = None):
        """One A* slice, in-process or on the pool.  Returns the decision
        (or None) plus the search holding the advanced frontier — the
        pool round-trips the search object, so resume works identically
        either way.  With spans enabled, the pool also round-trips a
        worker-side ``(t0, t1, pid)`` fragment with the pickled search
        (``perf_counter`` is system-wide monotonic on these hosts), so
        the A* compute interval lands on the trace inside the host-side
        dispatch span."""
        pool = self._pool
        want_span = self.obs is not None and self.obs.spans.enabled
        if pool is not None and not self.pool_health.allow_primary():
            # FAILING pool is sticky-skipped between probes: slices go
            # straight in-process without paying a doomed dispatch
            self.metrics.counter_add("sched.pool_skips")
            pool = None
        if pool is not None:
            from concurrent.futures.process import BrokenProcessPool
            from repro.core.verify import run_search_slice
            if self.faults is not None:
                # kill_worker specs act here, right before the dispatch
                self.faults.fire("verify.pool", pool=pool)
            fut = None
            for attempt in range(self.dispatch_retries + 1):
                try:
                    fut = pool.submit(run_search_slice, search,
                                      self.slice_expansions, deadline,
                                      want_span)
                    break
                except BrokenProcessPool:
                    # broken before dispatch (a worker died under an
                    # earlier slice): same recovery as a mid-slice break
                    self._on_pool_broken(pool)
                    raise _PoolBroken() from None
                except (OSError, RuntimeError):
                    # transient dispatch failure (queue hiccup / raced
                    # shutdown): back off and retry before falling back
                    if attempt < self.dispatch_retries:
                        time.sleep(0.005 * (2 ** attempt))
            if fut is not None:
                try:
                    out = fut.result()
                except BrokenProcessPool:
                    # worker died mid-slice; the search state here is
                    # untouched (the pool ran a pickled copy), so hand
                    # the pair back to the heap at its current frontier
                    # and retire/rebuild the poisoned pool
                    self._on_pool_broken(pool)
                    raise _PoolBroken() from None
                # any other exception came from the A* slice itself and
                # re-raises unchanged — _run_item counts it once as an
                # error pair, with no duplicate in-process run
                if out is not None:
                    self.pool_health.record_success()
                    if len(out) == 3:
                        d, search, frag = out
                        if want_span and frag is not None:
                            self.obs.spans.record(
                                "astar_slice", frag[0], frag[1], qid=qid,
                                tid=f"ged-pool-{frag[2]}")
                        return d, search
                    return out
            # a dead pool degrades to in-process slices (slower, never
            # wrong): results must not depend on the pool's health
            with self._cv:
                self.stats["pool_fallbacks"] += 1
        return (search.run(max_expansions=self.slice_expansions,
                           deadline=deadline), search)

    def _run_item(self, item) -> None:
        """Run one pair.  Contained like the filter stage: an exception
        anywhere in the A*/delivery path counts the pair unverified and
        still retires it — a raising pair must never kill a verifier
        thread or leave its query's countdown stuck (DESIGN.md §12)."""
        bound, _seq, job, gid, search = item
        finish = True
        try:
            t0 = time.perf_counter()
            if job.deadline is not None and t0 >= job.deadline:
                with self._cv:
                    job.unverified += 1
                    self.stats["expired_pairs"] += 1
                return
            # top-k pruning: once the job's kth-best is confirmed, pairs
            # whose (bound, gid) can no longer displace it are retired
            # without A*.  A resumed pair's bound reflects its improved
            # frontier min_f, so partially-run searches prune too.
            if job.should_skip is not None \
                    and job.should_skip(int(gid), int(bound)):
                with self._cv:
                    job.pruned += 1
                    self.stats["pruned_pairs"] += 1
                return
            if search is None:
                # the heap bound is a provable GED lower bound (filter
                # bound merged with the stage-1.5 assignment LB), so it
                # seeds A* directly: lb > τ decides τ+1 with zero
                # expansions and min_f never reports below it (§16)
                search = GEDSearch(self.db[gid], job.graph, job.tau,
                                   initial_bound=int(bound))
            else:
                with self._cv:
                    self.stats["resumed_runs"] += 1
            exp0 = search.expansions
            if self.faults is not None:
                self.faults.fire("verify.slice", qid=job.qid, gid=int(gid))
            d, search = self._execute(search, job.deadline, qid=job.qid)
            t1 = time.perf_counter()
            obs = self.obs
            if obs is not None and obs.spans.enabled:
                # per-slice verify span: which pair, at what seed bound,
                # how much A* it burned, and whether it decided (§17)
                obs.spans.record(
                    "verify", t0, t1, qid=job.qid, gid=int(gid),
                    bound=int(bound), expansions=search.expansions - exp0,
                    decided=d is not None)
            self.metrics.observe("sched.verify_slice_s", t1 - t0)
            with self._cv:
                job.verify_s += t1 - t0
                if self._interval_sink is not None:
                    self._interval_sink.append((t0, t1))
            if d is None:
                if job.deadline is not None and t1 >= job.deadline:
                    with self._cv:
                        job.unverified += 1
                        self.stats["expired_pairs"] += 1
                    return
                # timesliced: resume later at the improved frontier bound
                with self._cv:
                    heapq.heappush(self._heap,
                                   (max(int(bound), search.min_f()),
                                    next(self._seq), job, gid, search))
                    self._cv.notify()
                finish = False
                return
            with self._cv:
                self.stats["verified_pairs"] += 1
                if d <= job.tau:
                    job.matches.append((gid, d))
            if d <= job.tau and job.on_match is not None:
                job.on_match(job, gid, d)
        except _PoolBroken:
            # the pool died under this pair, not the pair under the pool:
            # its search state is intact, so re-enqueue at the frontier it
            # already reached (min_f) — never restart from scratch, never
            # retire it unverified (the satellite invariant tests assert
            # exactly one GEDSearch construction per pair)
            with self._cv:
                heapq.heappush(self._heap,
                               (max(int(bound), search.min_f()),
                                next(self._seq), job, gid, search))
                self._cv.notify()
            finish = False
        except Exception:               # noqa: BLE001 — stage containment
            with self._cv:
                job.unverified += 1
                self.stats["error_pairs"] += 1
        finally:
            if finish:
                self._finish_one(job)

    def _finish_one(self, job: VerifyJob) -> None:
        with self._cv:
            job.remaining -= 1
            done = job.remaining == 0
        if done and self.obs is not None and self.obs.spans.enabled:
            # the query's whole worklist residency: enqueue -> last pair
            self.obs.spans.record(
                "worklist", job.t_enq, time.perf_counter(), qid=job.qid,
                matches=len(job.matches), unverified=job.unverified,
                pruned=job.pruned)
        if done and job.on_done is not None:
            try:
                job.on_done(job)
            except Exception:           # lint: disable=SRV001
                pass                    # last-resort guard: delivery errors
                                        # must not kill the worker (on_done
                                        # resolves its own ticket with the
                                        # error first)


class GraphQueryEngine:
    """Batched filter-and-verify serving over a ``CandidateSource``."""

    def __init__(self, source: CandidateSource, backend: str = "auto",
                 encoding_cache_size: int = 1024,
                 result_cache_size: int = 256, slab_layout: str = "dense",
                 hot_d: Optional[int] = None,
                 hot_mass: Optional[float] = None, tile_table=None,
                 assign_lb: bool = True, lb_hungarian: int = 0,
                 lb_tile_table=None, obs: Optional[Observability] = None,
                 encoding_cache_bytes: Optional[int] = None,
                 result_cache_bytes: Optional[int] = None, faults=None):
        self.source = source
        self.backend = resolve_backend() if backend == "auto" else backend
        self.slab_layout = slab_layout
        self.hot_d = hot_d
        self.hot_mass = hot_mass
        # autotuned kernel tiles for the pallas path (DESIGN.md §13);
        # e.g. tile_table=cfg.tile_table() for a config-selected table
        self.tile_table = tile_table
        # stage-1.5 assignment-LB knobs (DESIGN.md §16): the batched
        # branch bound between the q-gram filter and A* verification;
        # lb_hungarian > 0 additionally runs the exact Hungarian
        # assignment on that many top-LB survivors per query
        self.assign_lb = bool(assign_lb)
        self.lb_hungarian = int(lb_hungarian)
        self.lb_tile_table = lb_tile_table
        # every engine carries an Observability (DESIGN.md §17): the
        # registry backs the ``stats`` view below; span recording stays
        # off unless the caller opts in (the ≤2% overhead budget)
        self.obs = obs if obs is not None else Observability(spans=False)
        # duck-typed fault injector, threaded to the filter evaluator per
        # call and to the async pipeline's scheduler (DESIGN.md §18)
        self.faults = faults
        # caches are entry-bounded and — with *_cache_bytes — also
        # byte-bounded; high-water marks surface as gauges (max-merge)
        reg = self.obs.metrics
        self._enc_cache = _LRU(
            encoding_cache_size, max_bytes=encoding_cache_bytes,
            sizeof=_approx_nbytes if encoding_cache_bytes else None,
            on_hwm=lambda b, n: (
                reg.gauge_set("engine.enc_cache_bytes_hwm", b),
                reg.gauge_set("engine.enc_cache_entries_hwm", n)))
        self._res_cache = _LRU(
            result_cache_size, max_bytes=result_cache_bytes,
            sizeof=_approx_nbytes if result_cache_bytes else None,
            on_hwm=lambda b, n: (
                reg.gauge_set("engine.res_cache_bytes_hwm", b),
                reg.gauge_set("engine.res_cache_entries_hwm", n)))
        self._qid = itertools.count()   # per-engine query ids for spans
        self.stats: StatsView = self.obs.metrics.view("engine", initial={
            "batches": 0, "queries": 0, "filter_s": 0.0, "verify_s": 0.0,
            "lb_s": 0.0, "verified_pairs": 0, "expired_pairs": 0,
            "pruned_pairs": 0, "lb_pruned": 0, "lb_tightened": 0,
            "resumed_runs": 0, "pool_fallbacks": 0, "pool_rebuilds": 0,
            "error_pairs": 0, "cache_hits": 0, "topk_rounds": 0})

    # ---- encoding cache ----------------------------------------------------
    def _qtuple(self, g: Graph) -> Tuple[bytes, QueryTuple]:
        key = _graph_key(g)
        qt = self._enc_cache.get(key)
        if qt is None:
            t0 = time.perf_counter()
            qt = QueryTuple.from_graph(g, self.source.vocab)
            if self.obs.spans.enabled:
                self.obs.spans.record("encode", t0, time.perf_counter())
            self._enc_cache.put(key, qt)
        return key, qt

    # ---- candidate generation hook (overridden by the sharded engine) ------
    def _batched_candidates(self, graphs, taus, qtuples):
        kwargs = {"qtuples": qtuples}
        params = inspect.signature(
            self.source.batched_candidates).parameters
        if "backend" in params:     # tree sources take no backend
            kwargs["backend"] = self.backend
        if "slab" in params:        # nor a FilterSlab layout
            kwargs["slab"] = self.slab_layout
            kwargs["hot_d"] = self.hot_d
        if "hot_mass" in params:
            kwargs["hot_mass"] = self.hot_mass
        if "tile_table" in params and self.tile_table is not None:
            kwargs["tile_table"] = self.tile_table
        if "assign_lb" in params:
            kwargs["assign_lb"] = self.assign_lb
            kwargs["lb_hungarian"] = self.lb_hungarian
            if self.lb_tile_table is not None:
                kwargs["lb_tile_table"] = self.lb_tile_table
        if "faults" in params:      # flat sources thread the injector
            kwargs["faults"] = self.faults
        return self.source.batched_candidates(graphs, taus, **kwargs)

    # ---- shared stages (submit composes them inline; the async pipeline
    # runs them across threads — DESIGN.md §12) ------------------------------
    def _admit(self, requests: Sequence[GraphQuery]):
        """Stage 0: result-cache replay + in-batch duplicate coalescing.

        Returns (results, fresh, aliases, keys, qtuples, qids);
        ``results`` has cache hits already resolved — tagged
        ``cache_hit`` with the stale per-query timings (filter, verify,
        lb, queue) zeroed, so replayed stats are never mistaken for
        fresh filter/verify work.  ``qids`` are the engine-assigned
        query ids correlating this batch's spans."""
        t_adm = time.perf_counter()
        results: List[Optional[QueryResult]] = [None] * len(requests)
        fresh: List[int] = []
        aliases: List[Tuple[int, int]] = []      # (request idx, source idx)
        pending: Dict[Tuple, int] = {}
        keys: List[Optional[bytes]] = [None] * len(requests)
        qtuples: List[Optional[QueryTuple]] = [None] * len(requests)
        qids: List[int] = [next(self._qid) for _ in requests]
        spans_on = self.obs.spans.enabled
        for i, r in enumerate(requests):
            key, qt = self._qtuple(r.graph)
            # the cache key carries the full query modality: a range-τ
            # entry must never answer a top_k query (or vice versa) —
            # same graph, same τ, different answer shape (DESIGN.md §15)
            k3 = (key, int(r.tau), bool(r.verify),
                  None if r.top_k is None else int(r.top_k))
            hit = self._res_cache.get(k3)
            if hit is not None:
                # cached results are always complete (partials are never
                # cached), so a deadline-carrying request may take them too
                self.stats["cache_hits"] += 1
                results[i] = replace(
                    hit, filter_time_s=0.0, verify_time_s=0.0,
                    stats={**hit.stats, "cache_hit": 1,
                           "lb_s": 0.0, "queue_s": 0.0})
                if spans_on:
                    now = time.perf_counter()
                    self.obs.spans.record("query", t_adm, now,
                                          qid=qids[i], cache_hit=1)
                continue
            # in-batch coalescing must also match on the deadline: a
            # deadline-free duplicate aliased to a deadline-carrying one
            # would silently inherit its partial (recall-lossy) result
            k4 = k3 + (r.deadline_s,)
            if k4 in pending:
                aliases.append((i, pending[k4]))  # duplicate in this batch
            else:
                pending[k4] = i
                fresh.append(i)
                keys[i] = key
                qtuples[i] = qt
        if spans_on:
            self.obs.spans.record("admission", t_adm, time.perf_counter(),
                                  n=len(requests), fresh=len(fresh))
        return results, fresh, aliases, keys, qtuples, qids

    def _cache_result(self, key: bytes, request: GraphQuery,
                      res: QueryResult) -> None:
        self._res_cache.put(
            (key, int(request.tau), bool(request.verify),
             None if request.top_k is None else int(request.top_k)), res)

    @staticmethod
    def _job_bounds(batch, row: int) -> List[int]:
        bnd = batch.bounds[row]
        if bnd is None:                      # tree sources carry no bounds
            return [0] * len(batch.ids[row])
        return [int(b) for b in bnd]

    @staticmethod
    def _job_lbs(batch, row: int) -> Optional[Sequence[int]]:
        """The row's stage-1.5 assignment LBs, or None when the source
        computed none (tree sources, ``assign_lb=False``)."""
        lbs = getattr(batch, "lbs", None)
        return None if lbs is None else lbs[row]

    @staticmethod
    def _job_lb_share(batch, row: int) -> float:
        """The row's share of the batch's assignment-LB pass time, in
        seconds (0.0 for sources that don't report it)."""
        lb_s = getattr(batch, "lb_s", None)
        return 0.0 if lb_s is None else float(lb_s[row])

    @staticmethod
    def _merge_lb(ids: Sequence[int], bounds: Sequence[int],
                  lbs: Optional[Sequence[int]], tau: int):
        """Fold the stage-1.5 assignment LBs into one query's worklist
        admission (DESIGN.md §16).  A pair with ``lb > τ`` is already
        decided (GED >= lb), so it never enters the heap; survivors seed
        A* at the tighter ``max(filter bound, lb)``.  The candidate
        *list* is untouched by the caller — the LB prunes work, never
        recall.  Returns (ids, bounds, n_lb_pruned, n_lb_tightened)."""
        if lbs is None:
            return list(ids), list(bounds), 0, 0
        keep_ids: List[int] = []
        keep_bounds: List[int] = []
        pruned = tightened = 0
        for g, b, lb in zip(ids, bounds, lbs):
            lb = int(lb)
            if lb > int(tau):
                pruned += 1
                continue
            if lb > int(b):
                tightened += 1
                b = lb
            keep_ids.append(int(g))
            keep_bounds.append(int(b))
        return keep_ids, keep_bounds, pruned, tightened

    @staticmethod
    def _assemble(cand: List[int], job: Optional[VerifyJob], n_db: int,
                  per_q_filter: float, lb_s: float = 0.0) -> QueryResult:
        stats: Dict[str, int] = {"batched": 1, "lb_s": lb_s}
        matches: List[Tuple[int, int]] = []
        verify_s = 0.0
        if job is not None:
            matches = sorted(job.matches)
            verify_s = job.verify_s
            if job.unverified:
                # deadline fired: matches may be incomplete but candidates
                # are untouched — recall-safe partial (DESIGN.md §12)
                stats["partial"] = 1
                stats["unverified"] = job.unverified
        return QueryResult(
            candidates=cand, matches=matches, n_filtered=n_db - len(cand),
            filter_time_s=per_q_filter, verify_time_s=verify_s, stats=stats)

    def _assemble_topk(self, st: TopKState, n_db: int) -> QueryResult:
        """Result for one top-k query from its escalation state: matches
        are the k smallest (ged, gid) — the deterministic tie rule — and
        candidates are every gid ever admitted across rounds (never
        truncated, the recall-safety analog of the range path)."""
        matches = st.topk_matches()
        stats: Dict[str, int] = {
            "batched": 1, "lb_s": st.lb_s, "top_k": st.k,
            "topk_rounds": st.rounds, "topk_tau_final": st.tau,
            "topk_pruned": st.pruned}
        if len(matches) < st.k:
            stats["topk_exhausted"] = 1   # fewer than k graphs within cap
        if st.unverified or st.deadline_hit:
            # deadline fired mid-escalation: the verified prefix is
            # returned, flagged partial, and never cached (DESIGN.md §15)
            stats["partial"] = 1
            stats["unverified"] = st.unverified
        cand = sorted(st.seen)
        return QueryResult(
            candidates=cand, matches=matches, n_filtered=n_db - len(cand),
            filter_time_s=st.filter_s, verify_time_s=st.verify_s,
            stats=stats)

    def _fold_scheduler_stats(self, sched: VerifyScheduler) -> None:
        """Fold a drained scheduler's counters into the engine registry —
        the one merge path shared by the sync range and sync top-k drains
        (the async pipeline keeps a live scheduler and merges at its
        ``stats`` property instead)."""
        ss = sched.stats_snapshot()
        for k in VerifyScheduler.STAT_KEYS:
            self.stats[k] += ss[k]

    def _submit_topk(self, requests: Sequence[GraphQuery],
                     fresh: List[int], keys, qtuples, results,
                     qids: Sequence[int], t_sub: float) -> None:
        """The sync adaptive-τ escalation loop (DESIGN.md §15): per round,
        one joint filter pass over every still-active top-k query at its
        own round τ, then the shared cheapest-first worklist drains the
        *new* pairs (decided gids are never resubmitted).  Escalation
        stops per query when its kth-best confirmed distance is covered
        by the round τ, the cap is reached, or its deadline fires."""
        sched = VerifyScheduler(self.source.db, obs=self.obs)
        now = time.perf_counter()
        spans_on = self.obs.spans.enabled
        states: Dict[int, TopKState] = {}
        for i in fresh:
            r = requests[i]
            deadline = (None if r.deadline_s is None
                        else now + float(r.deadline_s))
            states[i] = TopKState(int(r.top_k), int(r.tau), deadline)
        n_db = len(self.source.db)
        active = list(fresh)
        while active:
            graphs = [requests[i].graph for i in active]
            taus = [states[i].tau for i in active]
            t0 = time.perf_counter()
            with use_obs(self.obs):
                batch = self._batched_candidates(
                    graphs, taus, [qtuples[i] for i in active])
            t1 = time.perf_counter()
            self.stats["filter_s"] += t1 - t0
            if spans_on:
                self.obs.spans.record("filter", t0, t1, rows=len(active),
                                      backend=self.backend)
            share = (t1 - t0) / len(active)
            jobs: Dict[int, VerifyJob] = {}
            for row, i in enumerate(active):
                st = states[i]
                st.rounds += 1
                self.stats["topk_rounds"] += 1
                st.filter_s += share
                lb_share = self._job_lb_share(batch, row)
                st.lb_s += lb_share
                self.stats["lb_s"] += lb_share
                bounds = self._job_bounds(batch, row)
                lbs = self._job_lbs(batch, row)
                keep = [c for c, g in enumerate(batch.ids[row])
                        if int(g) not in st.seen]
                new_ids = [int(batch.ids[row][c]) for c in keep]
                st.seen.update(new_ids)   # lb-pruned gids stay "seen":
                # they are decided (GED >= lb > cap), never resubmitted
                w_ids, w_bounds, n_pr, n_tt = self._merge_lb(
                    new_ids, [bounds[c] for c in keep],
                    None if lbs is None else [int(lbs[c]) for c in keep],
                    st.cap)
                # pairs run at the query CAP, not the round τ — decisions
                # stay final and frontiers resumable (DESIGN.md §15)
                jobs[i] = sched.add_job(
                    requests[i].graph, st.cap, w_ids, w_bounds,
                    deadline=st.deadline,
                    on_match=lambda job, g, d, s=st: s.record_match(g, d),
                    should_skip=st.should_skip,
                    n_lb_pruned=n_pr, n_lb_tightened=n_tt, qid=qids[i])
            sched.run_until_idle()   # the one-worker special case
            still: List[int] = []
            for i in active:
                st = states[i]
                st.absorb_round(jobs[i])
                now = time.perf_counter()
                if spans_on:
                    self.obs.spans.record("topk_round", t0, now,
                                          qid=qids[i], tau=st.tau,
                                          round=st.rounds)
                expired = st.deadline is not None and now >= st.deadline
                if st.unverified or expired:
                    st.deadline_hit = True
                if st.deadline_hit or st.satisfied():
                    res = self._assemble_topk(st, n_db)
                    results[i] = res
                    if not (st.unverified or st.deadline_hit):
                        self._cache_result(keys[i], requests[i], res)
                    if spans_on:
                        self.obs.spans.record(
                            "query", t_sub, time.perf_counter(),
                            qid=qids[i], top_k=st.k,
                            partial=int(bool(res.stats.get("partial"))))
                else:
                    st.escalate()
                    still.append(i)
            active = still
        self.stats["verify_s"] += sum(s.verify_s for s in states.values())
        self._fold_scheduler_stats(sched)

    # ---- the batched path --------------------------------------------------
    def submit(self, requests: Sequence[GraphQuery]) -> List[QueryResult]:
        """Answer a batch; results align with ``requests`` order."""
        t_sub = time.perf_counter()
        spans_on = self.obs.spans.enabled
        self.stats["batches"] += 1
        self.stats["queries"] += len(requests)
        results, all_fresh, aliases, keys, qtuples, qids = \
            self._admit(requests)
        fresh = [i for i in all_fresh if requests[i].top_k is None]
        fresh_topk = [i for i in all_fresh if requests[i].top_k is not None]
        if fresh:
            graphs = [requests[i].graph for i in fresh]
            taus = [int(requests[i].tau) for i in fresh]

            # stages 1-3: bucket, shard the slab, filter (source-specific)
            t0 = time.perf_counter()
            with use_obs(self.obs):
                batch = self._batched_candidates(
                    graphs, taus, [qtuples[i] for i in fresh])
            t1 = time.perf_counter()
            self.stats["filter_s"] += t1 - t0
            if spans_on:
                self.obs.spans.record("filter", t0, t1, rows=len(fresh),
                                      backend=self.backend)

            # stage 4: shared verification worklist, cheapest pair first
            sched = VerifyScheduler(self.source.db, obs=self.obs)
            now = time.perf_counter()
            jobs: Dict[int, VerifyJob] = {}
            for row, i in enumerate(fresh):
                r = requests[i]
                if not r.verify:
                    continue
                deadline = (None if r.deadline_s is None
                            else now + float(r.deadline_s))
                w_ids, w_bounds, n_pr, n_tt = self._merge_lb(
                    batch.ids[row], self._job_bounds(batch, row),
                    self._job_lbs(batch, row), taus[row])
                jobs[row] = sched.add_job(
                    r.graph, taus[row], w_ids, w_bounds, deadline=deadline,
                    n_lb_pruned=n_pr, n_lb_tightened=n_tt, qid=qids[i])
            sched.run_until_idle()   # the one-worker special case
            self.stats["verify_s"] += sum(j.verify_s for j in jobs.values())
            self._fold_scheduler_stats(sched)

            n_db = len(self.source.db)
            per_q_filter = (t1 - t0) / max(len(fresh), 1)
            for row, i in enumerate(fresh):
                job = jobs.get(row)
                lb_share = self._job_lb_share(batch, row)
                self.stats["lb_s"] += lb_share
                res = self._assemble(batch.ids[row], job, n_db,
                                     per_q_filter, lb_s=lb_share)
                results[i] = res
                # deadline-partial results are never cached: a later query
                # without the deadline must not replay incomplete matches
                if job is None or not job.unverified:
                    self._cache_result(keys[i], requests[i], res)
                if spans_on:
                    self.obs.spans.record(
                        "query", t_sub, time.perf_counter(), qid=qids[i],
                        tau=taus[row],
                        partial=int(bool(res.stats.get("partial"))))
        if fresh_topk:
            self._submit_topk(requests, fresh_topk, keys, qtuples, results,
                              qids, t_sub)
        # resolve from results, not the cache: small caches may already
        # have evicted the entry by the time the batch finishes
        for i, src in aliases:
            results[i] = results[src]
        return results  # type: ignore[return-value]

    # ---- single-query wrappers ---------------------------------------------
    def query(self, graph: Graph, tau: int, verify: bool = True) -> QueryResult:
        return self.submit([GraphQuery(graph, tau, verify)])[0]

    def query_topk(self, graph: Graph, k: int, cap: int,
                   deadline_s: Optional[float] = None) -> QueryResult:
        """k-nearest within a GED cap: matches are the k smallest
        (ged, gid), sorted by (ged, gid) — see ``GraphQuery.top_k``."""
        return self.submit([GraphQuery(graph, cap, top_k=k,
                                       deadline_s=deadline_s)])[0]

    @property
    def cache_info(self) -> Dict[str, int]:
        enc, res = self._enc_cache.usage(), self._res_cache.usage()
        return {"encoding_hits": self._enc_cache.hits,
                "encoding_misses": self._enc_cache.misses,
                "result_hits": self._res_cache.hits,
                "result_misses": self._res_cache.misses,
                "encoding_bytes": enc["bytes"],
                "encoding_bytes_hwm": enc["bytes_hwm"],
                "encoding_entries_hwm": enc["entries_hwm"],
                "result_bytes": res["bytes"],
                "result_bytes_hwm": res["bytes_hwm"],
                "result_entries_hwm": res["entries_hwm"]}


class ShardedGraphQueryEngine(GraphQueryEngine):
    """GraphQueryEngine whose filter stage runs over a device mesh.

    Each bucket's region slab of ``DBArrays`` is block-partitioned over
    the mesh's batch axes (('pod', 'data') on the production meshes), the
    padded query block is replicated, every device runs the full leaf
    cascade inside shard_map, and fixed-size per-device top-k candidate
    blocks are all-gathered into the shared cheapest-first GED worklist
    (stage 4 is unchanged — the blocks drain through ``submit``'s
    worklist exactly like single-host candidates).

    ``layout`` picks the DESIGN.md §5 layout: ``'graph'`` (default; every
    mesh axis shards graphs) or ``'vocab'`` (graphs over ('pod', 'data'),
    the dense/hot F_D vocabulary dim over 'model' with a psum'd partial
    min-sum — the fit for very wide PubChem-scale vocabularies).
    ``slab_layout`` picks the resident F_D form per DESIGN.md §11:
    ``'dense'``, ``'hot'`` (hot prefix sharded like dense, batched CSR
    tail correction psum-then-added on device), or ``'packed'`` (hybrid
    bit-packed words rows sharded over the batch axes, decoded per device
    inside shard_map; graph-sharded only).
    Candidate sets are bit-identical to the single-host engine
    (``tests/test_sharded_engine.py``): block truncation is recall-safe
    because overflowing blocks fall back to exact per-device ids.
    """

    def __init__(self, source: CandidateSource, mesh, layout: str = "graph",
                 k: int = 256, shard_pad: int = 512,
                 slab_layout: str = "dense", hot_d: Optional[int] = None,
                 hot_mass: Optional[float] = None, **kw):
        for attr in ("enc", "set_filter_eval"):
            if not hasattr(source, attr):
                raise TypeError(
                    "ShardedGraphQueryEngine needs a flat-style source "
                    "(FlatMSQIndex); tree sources have no slab arrays")
        super().__init__(source, backend="distributed",
                         slab_layout=slab_layout, hot_d=hot_d,
                         hot_mass=hot_mass, **kw)
        from repro.core.engine import BatchedFilterEval
        self.mesh = mesh
        self.layout = layout
        self.evaluator = BatchedFilterEval(
            source.db, source.enc, source.partition, backend="distributed",
            mesh=mesh, layout=layout, k=k, shard_pad=shard_pad,
            slab=slab_layout, hot_d=hot_d, hot_mass=hot_mass,
            assign_lb=self.assign_lb, lb_hungarian=self.lb_hungarian,
            lb_tile_table=self.lb_tile_table)
        # also visible to plain GraphQueryEngine(source, "distributed") users
        source.set_filter_eval("distributed", self.evaluator)

    @classmethod
    def from_config(cls, source: CandidateSource, mesh, cfg,
                    **kw) -> "ShardedGraphQueryEngine":
        """Layouts/top-k from an MSQConfig (msq_pubchem defaults to the
        vocab-sharded layout and the hot slab for its wide q-gram
        vocabulary).  A config ``hot_mass`` overrides the fixed ``hot_d``
        width — H is then picked from the dataset's q-gram mass."""
        hm = getattr(cfg, "hot_mass", None)
        kw.setdefault("slab_layout", getattr(cfg, "slab_layout", "dense"))
        kw.setdefault("hot_mass", hm)
        kw.setdefault("hot_d",
                      None if hm is not None else getattr(cfg, "hot_d", None))
        kw.setdefault("assign_lb", getattr(cfg, "assign_lb", True))
        kw.setdefault("lb_hungarian", getattr(cfg, "lb_hungarian", 0))
        return cls(source, mesh,
                   layout=getattr(cfg, "sharded_layout", "graph"),
                   k=int(getattr(cfg, "shard_topk", 256)), **kw)

    def _batched_candidates(self, graphs, taus, qtuples):
        from repro.core.engine import batched_flat_candidates
        if self.faults is not self.evaluator.faults:
            self.evaluator.set_faults(self.faults)
        return batched_flat_candidates(self.evaluator, graphs, taus, qtuples)

    @property
    def shard_stats(self) -> Dict[str, int]:
        """Candidate-block accounting (overflow_blocks counts recall-safe
        exact-id fallbacks, not drops)."""
        return dict(self.evaluator.dist_stats)
