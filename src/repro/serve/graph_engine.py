"""GraphQueryEngine: batched multi-query graph similarity serving.

Answers a batch of (query graph, tau) requests over any ``CandidateSource``
(tree-backed ``MSQIndex`` or flat ``FlatMSQIndex``) in four stages
(DESIGN.md §10):

  1. **bucket** queries by reduced query region
     (``core.engine.bucket_queries``) so each region's graphs are gathered
     once per batch,
  2. **shard** each bucket's slab: single-host backends gather it into one
     padded block; ``ShardedGraphQueryEngine`` block-partitions it over
     the mesh and replicates the padded query block,
  3. **filter**: the leaf-level cascade per bucket
     (``core.engine.BatchedFilterEval`` — jax / numpy / pallas backends on
     one host; the ``distributed`` backend runs it inside shard_map per
     device and all-gathers fixed-size top-k candidate blocks),
  4. **worklist**: candidate blocks from all queries drain into one shared
     verification worklist, cheapest-candidate-first through ``ged_upto``
     (low filter bounds are both likelier matches and cheaper A* runs, so
     early results stream out first).

Repeat queries hit two LRU caches: query *encodings* (the q-gram
``QueryTuple``, reusable across taus) and whole *results* (exact
(graph, tau, verify) hits).  The single-query ``query()`` is a thin
wrapper over a one-element batch.
"""
from __future__ import annotations

import inspect
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import CandidateSource, resolve_backend
from repro.core.search import QueryResult
from repro.core.tree import QueryTuple
from repro.core.verify import ged_upto
from repro.graphs.graph import Graph


@dataclass
class GraphQuery:
    """One similarity-search request."""

    graph: Graph
    tau: int
    verify: bool = True


def _graph_key(g: Graph) -> bytes:
    """Content key for the caches (exact array equality, not isomorphism)."""
    e = np.asarray(g.edges, np.int64).reshape(-1)
    return b"|".join((np.asarray(g.vlabels, np.int64).tobytes(),
                      e.tobytes(),
                      np.asarray(g.elabels, np.int64).tobytes()))


class _LRU:
    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)


class GraphQueryEngine:
    """Batched filter-and-verify serving over a ``CandidateSource``."""

    def __init__(self, source: CandidateSource, backend: str = "auto",
                 encoding_cache_size: int = 1024,
                 result_cache_size: int = 256, slab_layout: str = "dense",
                 hot_d: Optional[int] = None):
        self.source = source
        self.backend = resolve_backend() if backend == "auto" else backend
        self.slab_layout = slab_layout
        self.hot_d = hot_d
        self._enc_cache = _LRU(encoding_cache_size)
        self._res_cache = _LRU(result_cache_size)
        self.stats: Dict[str, float] = {
            "batches": 0, "queries": 0, "filter_s": 0.0, "verify_s": 0.0,
            "verified_pairs": 0}

    # ---- encoding cache ----------------------------------------------------
    def _qtuple(self, g: Graph) -> Tuple[bytes, QueryTuple]:
        key = _graph_key(g)
        qt = self._enc_cache.get(key)
        if qt is None:
            qt = QueryTuple.from_graph(g, self.source.vocab)
            self._enc_cache.put(key, qt)
        return key, qt

    # ---- candidate generation hook (overridden by the sharded engine) ------
    def _batched_candidates(self, graphs, taus, qtuples):
        kwargs = {"qtuples": qtuples}
        params = inspect.signature(
            self.source.batched_candidates).parameters
        if "backend" in params:     # tree sources take no backend
            kwargs["backend"] = self.backend
        if "slab" in params:        # nor a FilterSlab layout
            kwargs["slab"] = self.slab_layout
            kwargs["hot_d"] = self.hot_d
        return self.source.batched_candidates(graphs, taus, **kwargs)

    # ---- the batched path --------------------------------------------------
    def submit(self, requests: Sequence[GraphQuery]) -> List[QueryResult]:
        """Answer a batch; results align with ``requests`` order."""
        self.stats["batches"] += 1
        self.stats["queries"] += len(requests)
        results: List[Optional[QueryResult]] = [None] * len(requests)

        # whole-result cache + encoding cache + in-batch duplicate coalescing
        fresh: List[int] = []
        aliases: List[Tuple[int, int]] = []      # (request idx, source idx)
        pending: Dict[Tuple, int] = {}
        keys: List[Optional[bytes]] = [None] * len(requests)
        qtuples: List[Optional[QueryTuple]] = [None] * len(requests)
        for i, r in enumerate(requests):
            key, qt = self._qtuple(r.graph)
            k3 = (key, int(r.tau), bool(r.verify))
            hit = self._res_cache.get(k3)
            if hit is not None:
                results[i] = hit
            elif k3 in pending:
                aliases.append((i, pending[k3]))  # duplicate in this batch
            else:
                pending[k3] = i
                fresh.append(i)
                keys[i] = key
                qtuples[i] = qt
        if not fresh:
            return results  # type: ignore[return-value]

        graphs = [requests[i].graph for i in fresh]
        taus = [int(requests[i].tau) for i in fresh]

        # stages 1-3: bucket, shard the slab, filter (source-specific)
        t0 = time.perf_counter()
        batch = self._batched_candidates(graphs, taus,
                                         [qtuples[i] for i in fresh])
        t1 = time.perf_counter()
        self.stats["filter_s"] += t1 - t0

        # stage 4: shared verification worklist, cheapest candidate first
        matches: List[List[Tuple[int, int]]] = [[] for _ in fresh]
        verify_s = [0.0] * len(fresh)
        work: List[Tuple[int, int, int]] = []      # (bound, row, gid)
        for row, i in enumerate(fresh):
            if not requests[i].verify:
                continue
            bnd = batch.bounds[row]
            for k, gid in enumerate(batch.ids[row]):
                b = int(bnd[k]) if bnd is not None else 0
                work.append((b, row, gid))
        work.sort()
        db = self.source.db
        for b, row, gid in work:
            tv0 = time.perf_counter()
            d = ged_upto(db[gid], graphs[row], taus[row])
            verify_s[row] += time.perf_counter() - tv0
            if d <= taus[row]:
                matches[row].append((gid, d))
        self.stats["verify_s"] += sum(verify_s)
        self.stats["verified_pairs"] += len(work)

        n_db = len(db)
        per_q_filter = (t1 - t0) / max(len(fresh), 1)
        for row, i in enumerate(fresh):
            cand = batch.ids[row]
            res = QueryResult(
                candidates=cand,
                matches=sorted(matches[row]),
                n_filtered=n_db - len(cand),
                filter_time_s=per_q_filter,
                verify_time_s=verify_s[row],
                stats={"batched": 1},
            )
            results[i] = res
            self._res_cache.put(
                (keys[i], taus[row], bool(requests[i].verify)), res)
        # resolve from results, not the cache: small caches may already
        # have evicted the entry by the time the batch finishes
        for i, src in aliases:
            results[i] = results[src]
        return results  # type: ignore[return-value]

    # ---- single-query wrapper ----------------------------------------------
    def query(self, graph: Graph, tau: int, verify: bool = True) -> QueryResult:
        return self.submit([GraphQuery(graph, tau, verify)])[0]

    @property
    def cache_info(self) -> Dict[str, int]:
        return {"encoding_hits": self._enc_cache.hits,
                "encoding_misses": self._enc_cache.misses,
                "result_hits": self._res_cache.hits,
                "result_misses": self._res_cache.misses}


class ShardedGraphQueryEngine(GraphQueryEngine):
    """GraphQueryEngine whose filter stage runs over a device mesh.

    Each bucket's region slab of ``DBArrays`` is block-partitioned over
    the mesh's batch axes (('pod', 'data') on the production meshes), the
    padded query block is replicated, every device runs the full leaf
    cascade inside shard_map, and fixed-size per-device top-k candidate
    blocks are all-gathered into the shared cheapest-first GED worklist
    (stage 4 is unchanged — the blocks drain through ``submit``'s
    worklist exactly like single-host candidates).

    ``layout`` picks the DESIGN.md §5 layout: ``'graph'`` (default; every
    mesh axis shards graphs) or ``'vocab'`` (graphs over ('pod', 'data'),
    the dense/hot F_D vocabulary dim over 'model' with a psum'd partial
    min-sum — the fit for very wide PubChem-scale vocabularies).
    ``slab_layout`` picks the resident F_D form per DESIGN.md §11:
    ``'dense'``, ``'hot'`` (hot prefix sharded like dense, batched CSR
    tail correction psum-then-added on device), or ``'packed'`` (hybrid
    bit-packed words rows sharded over the batch axes, decoded per device
    inside shard_map; graph-sharded only).
    Candidate sets are bit-identical to the single-host engine
    (``tests/test_sharded_engine.py``): block truncation is recall-safe
    because overflowing blocks fall back to exact per-device ids.
    """

    def __init__(self, source: CandidateSource, mesh, layout: str = "graph",
                 k: int = 256, shard_pad: int = 512,
                 slab_layout: str = "dense", hot_d: Optional[int] = None,
                 **kw):
        for attr in ("enc", "set_filter_eval"):
            if not hasattr(source, attr):
                raise TypeError(
                    "ShardedGraphQueryEngine needs a flat-style source "
                    "(FlatMSQIndex); tree sources have no slab arrays")
        super().__init__(source, backend="distributed",
                         slab_layout=slab_layout, hot_d=hot_d, **kw)
        from repro.core.engine import BatchedFilterEval
        self.mesh = mesh
        self.layout = layout
        self.evaluator = BatchedFilterEval(
            source.db, source.enc, source.partition, backend="distributed",
            mesh=mesh, layout=layout, k=k, shard_pad=shard_pad,
            slab=slab_layout, hot_d=hot_d)
        # also visible to plain GraphQueryEngine(source, "distributed") users
        source.set_filter_eval("distributed", self.evaluator)

    @classmethod
    def from_config(cls, source: CandidateSource, mesh, cfg,
                    **kw) -> "ShardedGraphQueryEngine":
        """Layouts/top-k from an MSQConfig (msq_pubchem defaults to the
        vocab-sharded layout and the hot slab for its wide q-gram
        vocabulary)."""
        kw.setdefault("slab_layout", getattr(cfg, "slab_layout", "dense"))
        kw.setdefault("hot_d", getattr(cfg, "hot_d", None))
        return cls(source, mesh,
                   layout=getattr(cfg, "sharded_layout", "graph"),
                   k=int(getattr(cfg, "shard_topk", 256)), **kw)

    def _batched_candidates(self, graphs, taus, qtuples):
        from repro.core.engine import batched_flat_candidates
        return batched_flat_candidates(self.evaluator, graphs, taus, qtuples)

    @property
    def shard_stats(self) -> Dict[str, int]:
        """Candidate-block accounting (overflow_blocks counts recall-safe
        exact-id fallbacks, not drops)."""
        return dict(self.evaluator.dist_stats)
