"""Typed error taxonomy for the serving stack (DESIGN.md §18).

Every fault the pipeline can hit resolves a ticket with one of these —
callers can branch on the class (admission rejection vs. stage failure)
without parsing messages, and the invariant "every injected fault
resolves to a typed outcome, never a hang" is checkable by type.
"""
from __future__ import annotations

from typing import Optional


class QueryError(RuntimeError):
    """Base class for typed per-query failures.

    ``stage`` names the pipeline stage that failed ("admission",
    "filter", "verify"); ``cause`` carries the original exception when
    one exists (also chained via ``__cause__`` for tracebacks).
    """

    stage = "query"

    def __init__(self, message: str, *,
                 cause: Optional[BaseException] = None) -> None:
        super().__init__(message)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause


class FilterStageError(QueryError):
    """The device filter stage raised for this ticket's batch.

    Only the poisoned batch's tickets fail; the filter thread and every
    other in-flight query keep running (DESIGN.md §18)."""

    stage = "filter"


class VerifyStageError(QueryError):
    """The verification stage failed this query beyond containment."""

    stage = "verify"


class AdmissionError(QueryError):
    """The bounded inbox rejected (or shed) this query under overload.

    ``policy`` is the shedding policy that fired ("reject" rejected the
    new arrival, "shed_oldest" evicted a queued victim); ``shed`` is
    True on the evicted victim's ticket, False on a rejected arrival.
    """

    stage = "admission"

    def __init__(self, message: str, *, policy: str = "reject",
                 tenant: Optional[str] = None, shed: bool = False,
                 cause: Optional[BaseException] = None) -> None:
        super().__init__(message, cause=cause)
        self.policy = policy
        self.tenant = tenant
        self.shed = shed
