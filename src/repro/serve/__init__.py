from repro.serve.engine import ServeEngine, Request
from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                      ShardedGraphQueryEngine)

__all__ = ["ServeEngine", "Request", "GraphQuery", "GraphQueryEngine",
           "ShardedGraphQueryEngine"]
