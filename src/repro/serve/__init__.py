from repro.serve.engine import ServeEngine, Request
from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                      ShardedGraphQueryEngine,
                                      VerifyScheduler)
from repro.serve.pipeline import (AsyncGraphQueryEngine, QueryTicket,
                                  as_completed)

__all__ = ["ServeEngine", "Request", "GraphQuery", "GraphQueryEngine",
           "ShardedGraphQueryEngine", "VerifyScheduler",
           "AsyncGraphQueryEngine", "QueryTicket", "as_completed"]
