from repro.serve.engine import ServeEngine, Request
from repro.serve.errors import (AdmissionError, FilterStageError,
                                QueryError, VerifyStageError)
from repro.serve.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                      ShardedGraphQueryEngine,
                                      VerifyScheduler)
from repro.serve.pipeline import (AsyncGraphQueryEngine, QueryTicket,
                                  as_completed)
from repro.serve.traffic import (TenantSpec, TrafficReport, TrafficTrace,
                                 generate_trace, replay, tenant_weights)

__all__ = ["ServeEngine", "Request", "GraphQuery", "GraphQueryEngine",
           "ShardedGraphQueryEngine", "VerifyScheduler",
           "AsyncGraphQueryEngine", "QueryTicket", "as_completed",
           "TenantSpec", "TrafficReport", "TrafficTrace",
           "generate_trace", "replay", "tenant_weights",
           "QueryError", "FilterStageError", "VerifyStageError",
           "AdmissionError", "FaultInjector", "FaultSpec", "InjectedFault"]
